"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)
recurrent update for decode.

Recurrence (per head, state H in R^{dh x dstate}):
    H_t = a_t * H_{t-1} + dt_t * x_t B_t^T,   a_t = exp(-exp(A_log) dt_t)
    y_t = H_t C_t + D * x_t

Train path uses the standard SSD chunking: quadratic intra-chunk form +
sequential inter-chunk state carry (lax.scan over chunks). This keeps the
materialized state at (b, nchunks, heads, dh, dstate) instead of per-token.
Decode is inapplicable territory for KVPR (state is O(1), nothing to
stream) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.sharding import shard

Array = jax.Array


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.head_dim
    return d_inner, nheads, ssm.head_dim, ssm.state_dim


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d_inner, nh, dh, ds = _dims(cfg)
    h = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        # fused input projection -> [x, z, B, C, dt]
        "in_proj": dense_init(ks[0], h, (2 * d_inner + 2 * ds + nh,), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_width, d_inner))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[5], d_inner, (h,), dtype),
    }


def _split_proj(xp: Array, cfg: ModelConfig):
    d_inner, nh, dh, ds = _dims(cfg)
    x, z, B, C, dt = jnp.split(
        xp, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds],
        axis=-1)
    return x, z, B, C, dt


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, nh, dh, ds = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, d_inner), dtype),
        "ssd": jnp.zeros((batch, nh, dh, ds), jnp.float32),
    }


def mamba2_forward(x_in: Array, p: dict, cfg: ModelConfig) -> Array:
    """Full-sequence chunked SSD. x_in: (b, s, h) -> (b, s, h)."""
    y, _ = mamba2_forward_with_state(x_in, p, cfg)
    return y


def mamba2_forward_with_state(x_in: Array, p: dict, cfg: ModelConfig
                              ) -> Tuple[Array, dict]:
    """As mamba2_forward but also returns the final recurrent state
    (for hybrid prefill -> decode handoff)."""
    d_inner, nh, dh, ds = _dims(cfg)
    b, s_orig, _ = x_in.shape
    Q = min(cfg.ssm.chunk, s_orig)
    s = ((s_orig + Q - 1) // Q) * Q
    if s != s_orig:  # pad; padded steps get dt=0 -> identity state update
        x_in = jnp.pad(x_in, ((0, 0), (0, s - s_orig), (0, 0)))
    nc = s // Q

    xp = jnp.einsum("bsh,hD->bsD", x_in, p["in_proj"])
    x, z, B, C, dt = _split_proj(xp, cfg)
    x = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = shard(x, "batch", "seq", None)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (b,s,nh)
    if s != s_orig:
        dt = dt * (jnp.arange(s) < s_orig)[None, :, None]
    loga = -jnp.exp(p["A_log"]) * dt                                  # (b,s,nh)

    # reshape into chunks
    xc = x.reshape(b, nc, Q, nh, dh).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, ds).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, ds).astype(jnp.float32)
    dtc = dt.reshape(b, nc, Q, nh)
    lac = loga.reshape(b, nc, Q, nh)
    La = jnp.cumsum(lac, axis=2)                                      # inclusive

    # ---- intra-chunk (quadratic, masked) ----
    CB = jnp.einsum("bcqd,bckd->bcqk", Cc, Bc)                        # (b,nc,Q,Q)
    M = jnp.exp(La[:, :, :, None, :] - La[:, :, None, :, :])          # (b,nc,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], M, 0.0)
    S = CB[..., None] * M * dtc[:, :, None, :, :]                     # (b,nc,i,j,nh)
    y_intra = jnp.einsum("bcijn,bcjnd->bcind", S, xc)

    # ---- chunk states ----
    decay_end = jnp.exp(La[:, :, -1:, :] - La)                        # (b,nc,Q,nh)
    chunk_state = jnp.einsum("bcqn,bcqnd,bcqs->bcnds",
                             dtc * decay_end, xc, Bc)                 # (b,nc,nh,dh,ds)
    chunk_decay = jnp.exp(La[:, :, -1, :])                            # (b,nc,nh)

    def carry(H, inp):
        st, dec = inp
        H_out = H                                                     # state entering chunk
        H = H * dec[:, :, None, None] + st
        return H, H_out

    H0 = jnp.zeros((b, nh, dh, ds), jnp.float32)
    H_final, H_in = jax.lax.scan(
        carry, H0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    H_in = jnp.moveaxis(H_in, 0, 1)                                   # (b,nc,nh,dh,ds)

    # ---- inter-chunk ----
    y_inter = jnp.einsum("bcqs,bcnds->bcqnd", Cc, H_in) \
        * jnp.exp(La)[..., None]                                      # (b,nc,Q,nh,dh)

    y = (y_intra + y_inter).reshape(b, s, nh, dh)
    y = y + p["D"][None, None, :, None] * x.reshape(b, s, nh, dh).astype(jnp.float32)
    y = y.reshape(b, s, d_inner)[:, :s_orig].astype(x_in.dtype)
    y = y * jax.nn.silu(z[:, :s_orig])
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsD,Dh->bsh", y, p["out_proj"])
    # conv state = last (width-1) *pre-conv, real* inputs
    width = cfg.ssm.conv_width
    x_pre = _split_proj(xp, cfg)[0][:, :s_orig]
    if s_orig >= width - 1:
        conv_state = x_pre[:, s_orig - (width - 1):, :]
    else:
        conv_state = jnp.pad(x_pre,
                             ((0, 0), (width - 1 - s_orig, 0), (0, 0)))
    return out, {"conv": conv_state.astype(x_in.dtype), "ssd": H_final}


def mamba2_decode(x_in: Array, state: dict, p: dict, cfg: ModelConfig
                  ) -> Tuple[Array, dict]:
    """One-token step. x_in: (b, 1, h) -> (b, 1, h), new state."""
    d_inner, nh, dh, ds = _dims(cfg)
    b = x_in.shape[0]

    xp = jnp.einsum("bsh,hD->bsD", x_in, p["in_proj"])
    x, z, B, C, dt = _split_proj(xp, cfg)

    # conv with rolling state
    conv_in = jnp.concatenate([state["conv"], x], axis=1)   # (b, width, d)
    w = p["conv_w"]
    xconv = jnp.einsum("bwd,wd->bd", conv_in, w) + p["conv_b"]
    xconv = jax.nn.silu(xconv)[:, None, :]                  # (b,1,d)
    new_conv = conv_in[:, 1:, :]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                             # (b,nh)
    xh = xconv[:, 0].reshape(b, nh, dh).astype(jnp.float32)
    Bv = B[:, 0].astype(jnp.float32)                                   # (b,ds)
    Cv = C[:, 0].astype(jnp.float32)

    H = state["ssd"] * a[:, :, None, None] \
        + jnp.einsum("bn,bnd,bs->bnds", dt, xh, Bv)
    y = jnp.einsum("bnds,bs->bnd", H, Cv) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x_in.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsD,Dh->bsh", y, p["out_proj"])
    return out, {"conv": new_conv, "ssd": H}


def mamba2_reference(x_in: Array, p: dict, cfg: ModelConfig) -> Array:
    """Naive sequential oracle for tests: runs decode step over the seq."""
    b, s, _ = x_in.shape
    state = init_state(cfg, b, x_in.dtype)

    def step(state, xt):
        y, state = mamba2_decode(xt[:, None, :], state, p, cfg)
        return state, y[:, 0]

    _, ys = jax.lax.scan(step, state, jnp.moveaxis(x_in, 1, 0))
    return jnp.moveaxis(ys, 0, 1)
