"""Core transformer layers: norms, rope, attention (GQA, sliding-window,
cross), MLP (gated & plain), embeddings. Pure functions over param pytrees;
stacked-layer params are scanned by transformer.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


# ---------------------------------------------------------------- init utils

def dense_init(key, in_dim: int, out_shape, dtype) -> Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim,) + tuple(out_shape)) * scale).astype(dtype)


# --------------------------------------------------------------------- norms

def rms_norm(x: Array, gamma: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def apply_norm(x: Array, p: dict, eps: float) -> Array:
    if "beta" in p:
        return layer_norm(x, p["gamma"], p["beta"], eps)
    return rms_norm(x, p["gamma"], eps)


def init_norm(key, d: int, dtype, layer: bool = False) -> dict:
    p = {"gamma": jnp.ones((d,), dtype)}
    if layer:
        p["beta"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------- rope

def rope_freqs(dh: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, dh); positions: broadcastable to (..., seq)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, dh/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin, cos = sin[..., None, :], cos[..., None, :]      # (..., s, 1, dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    dh: int


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    h, dh = cfg.d_model, cfg.dh
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, h, (cfg.num_heads, dh), dtype),
        "wk": dense_init(k2, h, (cfg.num_kv_heads, dh), dtype),
        "wv": dense_init(k3, h, (cfg.num_kv_heads, dh), dtype),
        "wo": dense_init(k4, cfg.num_heads * dh, (h,), dtype),
    }


def qkv_proj(x: Array, p: dict, cfg: ModelConfig, positions: Optional[Array]
             ) -> Tuple[Array, Array, Array]:
    """x: (b, s, h) -> q (b,s,H,dh), k/v (b,s,KV,dh); rope if configured."""
    q = jnp.einsum("bsh,hnd->bsnd", x, p["wq"])
    k = jnp.einsum("bsh,hnd->bsnd", x, p["wk"])
    v = jnp.einsum("bsh,hnd->bsnd", x, p["wv"])
    if cfg.pos_embedding == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def gqa_attend(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q: (b, sq, H, dh); k,v: (b, skv, KV, dh); mask broadcastable to
    (b, H, sq, skv) or (b, 1, sq, skv). Returns (b, sq, H, dh)."""
    b, sq, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(b, sq, KV, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if mask is not None:
        # mask (b, 1, sq, skv) -> (b, 1, 1, sq, skv)
        scores = jnp.where(mask[:, :, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, H, dh)


def causal_mask(sq: int, skv: int, q_offset: int = 0,
                window: int = 0) -> Array:
    """(1, 1, sq, skv) bool; window>0 adds sliding-window banding."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m[None, None]


def attention_block(x: Array, p: dict, cfg: ModelConfig, positions: Array,
                    window: int = 0, memory: Optional[Array] = None) -> Array:
    """Full-sequence (train/prefill) self-attention; if `memory` is given,
    cross-attention over it (no mask, no rope on memory side)."""
    if memory is None:
        q, k, v = qkv_proj(x, p, cfg, positions)
        mask = causal_mask(x.shape[1], x.shape[1], 0, window)
        out = gqa_attend(q, k, v, mask)
    else:
        q = jnp.einsum("bsh,hnd->bsnd", x, p["wq"])
        k = jnp.einsum("bsh,hnd->bsnd", memory, p["wk"])
        v = jnp.einsum("bsh,hnd->bsnd", memory, p["wv"])
        out = gqa_attend(q, k, v, None)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.num_heads * cfg.dh)
    return jnp.einsum("bsD,Dh->bsh", out, p["wo"])


def chunked_causal_attend(q: Array, k: Array, v: Array, window: int = 0,
                          q_block: int = 512, q_offset: int = 0,
                          unroll: bool = False,
                          kv_start: Optional[Array] = None) -> Array:
    """Memory-bounded causal GQA attention: scan over query blocks so the
    (sq x skv) score matrix is never materialized at full size. Exact.

    q: (b, sq, H, dh); k/v: (b, skv, KV, dh). window>0 = sliding window.
    unroll=True emits every block statically (accurate XLA cost analysis
    for the roofline dry-run; scan bodies are costed once).
    kv_start: optional (b,) per-row first VALID key index — keys before
    it (a ragged batch's left-padding) get exactly zero attention
    weight.  Queries in the padded region see only masked keys; the
    NEG_INF trick keeps their (discarded) outputs finite.
    """
    b, sq, H, dh = q.shape
    skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    if sq <= q_block:
        mask = causal_mask(sq, skv, q_offset, window)
        if kv_start is not None:
            mask = mask & (jnp.arange(skv)[None, None, None, :]
                           >= kv_start[:, None, None, None])
        return gqa_attend(q, k, v, mask)
    assert sq % q_block == 0, (sq, q_block)
    nb = sq // q_block
    qb = q.reshape(b, nb, q_block, KV, g, dh)
    kj = jnp.arange(skv)[None, :]

    def body(_, qblk_i):
        qblk, i = qblk_i                          # (b, qB, KV, g, dh)
        off = i * q_block + q_offset
        scores = jnp.einsum("bskgd,btkd->bkgst", qblk, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(dh).astype(jnp.float32)
        qi = jnp.arange(q_block)[:, None] + off
        m = kj <= qi
        if window > 0:
            m = m & (kj > qi - window)
        if kv_start is not None:
            m = m[None] & (kj[None] >= kv_start[:, None, None])
            m = m[:, None, None]               # (b, 1, 1, qB, skv)
        else:
            m = m[None, None, None]
        scores = jnp.where(m, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
        return None, out

    if unroll:
        outs = jnp.stack([body(None, (qb[:, i], i))[1] for i in range(nb)])
    else:
        _, outs = jax.lax.scan(body, None,
                               (jnp.moveaxis(qb, 1, 0), jnp.arange(nb)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, H, dh)
    return out


# ----------------------------------------------------------------------- mlp

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_init(ks[0], d_model, (d_ff,), dtype),
        "w2": dense_init(ks[1], d_ff, (d_model,), dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[2], d_model, (d_ff,), dtype)
    return p


def mlp_block(x: Array, p: dict, act: str) -> Array:
    h = jnp.einsum("bsh,hf->bsf", x, p["w1"])
    h = shard(h, "batch", "seq", "mlp")
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    if "wg" in p:
        g = jnp.einsum("bsh,hf->bsf", x, p["wg"])
        g = shard(g, "batch", "seq", "mlp")
        h = a(g) * h
    else:
        h = a(h)
    return jnp.einsum("bsf,fh->bsh", h, p["w2"])


# ---------------------------------------------------------------- embeddings

def init_embedding(key, cfg: ModelConfig, dtype) -> dict:
    V = cfg.padded_vocab
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": (jax.random.normal(k1, (V, cfg.d_model)) * 0.02).astype(dtype)}
    if cfg.pos_embedding == "learned":
        p["pos"] = (jax.random.normal(k2, (cfg.max_seq_len, cfg.d_model))
                    * 0.02).astype(dtype)
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k3, cfg.d_model, (V,), dtype)
    return p


def embed(tokens: Array, p: dict, cfg: ModelConfig,
          positions: Optional[Array] = None) -> Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.pos_embedding == "learned":
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        x = x + jnp.take(p["pos"], jnp.clip(pos, 0, cfg.max_seq_len - 1), axis=0)
    return shard(x, "batch", "seq", "embed")


def unembed(x: Array, p: dict, cfg: ModelConfig) -> Array:
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsh,hv->bsv", x, w)
    logits = shard(logits, "batch", "seq", "vocab")
    # mask vocab padding
    V, Vp = cfg.vocab_size, cfg.padded_vocab
    if Vp > V:
        pad_mask = jnp.arange(Vp) >= V
        logits = jnp.where(pad_mask, NEG_INF, logits)
    return logits
