"""Unified model covering all assigned architecture families.

Public surface:
    m = Model(cfg)
    params          = m.init_params(key, dtype)
    logits, aux     = m.forward(params, tokens, extra)        # train/teacher-forcing
    logits, cache   = m.prefill(params, tokens, extra, max_len)
    logits, cache   = m.decode_step(params, cache, token)
    cache           = m.init_cache(batch, max_len, dtype)

`extra` carries stub-frontend embeddings for audio (frames (b, enc_s, d))
and vlm (patches (b, P, d)). Stacked per-layer params are scanned
(jax.lax.scan) so the HLO stays one-layer-sized for the 512-device
dry-run. Full-sequence attention is chunked over query blocks (exact,
flash-style) so s x s score matrices are never materialized.

gemma3's 5:1 local:global pattern is structured as "superblocks": scan
over n_super groups of (global_every-1 sliding-window layers + 1 global
layer), each sub-population with its own stacked params and cache (local
layers keep a ring buffer of window size W — this is what makes long_500k
decode sub-quadratic-memory for gemma3).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cache as cache_lib
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL
from repro.models.sharding import shard

Array = jax.Array
PyTree = Any

Q_BLOCK = 512


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


class Model:
    def __init__(self, cfg: ModelConfig, remat: bool = False,
                 use_kernels: bool = False, seq_shard: bool = False,
                 scan_layers: bool = True, q_block: int = Q_BLOCK,
                 seq_shard_impl: str = "gspmd", moe_impl: str = "gspmd"):
        self.cfg = cfg
        self.remat = remat
        self.use_kernels = use_kernels
        # seq_shard: decode KV cache is sharded along the sequence dim
        # (long_500k, batch=1) -> use masked one-hot cache writes so GSPMD
        # never gathers the cache (see models/cache.py).
        self.seq_shard = seq_shard
        # scan_layers=False unrolls the layer loop: bigger HLO + slower
        # compile, but XLA cost_analysis then counts every layer (scan
        # bodies are costed ONCE by XLA) — used by the roofline dry-run.
        self.scan_layers = scan_layers
        self.q_block = q_block
        # "gspmd": masked writes + auto-partitioned softmax (baseline);
        # "shard_map": manual owner-shard write + two-psum combine
        # (models/seq_parallel.py — the beyond-paper §Perf variant).
        self.seq_shard_impl = seq_shard_impl
        # MoE dispatch: "gspmd" = global-capacity einsum dispatch
        # (baseline); "shard_map" = GShard-style local dispatch with
        # expert parallelism over "model" (models/moe.py §Perf variant).
        self.moe_impl = moe_impl

    def _scan(self, body, carry, xs):
        """lax.scan over stacked layers, or an unrolled python loop."""
        if self.scan_layers:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return carry, ys

    @property
    def is_local_global(self) -> bool:
        return bool(self.cfg.sliding_window and self.cfg.global_every)

    # ------------------------------------------------------------- params

    def _dense_layer_init(self, dtype):
        cfg = self.cfg
        ln_layer = cfg.pos_embedding == "learned"

        def init(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            return {
                "ln1": L.init_norm(k1, cfg.d_model, dtype, layer=ln_layer),
                "attn": L.init_attention(k2, cfg, dtype),
                "ln2": L.init_norm(k3, cfg.d_model, dtype, layer=ln_layer),
                "mlp": L.init_mlp(k4, cfg.d_model, cfg.d_ff, dtype,
                                  cfg.gated_mlp),
            }
        return init

    def init_params(self, key, dtype=jnp.float32) -> PyTree:
        cfg = self.cfg
        k_emb, k_layers, k_final, k_enc, k_shared = jax.random.split(key, 5)
        params: Dict[str, PyTree] = {
            "embed": L.init_embedding(k_emb, cfg, dtype),
            "final_norm": L.init_norm(k_final, cfg.d_model, dtype,
                                      layer=cfg.pos_embedding == "learned"),
        }
        at = cfg.arch_type
        dense_layer = self._dense_layer_init(dtype)

        def moe_layer(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            return {
                "ln1": L.init_norm(k1, cfg.d_model, dtype),
                "attn": L.init_attention(k2, cfg, dtype),
                "ln2": L.init_norm(k3, cfg.d_model, dtype),
                "moe": MOE.init_moe(k4, cfg, dtype),
            }

        if at in ("dense", "vlm"):
            if self.is_local_global:
                ge = cfg.global_every
                n_super = cfg.num_layers // ge
                params["local_layers"] = _stack_init(
                    lambda k: _stack_init(dense_layer, k, ge - 1),
                    k_layers, n_super)
                params["global_layers"] = _stack_init(
                    dense_layer, jax.random.fold_in(k_layers, 1), n_super)
            else:
                params["layers"] = _stack_init(dense_layer, k_layers,
                                               cfg.num_layers)
        elif at == "moe":
            params["layers"] = _stack_init(moe_layer, k_layers,
                                           cfg.num_layers)
        elif at == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.num_layers // every
            params["layers"] = _stack_init(
                lambda k: _stack_init(
                    lambda kk: {"ln": L.init_norm(kk, cfg.d_model, dtype),
                                "mamba": M2.init_mamba2(kk, cfg, dtype)},
                    k, every),
                k_layers, n_groups)
            params["shared"] = dense_layer(k_shared)
        elif at == "ssm":
            n_pairs = cfg.num_layers // 2
            params["layers"] = _stack_init(
                lambda k: {
                    "mlstm": XL.init_mlstm(jax.random.fold_in(k, 0), cfg,
                                           dtype),
                    "slstm": XL.init_slstm(jax.random.fold_in(k, 1), cfg,
                                           dtype),
                }, k_layers, n_pairs)
        elif at == "audio":
            def dec_layer(k):
                ks = jax.random.split(k, 6)
                return {
                    "ln1": L.init_norm(ks[0], cfg.d_model, dtype, layer=True),
                    "self_attn": L.init_attention(ks[1], cfg, dtype),
                    "ln_x": L.init_norm(ks[2], cfg.d_model, dtype, layer=True),
                    "cross_attn": L.init_attention(ks[3], cfg, dtype),
                    "ln2": L.init_norm(ks[4], cfg.d_model, dtype, layer=True),
                    "mlp": L.init_mlp(ks[5], cfg.d_model, cfg.d_ff, dtype,
                                      cfg.gated_mlp),
                }
            params["layers"] = _stack_init(dec_layer, k_layers,
                                           cfg.num_layers)
            params["encoder"] = _stack_init(dense_layer, k_enc,
                                            cfg.encoder_layers)
            params["enc_pos"] = (jax.random.normal(
                jax.random.fold_in(k_enc, 9),
                (cfg.encoder_seq_len, cfg.d_model)) * 0.02).astype(dtype)
            params["enc_norm"] = L.init_norm(jax.random.fold_in(k_enc, 7),
                                             cfg.d_model, dtype, layer=True)
        else:
            raise ValueError(f"unknown arch_type {at}")
        return params

    # ------------------------------------------------------------ shared bits

    def _attn_sublayer(self, x, lp, positions, window: int,
                       collect_kv: bool = False, kv_start=None):
        """Pre-norm attention sublayer on full sequences (chunked).
        kv_start: optional (b,) first valid key per row (left-padded
        ragged batches)."""
        cfg = self.cfg
        b, s = x.shape[:2]
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
        out = L.chunked_causal_attend(q, k, v, window=window,
                                      q_block=self.q_block,
                                      unroll=not self.scan_layers,
                                      kv_start=kv_start)
        out = out.reshape(b, s, cfg.num_heads * cfg.dh)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        if collect_kv:
            return x, (k, v)
        return x

    def _mlp_sublayer(self, x, lp):
        cfg = self.cfg
        h = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        if "moe" in lp:
            moe_fn = (MOE.moe_block_sharded if self.moe_impl == "shard_map"
                      else MOE.moe_block)
            out, aux = moe_fn(h, lp["moe"], cfg)
            return x + out, aux
        return x + L.mlp_block(h, lp["mlp"], cfg.act), jnp.zeros(())

    def _encode(self, params, frames: Array) -> Array:
        """Whisper encoder over stub frame embeddings (b, enc_s, d)."""
        cfg = self.cfg
        x = frames + params["enc_pos"][None, : frames.shape[1]]

        def body(x, lp):
            b, s = x.shape[:2]
            h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
            q = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wq"])
            k = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wk"])
            v = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wv"])
            o = L.gqa_attend(q, k, v, None)          # bidirectional
            o = o.reshape(b, s, -1)
            x = x + jnp.einsum("bsD,Dh->bsh", o, lp["attn"]["wo"])
            h = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
            return x + L.mlp_block(h, lp["mlp"], cfg.act), None

        x, _ = self._scan(body, x, params["encoder"])
        return L.apply_norm(x, params["enc_norm"], cfg.rms_eps)

    def _embed_inputs(self, params, tokens, extra):
        cfg = self.cfg
        pos = jnp.arange(tokens.shape[1])
        x = L.embed(tokens, params["embed"], cfg, pos)
        if cfg.arch_type == "vlm":
            x = jnp.concatenate([extra["patches"].astype(x.dtype), x], axis=1)
        return x

    # ------------------------------------------------------- forward (train)

    def forward(self, params, tokens: Array,
                extra: Optional[Dict[str, Array]] = None
                ) -> Tuple[Array, Array]:
        """Teacher-forcing full-sequence forward -> (logits, aux_loss)."""
        cfg = self.cfg
        at = cfg.arch_type
        x = self._embed_inputs(params, tokens, extra)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        aux = jnp.zeros((), jnp.float32)

        if at in ("dense", "vlm", "moe") and not self.is_local_global:
            def body(x, lp):
                x = self._attn_sublayer(x, lp, positions, window=0)
                x, a = self._mlp_sublayer(x, lp)
                return x, a
            body_fn = jax.checkpoint(body) if self.remat else body
            x, auxs = self._scan(body_fn, x, params["layers"])
            aux = jnp.sum(auxs)
        elif self.is_local_global:
            W = cfg.sliding_window

            def superblock(x, inp):
                loc_lp, glob_lp = inp

                def local(x, lp):
                    x = self._attn_sublayer(x, lp, positions, window=W)
                    x, _ = self._mlp_sublayer(x, lp)
                    return x, None
                x, _ = self._scan(local, x, loc_lp)
                x = self._attn_sublayer(x, glob_lp, positions, window=0)
                x, _ = self._mlp_sublayer(x, glob_lp)
                return x, None

            sb = jax.checkpoint(superblock) if self.remat else superblock
            x, _ = self._scan(sb, x, (params["local_layers"],
                                        params["global_layers"]))
        elif at == "hybrid":
            def group(x, glp):
                def mbody(x, lp):
                    h = L.rms_norm(x, lp["ln"]["gamma"], cfg.rms_eps)
                    return x + M2.mamba2_forward(h, lp["mamba"], cfg), None
                x, _ = self._scan(mbody, x, glp)
                sp = params["shared"]
                x = self._attn_sublayer(x, sp, positions, window=0)
                x, _ = self._mlp_sublayer(x, sp)
                return x, None
            group_fn = jax.checkpoint(group) if self.remat else group
            x, _ = self._scan(group_fn, x, params["layers"])
        elif at == "ssm":
            def pair(x, lp):
                x = x + XL.mlstm_forward(x, lp["mlstm"], cfg)
                x = x + XL.slstm_forward(x, lp["slstm"], cfg)
                return x, None
            pair_fn = jax.checkpoint(pair) if self.remat else pair
            x, _ = self._scan(pair_fn, x, params["layers"])
        elif at == "audio":
            memory = self._encode(params, extra["frames"])

            def body(x, lp):
                h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.qkv_proj(h, lp["self_attn"], cfg, positions)
                out = L.chunked_causal_attend(q, k, v,
                                              q_block=self.q_block,
                                              unroll=not self.scan_layers)
                out = out.reshape(b, s, -1)
                x = x + jnp.einsum("bsD,Dh->bsh", out, lp["self_attn"]["wo"])
                h = L.apply_norm(x, lp["ln_x"], cfg.rms_eps)
                x = x + L.attention_block(h, lp["cross_attn"], cfg, positions,
                                          memory=memory)
                h = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
                return x + L.mlp_block(h, lp["mlp"], cfg.act), None

            x, _ = self._scan(body, x, params["layers"])

        x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
        if at == "vlm":  # only score text positions
            x = x[:, extra["patches"].shape[1]:]
        logits = L.unembed(x, params["embed"], cfg)
        return logits, aux

    # ------------------------------------------------------------- caches

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   ) -> PyTree:
        cfg = self.cfg
        at = cfg.arch_type
        KV, dh = cfg.num_kv_heads, cfg.dh
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if at in ("dense", "vlm", "moe"):
            if self.is_local_global:
                ge = cfg.global_every
                n_super = cfg.num_layers // ge
                W = min(cfg.sliding_window, max_len)
                cache["k_local"] = jnp.zeros(
                    (n_super, ge - 1, batch, W, KV, dh), dtype)
                cache["v_local"] = jnp.zeros_like(cache["k_local"])
                cache["k_global"], cache["v_global"] = cache_lib.init_kv(
                    batch, max_len, KV, dh, dtype, n_super)
            else:
                cache["k"], cache["v"] = cache_lib.init_kv(
                    batch, max_len, KV, dh, dtype, cfg.num_layers)
                # per-row left-pad of a ragged prefill: the first `pad`
                # cache slots of each row are masked out of decode
                # attention and RoPE positions are shifted by -pad
                cache["pad"] = jnp.zeros((batch,), jnp.int32)
        elif at == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.num_layers // every
            st = M2.init_state(cfg, batch, dtype)
            cache["mamba"] = jax.tree.map(
                lambda a: jnp.zeros((n_groups, every) + a.shape, a.dtype), st)
            cache["k"], cache["v"] = cache_lib.init_kv(
                batch, max_len, KV, dh, dtype, n_groups)
        elif at == "ssm":
            n_pairs = cfg.num_layers // 2
            ms = XL.init_mlstm_state(cfg, batch)
            ss = XL.init_slstm_state(cfg, batch)
            cache["mlstm"] = jax.tree.map(
                lambda a: jnp.zeros((n_pairs,) + a.shape, a.dtype), ms)
            cache["mlstm"]["m"] = jnp.full_like(cache["mlstm"]["m"], -1e30)
            cache["slstm"] = jax.tree.map(
                lambda a: jnp.zeros((n_pairs,) + a.shape, a.dtype), ss)
            cache["slstm"]["m"] = jnp.full_like(cache["slstm"]["m"], -1e30)
        elif at == "audio":
            cache["k"], cache["v"] = cache_lib.init_kv(
                batch, max_len, KV, dh, dtype, cfg.num_layers)
            cache["k_cross"], cache["v_cross"] = cache_lib.init_kv(
                batch, cfg.encoder_seq_len, KV, dh, dtype, cfg.num_layers)
        return cache

    # ------------------------------------------------------------ prefill

    def prefill(self, params, tokens: Array,
                extra: Optional[Dict[str, Array]] = None,
                max_len: Optional[int] = None,
                cache_dtype=None,
                prompt_lens: Optional[Array] = None) -> Tuple[Array, PyTree]:
        """Process the prompt, fill the cache, return last-position logits.

        prompt_lens: optional (b,) true per-row prompt lengths of a
        LEFT-padded ragged batch.  Row i's real tokens occupy columns
        [s - len_i, s); its first real token gets position 0 (RoPE /
        learned embeddings shifted per row), padding columns are masked
        out of every attention (exactly zero weight), and the per-row
        pad width is recorded in ``cache["pad"]`` so ``decode_step``
        keeps masking and shifting.  Dense-family archs only — SSM /
        hybrid recurrences would thread pad tokens through their state.
        """
        cfg = self.cfg
        at = cfg.arch_type
        b = tokens.shape[0]
        max_len = max_len or cfg.max_seq_len
        kv_start = None
        if prompt_lens is not None:
            if (at not in ("dense", "vlm", "moe") or self.is_local_global
                    or (extra is not None and extra)):
                raise NotImplementedError(
                    "ragged prompt_lens is only supported for dense-family "
                    f"archs without extra inputs (arch_type={at!r})")
            if self.seq_shard and self.seq_shard_impl == "shard_map":
                # the shard_map decode attend has no kv_start masking —
                # refuse rather than silently attend over pad keys
                raise NotImplementedError(
                    "ragged prompt_lens is not supported with "
                    "seq_shard_impl='shard_map'")
            s = tokens.shape[1]
            pads = (s - jnp.asarray(prompt_lens)).astype(jnp.int32)
            positions = jnp.maximum(
                jnp.arange(s)[None, :] - pads[:, None], 0)
            x = L.embed(tokens, params["embed"], cfg, positions)
            kv_start = pads
        else:
            x = self._embed_inputs(params, tokens, extra)
            s = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        cache_dtype = cache_dtype or x.dtype
        cache = self.init_cache(b, max_len, cache_dtype)

        def put(c, kv, offset=(0, 0, 0, 0, 0)):
            return jax.lax.dynamic_update_slice(c, kv.astype(c.dtype), offset)

        if at in ("dense", "vlm", "moe") and not self.is_local_global:
            def body(x, lp):
                x, (k, v) = self._attn_sublayer(x, lp, positions, 0,
                                                collect_kv=True,
                                                kv_start=kv_start)
                x, _ = self._mlp_sublayer(x, lp)
                return x, (k, v)
            x, (ks, vs) = self._scan(body, x, params["layers"])
            cache["k"], cache["v"] = put(cache["k"], ks), put(cache["v"], vs)
            if kv_start is not None:
                cache["pad"] = kv_start
        elif self.is_local_global:
            W = min(cfg.sliding_window, max_len)

            def superblock(x, inp):
                loc_lp, glob_lp = inp

                def local(x, lp):
                    x, (k, v) = self._attn_sublayer(
                        x, lp, positions, cfg.sliding_window, collect_kv=True)
                    x, _ = self._mlp_sublayer(x, lp)
                    return x, (k, v)
                x, (kl, vl) = self._scan(local, x, loc_lp)
                x, (kg, vg) = self._attn_sublayer(x, glob_lp, positions, 0,
                                                  collect_kv=True)
                x, _ = self._mlp_sublayer(x, glob_lp)
                return x, (kl, vl, kg, vg)

            x, (kls, vls, kgs, vgs) = self._scan(
                superblock, x,
                (params["local_layers"], params["global_layers"]))
            # rings for locals (kls: (n_super, ge-1, b, s, KV, dh))
            cache["k_local"] = _fill_ring(cache["k_local"], kls, s)
            cache["v_local"] = _fill_ring(cache["v_local"], vls, s)
            cache["k_global"] = put(cache["k_global"], kgs)
            cache["v_global"] = put(cache["v_global"], vgs)
        elif at == "hybrid":
            def group(x, glp):
                def mbody(x, lp):
                    h = L.rms_norm(x, lp["ln"]["gamma"], cfg.rms_eps)
                    out, st = M2.mamba2_forward_with_state(h, lp["mamba"],
                                                           cfg)
                    return x + out, st
                x, mstates = self._scan(mbody, x, glp)
                sp = params["shared"]
                x, (k, v) = self._attn_sublayer(x, sp, positions, 0,
                                                collect_kv=True)
                x, _ = self._mlp_sublayer(x, sp)
                return x, (mstates, k, v)

            x, (mst, ks, vs) = self._scan(group, x, params["layers"])
            cache["mamba"] = jax.tree.map(
                lambda z, n: n.astype(z.dtype), cache["mamba"], mst)
            cache["k"], cache["v"] = put(cache["k"], ks), put(cache["v"], vs)
        elif at == "ssm":
            def pair(x, lp):
                out, ms = XL.mlstm_forward_with_state(x, lp["mlstm"], cfg)
                x = x + out
                out, ss = XL.slstm_forward_with_state(x, lp["slstm"], cfg)
                return x + out, (ms, ss)
            x, (mss, sss) = self._scan(pair, x, params["layers"])
            cache["mlstm"], cache["slstm"] = mss, sss
        elif at == "audio":
            memory = self._encode(params, extra["frames"])

            def body(x, lp):
                h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.qkv_proj(h, lp["self_attn"], cfg, positions)
                out = L.chunked_causal_attend(q, k, v,
                                              q_block=self.q_block,
                                              unroll=not self.scan_layers)
                out = out.reshape(b, s, -1)
                x = x + jnp.einsum("bsD,Dh->bsh", out, lp["self_attn"]["wo"])
                h = L.apply_norm(x, lp["ln_x"], cfg.rms_eps)
                kx = jnp.einsum("bsh,hnd->bsnd", memory,
                                lp["cross_attn"]["wk"])
                vx = jnp.einsum("bsh,hnd->bsnd", memory,
                                lp["cross_attn"]["wv"])
                qx = jnp.einsum("bsh,hnd->bsnd", h, lp["cross_attn"]["wq"])
                ox = L.gqa_attend(qx, kx, vx, None).reshape(b, s, -1)
                x = x + jnp.einsum("bsD,Dh->bsh", ox, lp["cross_attn"]["wo"])
                h = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
                return x + L.mlp_block(h, lp["mlp"], cfg.act), (k, v, kx, vx)

            x, (ks, vs, kxs, vxs) = self._scan(body, x, params["layers"])
            cache["k"], cache["v"] = put(cache["k"], ks), put(cache["v"], vs)
            cache["k_cross"] = kxs.astype(cache["k_cross"].dtype)
            cache["v_cross"] = vxs.astype(cache["v_cross"].dtype)
        else:
            raise NotImplementedError(at)

        cache["pos"] = jnp.asarray(s, jnp.int32)
        x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
        logits = L.unembed(x[:, -1:], params["embed"], cfg)
        return logits, cache

    # ------------------------------------------------------ chunked prefill

    def prefill_chunk(self, params, cache: PyTree, tokens: Array,
                      p0: int) -> Tuple[Array, PyTree]:
        """Resumable prefill: process prompt columns [p0, p0 + c) of a
        (possibly LEFT-padded ragged) batch against an existing cache.

        ``tokens`` (b, c) are the next c columns of the padded prompt;
        ``p0`` must equal the number of columns already prefilled (a
        static int — each (p0, c) pair is one XLA trace, so drivers
        should keep chunk widths bucketed).  Each chunk's queries attend
        over the cache's [0, p0) keys plus their own causal block, with
        the per-row pad recorded in ``cache["pad"]`` masked to exactly
        zero weight and RoPE/learned positions shifted per row — so a
        chunked prefill is token-identical to ``prefill`` on the same
        batch.  Start from ``init_cache`` (set ``cache["pad"]`` for
        ragged batches); dense-family archs only (the same envelope as
        ragged ``prompt_lens``).
        """
        cfg = self.cfg
        at = cfg.arch_type
        if at not in ("dense", "vlm", "moe") or self.is_local_global:
            raise NotImplementedError(
                "chunked prefill is only supported for dense-family "
                f"archs without local/global layers (arch_type={at!r})")
        b, c = tokens.shape
        use_sm = self.seq_shard and self.seq_shard_impl == "shard_map"
        pad = cache.get("pad")
        if use_sm and pad is not None:
            # same envelope as decode: the shard_map attend has no
            # kv_start masking — refuse rather than attend over pads
            raise NotImplementedError(
                "ragged pad is not supported with "
                "seq_shard_impl='shard_map'")
        cols = jnp.arange(c) + p0
        if pad is not None:
            positions = jnp.maximum(cols[None, :] - pad[:, None], 0)
        else:
            positions = jnp.broadcast_to(cols, (b, c))
        x = L.embed(tokens, params["embed"], cfg, positions)
        kv_start = pad

        def body(x, inp):
            lp, kc, vc = inp
            h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
            if use_sm:
                # sequence-parallel chunked prefill: the cache prefix
                # stays sharded — each shard reduces over its slice and
                # the chunk's own causal block folds in after the psum
                # (models/seq_parallel.py), so no per-chunk regather
                from repro.models import seq_parallel as SPAR
                out = SPAR.seq_sharded_prefill_chunk_attend(
                    q, kc, vc, k, v, p0)
                kc, vc = SPAR.seq_sharded_update_kv_chunk(
                    kc, vc, k, v, p0)
            else:
                # context = already-cached prefix + this chunk's own
                # keys (exact values, not possibly-downcast cache
                # copies)
                k_ctx = jnp.concatenate([kc[:, :p0].astype(k.dtype), k],
                                        axis=1)
                v_ctx = jnp.concatenate([vc[:, :p0].astype(v.dtype), v],
                                        axis=1)
                out = L.chunked_causal_attend(
                    q, k_ctx, v_ctx, q_block=self.q_block, q_offset=p0,
                    unroll=not self.scan_layers, kv_start=kv_start)
            out = out.reshape(b, c, cfg.num_heads * cfg.dh)
            x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
            x, _ = self._mlp_sublayer(x, lp)
            if not use_sm:
                kc = jax.lax.dynamic_update_slice(
                    kc, k.astype(kc.dtype), (0, p0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, v.astype(vc.dtype), (0, p0, 0, 0))
            return x, (kc, vc)

        x, (kn, vn) = self._scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = kn, vn
        cache["pos"] = jnp.asarray(p0 + c, jnp.int32)
        x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
        logits = L.unembed(x[:, -1:], params["embed"], cfg)
        return logits, cache

    # -------------------------------------------------------------- decode

    def decode_step(self, params, cache: PyTree, token: Array,
                    ) -> Tuple[Array, PyTree]:
        """token: (b, 1) -> (logits (b,1,V), updated cache)."""
        cfg = self.cfg
        at = cfg.arch_type
        b = token.shape[0]
        pos = cache["pos"]
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
        # ragged left-padded prefill: row i's token position is shifted
        # down by its pad width, and its padded cache slots stay masked
        pad = cache.get("pad")
        if pad is not None:
            positions = positions - pad[:, None]
        x = L.embed(token, params["embed"], cfg, positions)

        def _pin(kc, vc):
            # keep the cache sharding stable through the scan body so GSPMD
            # never invents an intermediate (gather-inducing) sharding
            kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
            vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
            return kc, vc

        use_sm = self.seq_shard and self.seq_shard_impl == "shard_map"

        def attn_decode(x, lp, kc, vc, ring, kv_start=None):
            h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
            q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
            if use_sm and not ring:
                from repro.models import seq_parallel as SPAR
                kc, vc = SPAR.seq_sharded_update_kv(kc, vc, k, v, pos)
                out = SPAR.seq_sharded_decode_attend(q, kc, vc, pos)
            else:
                kc, vc = cache_lib.update_kv(
                    kc, vc, k, v, pos, ring,
                    masked=self.seq_shard and not ring)
                if not ring:
                    kc, vc = _pin(kc, vc)
                out = cache_lib.decode_attend(q, kc, vc, pos, ring,
                                              kv_start=kv_start)
            out = out.reshape(b, 1, cfg.num_heads * cfg.dh)
            x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
            return x, kc, vc

        if at in ("dense", "vlm", "moe") and not self.is_local_global:
            def body(x, inp):
                lp, kc, vc = inp
                x, kc, vc = attn_decode(x, lp, kc, vc, False, kv_start=pad)
                x, _ = self._mlp_sublayer(x, lp)
                return x, (kc, vc)
            x, (kn, vn) = self._scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache["k"], cache["v"] = kn, vn
        elif self.is_local_global:
            def superblock(x, inp):
                loc_lp, glob_lp, kl, vl, kg, vg = inp

                def local(x, inp2):
                    lp, kc, vc = inp2
                    x, kc, vc = attn_decode(x, lp, kc, vc, True)
                    x, _ = self._mlp_sublayer(x, lp)
                    return x, (kc, vc)
                x, (kl, vl) = self._scan(local, x, (loc_lp, kl, vl))
                x, kg, vg = attn_decode(x, glob_lp, kg, vg, False)
                x, _ = self._mlp_sublayer(x, glob_lp)
                return x, (kl, vl, kg, vg)

            x, (kl, vl, kg, vg) = self._scan(
                superblock, x,
                (params["local_layers"], params["global_layers"],
                 cache["k_local"], cache["v_local"],
                 cache["k_global"], cache["v_global"]))
            cache["k_local"], cache["v_local"] = kl, vl
            cache["k_global"], cache["v_global"] = kg, vg
        elif at == "hybrid":
            def group(x, inp):
                glp, mstate, kc, vc = inp

                def mbody(x, inp2):
                    lp, st = inp2
                    h = L.rms_norm(x, lp["ln"]["gamma"], cfg.rms_eps)
                    out, st = M2.mamba2_decode(h, st, lp["mamba"], cfg)
                    return x + out, st
                x, mstate = self._scan(mbody, x, (glp, mstate))
                sp = params["shared"]
                h = L.apply_norm(x, sp["ln1"], cfg.rms_eps)
                q, k, v = L.qkv_proj(h, sp["attn"], cfg, positions)
                kc, vc = cache_lib.update_kv(kc, vc, k, v, pos,
                                             masked=self.seq_shard)
                kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
                vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
                out = cache_lib.decode_attend(q, kc, vc, pos)
                out = out.reshape(b, 1, cfg.num_heads * cfg.dh)
                x = x + jnp.einsum("bsD,Dh->bsh", out, sp["attn"]["wo"])
                x, _ = self._mlp_sublayer(x, sp)
                return x, (mstate, kc, vc)

            x, (mst, kn, vn) = self._scan(
                group, x,
                (params["layers"], cache["mamba"], cache["k"], cache["v"]))
            cache["mamba"], cache["k"], cache["v"] = mst, kn, vn
        elif at == "ssm":
            def pair(x, inp):
                lp, ms, ss = inp
                out, ms = XL.mlstm_decode(x, ms, lp["mlstm"], cfg)
                x = x + out
                out, ss = XL.slstm_decode(x, ss, lp["slstm"], cfg)
                return x + out, (ms, ss)
            x, (msn, ssn) = self._scan(
                pair, x, (params["layers"], cache["mlstm"], cache["slstm"]))
            cache["mlstm"], cache["slstm"] = msn, ssn
        elif at == "audio":
            def body(x, inp):
                lp, kc, vc, kx, vx = inp
                h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.qkv_proj(h, lp["self_attn"], cfg, positions)
                kc, vc = cache_lib.update_kv(kc, vc, k, v, pos)
                kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
                vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
                out = cache_lib.decode_attend(q, kc, vc, pos)
                out = out.reshape(b, 1, -1)
                x = x + jnp.einsum("bsD,Dh->bsh", out, lp["self_attn"]["wo"])
                h = L.apply_norm(x, lp["ln_x"], cfg.rms_eps)
                qx = jnp.einsum("bsh,hnd->bsnd", h, lp["cross_attn"]["wq"])
                ox = L.gqa_attend(qx, kx, vx, None).reshape(b, 1, -1)
                x = x + jnp.einsum("bsD,Dh->bsh", ox, lp["cross_attn"]["wo"])
                h = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
                return x + L.mlp_block(h, lp["mlp"], cfg.act), (kc, vc)

            x, (kn, vn) = self._scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["k_cross"], cache["v_cross"]))
            cache["k"], cache["v"] = kn, vn
        else:
            raise NotImplementedError(at)

        cache["pos"] = pos + 1
        x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
        logits = L.unembed(x, params["embed"], cfg)
        return logits, cache


def _fill_ring(ring_cache: Array, kv: Array, s: int) -> Array:
    """Place prefill KV (..., b, s, KV, dh) into a ring cache
    (..., b, W, KV, dh) honoring slot = pos % W layout."""
    W = ring_cache.shape[-3]
    if s <= W:
        pad = [(0, 0)] * kv.ndim
        pad[-3] = (0, W - s)
        return jnp.pad(kv, pad).astype(ring_cache.dtype)
    tail = kv[..., s - W:, :, :]                 # positions s-W .. s-1
    slots = ((s - W) + jnp.arange(W)) % W
    inv = jnp.argsort(slots)                     # slot -> tail index
    return jnp.take(tail, inv, axis=-3).astype(ring_cache.dtype)
