"""Lightweight logical-axis sharding (MaxText-style).

Models annotate activations with *logical* axis names; the launcher installs
a rule set mapping logical names to mesh axes. Outside a mesh context (CPU
tests) the annotations are no-ops.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default rules for the production meshes. "batch" shards over data (and
# pod, multi-pod); "model" carries tensor parallelism. Logical names used
# by the model code:
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "embed": None,            # activations keep embed replicated
    "heads": "model",
    "kv_heads": None,         # GQA kv heads (< model axis) replicated
    "qdh": None,
    "mlp": "model",           # d_ff
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "seq": None,
    "kv_seq": None,           # decode KV seq; set to "data" for seq-sharded decode
    "params_embed": "data",   # FSDP: shard d_model dim of params over data
    "params_vocab": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
}


def set_rules(rules: Optional[Dict[str, MeshAxes]], mesh: Optional[Mesh]):
    _state.rules = rules
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: Dict[str, MeshAxes], mesh: Mesh):
    prev = (get_rules(), get_mesh())
    set_rules(rules, mesh)
    try:
        yield
    finally:
        set_rules(*prev)


def resolve_spec(logical_axes: Sequence[Optional[str]],
                 rules: Optional[Dict[str, MeshAxes]] = None,
                 mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec under the active rules,
    dropping mesh axes that don't exist in the active mesh."""
    rules = rules if rules is not None else get_rules()
    mesh = mesh if mesh is not None else get_mesh()
    if rules is None:
        return P()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for name in logical_axes:
        spec = rules.get(name) if name is not None else None
        if spec is None:
            out.append(None)
            continue
        if isinstance(spec, str):
            out.append(spec if spec in mesh_axes else None)
        else:
            kept = tuple(a for a in spec if a in mesh_axes)
            out.append(kept if kept else None)
    return P(*out)


def shard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without rules."""
    rules, mesh = get_rules(), get_mesh()
    if rules is None or mesh is None:
        return x
    spec = resolve_spec(logical_axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical_axes: Optional[str],
                   rules: Optional[Dict[str, MeshAxes]] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical_axes, rules or DEFAULT_RULES, mesh))
