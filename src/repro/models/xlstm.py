"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential), alternating.

mLSTM recurrence per head (state C in R^{dh x dh}, normalizer n in R^{dh}):
    m_t = max(m_{t-1} + logsig(f~_t), i~_t)                 (stabilizer)
    C_t = exp(m_{t-1} + logf - m_t) C_{t-1} + exp(i~ - m_t) k_t v_t^T
    n_t = exp(m_{t-1} + logf - m_t) n_{t-1} + exp(i~ - m_t) k_t
    y_t = (q_t C_t) / max(|q_t . n_t|, 1)

Train path is a chunked parallel form: with La = cumsum(logf) and
u_j = i~_j - La_j the stabilizer is m_t = La_t + cummax(u)_t, so scores are
exp(u_j - w_t)(q.k) with w = cummax(u) — computed chunk-wise with a
rescaled state carry (exactly matching the sequential form; tested).

sLSTM has no parallel form; training runs lax.scan over time (the paper
itself ships custom kernels for this — on TPU the scan lowers to a fused
while loop).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.sharding import shard

Array = jax.Array


def _dims(cfg: ModelConfig):
    nh = cfg.ssm.num_heads or cfg.num_heads
    dh = cfg.d_model // nh
    return nh, dh


# ----------------------------------------------------------------- mLSTM

def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    h = cfg.d_model
    nh, dh = _dims(cfg)
    up = cfg.ssm.expand * h
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((h,), dtype),
        "w_up": dense_init(ks[0], h, (up,), dtype),       # -> x_m
        "w_z": dense_init(ks[1], h, (up,), dtype),        # gate branch
        "wq": dense_init(ks[2], up, (nh, dh), dtype),
        "wk": dense_init(ks[3], up, (nh, dh), dtype),
        "wv": dense_init(ks[4], up, (nh, dh), dtype),
        "w_if": dense_init(ks[5], up, (nh, 2), jnp.float32),
        "o_norm": jnp.ones((nh * dh,), dtype),
        "w_down": dense_init(ks[6], nh * dh, (h,), dtype),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    nh, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_qkvif(x: Array, p: dict, cfg: ModelConfig):
    nh, dh = _dims(cfg)
    xm = jnp.einsum("bsh,hu->bsu", x, p["w_up"])
    z = jnp.einsum("bsh,hu->bsu", x, p["w_z"])
    q = jnp.einsum("bsu,und->bsnd", xm, p["wq"]) / jnp.sqrt(dh)
    k = jnp.einsum("bsu,und->bsnd", xm, p["wk"]) / jnp.sqrt(dh)
    v = jnp.einsum("bsu,und->bsnd", xm, p["wv"])
    i_f = jnp.einsum("bsu,ung->bsng", xm.astype(jnp.float32), p["w_if"])
    i_t = i_f[..., 0]                                # pre-act input gate (log)
    logf = jax.nn.log_sigmoid(i_f[..., 1])           # (b,s,nh)
    return q, k, v, i_t, logf, z, xm


def mlstm_forward(x_in: Array, p: dict, cfg: ModelConfig) -> Array:
    y, _ = mlstm_forward_with_state(x_in, p, cfg)
    return y


def mlstm_forward_with_state(x_in: Array, p: dict, cfg: ModelConfig
                             ) -> Tuple[Array, dict]:
    """Chunked parallel mLSTM. x_in: (b, s, h). Also returns the final
    recurrent state in decode conventions (m = La_end + w_end)."""
    nh, dh = _dims(cfg)
    b, s_orig, h = x_in.shape
    Q = min(cfg.ssm.chunk, s_orig)
    s = ((s_orig + Q - 1) // Q) * Q
    if s != s_orig:  # pad; padded steps: f=1 (logf=0), i = -inf (no input)
        x_in_p = jnp.pad(x_in, ((0, 0), (0, s - s_orig), (0, 0)))
    else:
        x_in_p = x_in
    nc = s // Q

    x = rms_norm(x_in_p, p["norm"], cfg.rms_eps)
    q, k, v, i_t, logf, z, _ = _mlstm_qkvif(x, p, cfg)
    if s != s_orig:
        pad_mask = (jnp.arange(s) >= s_orig)[None, :, None]
        i_t = jnp.where(pad_mask, -1e30, i_t)
        logf = jnp.where(pad_mask, 0.0, logf)
    q = shard(q, "batch", "seq", "ssm_heads", None)
    k = shard(k, "batch", "seq", "ssm_heads", None)
    v = shard(v, "batch", "seq", "ssm_heads", None)

    La = jnp.cumsum(logf, axis=1)                    # (b,s,nh) inclusive
    u = i_t - La                                     # (b,s,nh)

    qc = q.reshape(b, nc, Q, nh, dh).astype(jnp.float32)
    kc = k.reshape(b, nc, Q, nh, dh).astype(jnp.float32)
    vc = v.reshape(b, nc, Q, nh, dh).astype(jnp.float32)
    uc = u.reshape(b, nc, Q, nh)
    Lac = La.reshape(b, nc, Q, nh)

    def chunk_step(carry, inp):
        C, n, w_prev = carry                         # state scaled by exp(-w_prev)
        qq, kk, vv, uu, ll = inp                     # (b,Q,nh,dh) / (b,Q,nh)
        w = jnp.maximum(jax.lax.cummax(uu, axis=1),
                        w_prev[:, None, :])          # (b,Q,nh) running max
        # intra-chunk
        qk = jnp.einsum("bind,bjnd->bijn", qq, kk)   # (b,Q,Q,n)
        sc = jnp.exp(uu[:, None, :, :] - w[:, :, None, :])
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        sc = jnp.where(tri, sc, 0.0)
        y_intra = jnp.einsum("bijn,bijn,bjnd->bind", qk, sc, vv)
        n_intra = jnp.einsum("bijn,bjnd->bind", sc, kk)
        # inter-chunk (state entering this chunk, scale w_prev)
        scale_in = jnp.exp(w_prev[:, None, :] - w)   # (b,Q,nh)
        y_inter = jnp.einsum("bind,bndp->binp", qq, C) * scale_in[..., None]
        n_inter = n[:, None] * scale_in[..., None]
        y_num = y_intra + y_inter
        n_tot = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("bind,bind->bin", qq, n_tot)),
                            1.0)
        y = y_num / denom[..., None]
        # update state to end-of-chunk scale
        w_end = w[:, -1, :]
        dec = jnp.exp(uu - w_end[:, None, :])        # (b,Q,nh)
        C_new = C * jnp.exp(w_prev - w_end)[:, :, None, None] \
            + jnp.einsum("bjn,bjnd,bjnp->bndp", dec, kk, vv)
        n_new = n * jnp.exp(w_prev - w_end)[:, :, None] \
            + jnp.einsum("bjn,bjnd->bnd", dec, kk)
        return (C_new, n_new, w_end), y

    C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    w0 = jnp.full((b, nh), -1e30, jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, uc, Lac))
    (Cf, nf, wf), ys = jax.lax.scan(chunk_step, (C0, n0, w0), inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh * dh)[:, :s_orig]
    y = y.astype(x_in.dtype)

    y = rms_norm(y, p["o_norm"], cfg.rms_eps)
    out = y * jax.nn.silu(z[:, :s_orig, : nh * dh])
    # padded steps leave (C, n, w) unchanged (f=1, i contribution 0), and
    # La is unchanged past s_orig (logf=0), so the handoff state is exact.
    final_state = {"C": Cf, "n": nf, "m": La[:, -1, :] + wf}
    return jnp.einsum("bsu,uh->bsh", out, p["w_down"]), final_state


def mlstm_decode(x_in: Array, state: dict, p: dict, cfg: ModelConfig
                 ) -> Tuple[Array, dict]:
    """Exact sequential step. x_in: (b, 1, h)."""
    nh, dh = _dims(cfg)
    b = x_in.shape[0]
    x = rms_norm(x_in, p["norm"], cfg.rms_eps)
    q, k, v, i_t, logf, z, _ = _mlstm_qkvif(x, p, cfg)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    i_t, logf = i_t[:, 0], logf[:, 0]                # (b,nh)

    m_prev, C, n = state["m"], state["C"], state["n"]
    m = jnp.maximum(m_prev + logf, i_t)
    fs = jnp.exp(m_prev + logf - m)                  # forget scale
    is_ = jnp.exp(i_t - m)                           # input scale
    C = C * fs[:, :, None, None] + is_[:, :, None, None] \
        * jnp.einsum("bnd,bnp->bndp", k, v)
    n = n * fs[:, :, None] + is_[:, :, None] * k
    num = jnp.einsum("bnd,bndp->bnp", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnd,bnd->bn", q, n)), 1.0)
    y = (num / den[..., None]).reshape(b, 1, nh * dh).astype(x_in.dtype)
    y = rms_norm(y, p["o_norm"], cfg.rms_eps)
    out = y * jax.nn.silu(z[..., : nh * dh])
    out = jnp.einsum("bsu,uh->bsh", out, p["w_down"])
    return out, {"C": C, "n": n, "m": m}


# ----------------------------------------------------------------- sLSTM

def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    h = cfg.d_model
    nh, dh = _dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.ones((h,), dtype),
        # gates i, f, z, o from input
        "w_gates": dense_init(ks[0], h, (nh, 4 * dh), jnp.float32),
        # block-diagonal recurrent weights per head
        "r_gates": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) /
                    jnp.sqrt(dh)).astype(jnp.float32),
        "w_down": dense_init(ks[2], h, (h,), dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    nh, dh = _dims(cfg)
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, nh, dh), -1e30)}


def _slstm_step(state, gates_x, p):
    c, n, hp, m_prev = state["c"], state["n"], state["h"], state["m"]
    g = gates_x + jnp.einsum("bnd,ndg->bng", hp, p["r_gates"])
    i_t, f_t, z_t, o_t = jnp.split(g, 4, axis=-1)    # (b,nh,dh) each
    logf = jax.nn.log_sigmoid(f_t)
    m = jnp.maximum(logf + m_prev, i_t)
    i_s = jnp.exp(i_t - m)
    f_s = jnp.exp(logf + m_prev - m)
    c = f_s * c + i_s * jnp.tanh(z_t)
    n = f_s * n + i_s
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m}


def slstm_forward(x_in: Array, p: dict, cfg: ModelConfig) -> Array:
    y, _ = slstm_forward_with_state(x_in, p, cfg)
    return y


def slstm_forward_with_state(x_in: Array, p: dict, cfg: ModelConfig
                             ) -> Tuple[Array, dict]:
    nh, dh = _dims(cfg)
    b, s, h = x_in.shape
    x = rms_norm(x_in, p["norm"], cfg.rms_eps)
    gates = jnp.einsum("bsh,hng->bsng", x.astype(jnp.float32), p["w_gates"])
    gates = gates.reshape(b, s, nh, 4, dh).reshape(b, s, nh, 4 * dh)

    def step(state, g_t):
        state = _slstm_step(state, g_t, p)
        return state, state["h"]

    state0 = init_slstm_state(cfg, b)
    state_f, hs = jax.lax.scan(step, state0, jnp.moveaxis(gates, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, h).astype(x_in.dtype)
    return jnp.einsum("bsh,hH->bsH", hs, p["w_down"]), state_f


def slstm_decode(x_in: Array, state: dict, p: dict, cfg: ModelConfig
                 ) -> Tuple[Array, dict]:
    nh, dh = _dims(cfg)
    b = x_in.shape[0]
    x = rms_norm(x_in, p["norm"], cfg.rms_eps)
    gates = jnp.einsum("bsh,hng->bsng", x.astype(jnp.float32),
                       p["w_gates"])[:, 0].reshape(b, nh, 4 * dh)
    state = _slstm_step(state, gates, p)
    h = state["h"].reshape(b, 1, cfg.d_model).astype(x_in.dtype)
    return jnp.einsum("bsh,hH->bsH", h, p["w_down"]), state
