"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Dispatch is rank-based (cumsum over one-hot expert assignment), avoiding
both the dense (tokens × E × C) dispatch einsum (whose FLOPs would swamp
the roofline) and data-dependent sorts. Expert weights are stacked on a
leading E dim and shard either expert-parallel over the "model" mesh axis
(E % model == 0) or tensor-parallel inside each expert (d_ff sharded).
Tokens dropped beyond capacity fall back to a zero update (residual keeps
them intact), matching Switch/Mesh-TF semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import get_mesh, get_rules, shard

Array = jax.Array


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    moe = cfg.moe
    h, f, E = cfg.d_model, moe.d_ff_expert, moe.num_experts
    ks = jax.random.split(key, 4)
    scale_h = 1.0 / jnp.sqrt(h)
    scale_f = 1.0 / jnp.sqrt(f)
    return {
        "router": dense_init(ks[0], h, (E,), jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, h, f)) * scale_h).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, h, f)) * scale_h).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, f, h)) * scale_f).astype(dtype),
    }


def _capacity(tokens: int, moe) -> int:
    c = int(tokens * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(c, moe.top_k)


def moe_block(x: Array, p: dict, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x: (b, s, h) -> (out (b, s, h), aux_loss scalar).

    aux_loss is the standard load-balance loss: E * sum_e f_e * p_e.
    """
    moe = cfg.moe
    b, s, h = x.shape
    E, K = moe.num_experts, moe.top_k
    T = b * s
    C = _capacity(T, moe)

    xf = x.reshape(T, h)
    gate_logits = jnp.einsum("th,he->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(gate_logits, axis=-1)                    # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)                          # (T, K)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce / K)

    # Rank each (token, k) slot within its expert via cumsum of one-hot.
    flat_e = top_e.reshape(T * K)                                    # slot -> expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (TK, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot                      # exclusive
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]  # (TK,)
    keep = rank < C

    # Scatter token features into the (E*C, h) expert buffer.
    slot = jnp.where(keep, flat_e * C + rank, E * C)                 # drop -> OOB
    xe_flat = jnp.repeat(xf, K, axis=0)                              # (TK, h)
    buf = jnp.zeros((E * C + 1, h), x.dtype).at[slot].set(xe_flat,
                                                          mode="drop")
    buf = buf[: E * C].reshape(E, C, h)
    buf = shard(buf, "experts", None, None)

    # Expert FFN (gated SiLU), stacked einsum over E.
    hdn = jnp.einsum("ech,ehf->ecf", buf, p["w1"])
    gte = jnp.einsum("ech,ehf->ecf", buf, p["wg"])
    hdn = jax.nn.silu(gte) * hdn
    hdn = shard(hdn, "experts", None, "expert_mlp")
    out_e = jnp.einsum("ecf,efh->ech", hdn, p["w2"])                 # (E, C, h)

    # Gather back and combine with gate probs.
    out_flat = out_e.reshape(E * C, h)
    gathered = jnp.where(keep[:, None],
                         jnp.take(out_flat, jnp.minimum(slot, E * C - 1),
                                  axis=0), 0.0)                      # (TK, h)
    combined = (gathered.reshape(T, K, h)
                * top_p[..., None].astype(x.dtype)).sum(axis=1)
    out = combined.reshape(b, s, h)
    return shard(out, "batch", "seq", "embed"), aux.astype(jnp.float32)


# --------------------------------------------------------------------------
# GShard-style LOCAL dispatch (§Perf variant): per-data-shard capacity +
# expert parallelism over the "model" axis via shard_map.
#
# The GSPMD moe_block above dispatches over the GLOBAL token axis: the
# (E, C_global, h) expert buffer cannot shard its capacity dim, so every
# model shard runs its experts over the *global* per-expert capacity and
# the data axis sits idle during the expert FFN — per-device expert FLOPs
# are dp× too high (the qwen3 train_4k roofline shows exactly this).
#
# Here each data shard dispatches its LOCAL tokens with local capacity
# C_loc = T_loc·K·cf/E (standard GShard/Switch local-capacity semantics),
# each model shard keeps only its E/ep expert range (token activations are
# replicated over "model", so routing needs no all-to-all), and partial
# expert outputs are combined with one psum over "model". Per-device
# expert FLOPs drop by dp×; the dense (tokens × E) dispatch bookkeeping
# shrinks by dp× as well.
# --------------------------------------------------------------------------

def _moe_local(xf: Array, router: Array, w1: Array, wg: Array, w2: Array,
               cfg: ModelConfig, ep_axis: str | None,
               dp_axes: Tuple[str, ...],
               tp_axis: str | None = None) -> Tuple[Array, Array]:
    """Per-shard MoE: xf (T_loc, h) local tokens; w* (E_loc, ...) local
    experts (expert-parallel) or (E, h, f_loc) f-sharded slices
    (tensor-parallel inside each expert). Runs inside shard_map."""
    moe = cfg.moe
    E, K = moe.num_experts, moe.top_k
    T, h = xf.shape
    C = _capacity(T, moe)
    E_loc = w1.shape[0]
    off = (jax.lax.axis_index(ep_axis) * E_loc) if ep_axis else 0

    gate_logits = jnp.einsum("th,he->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(gate_logits, axis=-1)                   # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce / K)

    flat_e = top_e.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]

    e_loc = flat_e - off                                    # local expert id
    mine = (e_loc >= 0) & (e_loc < E_loc)
    keep = mine & (rank < C)
    slot = jnp.where(keep, e_loc * C + rank, E_loc * C)     # drop -> OOB
    xe_flat = jnp.repeat(xf, K, axis=0)
    buf = jnp.zeros((E_loc * C + 1, h), xf.dtype).at[slot].set(
        xe_flat, mode="drop")
    buf = buf[: E_loc * C].reshape(E_loc, C, h)

    hdn = jnp.einsum("ech,ehf->ecf", buf, w1)
    gte = jnp.einsum("ech,ehf->ecf", buf, wg)
    hdn = jax.nn.silu(gte) * hdn
    out_e = jnp.einsum("ecf,efh->ech", hdn, w2)

    out_flat = out_e.reshape(E_loc * C, h)
    gathered = jnp.where(keep[:, None],
                         jnp.take(out_flat,
                                  jnp.minimum(slot, E_loc * C - 1),
                                  axis=0), 0.0)
    combined = (gathered.reshape(T, K, h)
                * top_p[..., None].astype(xf.dtype)).sum(axis=1)
    # ep: shards hold disjoint expert ranges; tp: shards hold disjoint
    # d_ff slices (partial w2 contractions) — either way one psum combines
    psum_axis = ep_axis or tp_axis
    if psum_axis:
        combined = jax.lax.psum(combined, psum_axis)
        aux = jax.lax.pmean(aux, psum_axis)  # identical already; keeps rep
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return combined, aux.astype(jnp.float32)


def moe_block_sharded(x: Array, p: dict, cfg: ModelConfig
                      ) -> Tuple[Array, Array]:
    """shard_map local-dispatch MoE. Falls back to the GSPMD moe_block
    when no mesh is active (CPU tests) or experts don't divide the mesh."""
    mesh = get_mesh()
    rules = get_rules() or {}
    if mesh is None:
        return moe_block(x, p, cfg)
    ep_axis = rules.get("experts")
    if isinstance(ep_axis, tuple):
        ep_axis = ep_axis[0] if ep_axis else None
    tp_axis = None
    if ep_axis is not None and (ep_axis not in mesh.axis_names or
                                cfg.moe.num_experts % mesh.shape[ep_axis]):
        # experts don't divide the axis (e.g. granite's 40 on 16):
        # tensor-parallel the d_ff dim inside each expert instead
        if (ep_axis in mesh.axis_names and
                cfg.moe.d_ff_expert % mesh.shape[ep_axis] == 0):
            tp_axis = ep_axis
        ep_axis = None
    batch_rule = rules.get("batch") or ()
    if isinstance(batch_rule, str):
        batch_rule = (batch_rule,)
    dp_axes = tuple(a for a in batch_rule if a in mesh.axis_names)

    b, s, h = x.shape
    dp_n = 1
    for a in dp_axes:
        dp_n *= mesh.shape[a]
    if b % max(dp_n, 1):
        dp_axes, dp_n = (), 1

    def local(x_loc, router, w1, wg, w2):
        bl = x_loc.shape[0]
        xf = x_loc.reshape(bl * s, h)
        out, aux = _moe_local(xf, router, w1, wg, w2, cfg, ep_axis,
                              dp_axes, tp_axis)
        return out.reshape(bl, s, h), aux

    if ep_axis:
        w_specs = (P(ep_axis), P(ep_axis), P(ep_axis))
    elif tp_axis:   # (E, h, f) f-sharded; (E, f, h) f-sharded
        w_specs = (P(None, None, tp_axis), P(None, None, tp_axis),
                   P(None, tp_axis, None))
    else:
        w_specs = (P(), P(), P())
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp_axes if dp_axes else None, None, None), P(),
                  *w_specs),
        out_specs=(P(dp_axes if dp_axes else None, None, None), P()),
        check_rep=False)
    out, aux = fn(x, p["router"], p["w1"], p["wg"], p["w2"])
    return shard(out, "batch", "seq", "embed"), aux
