"""Sequence-parallel decode attention via shard_map (beyond-paper
optimization; see EXPERIMENTS.md §Perf).

The GSPMD baseline for seq-sharded KV decode has two costs the partitioner
cannot remove:
  1. masked one-hot cache writes rewrite the WHOLE cache every step
     (memory term ~3x the minimum);
  2. softmax over the sharded seq dim emits multiple all-reduces of
     full score tensors.

Manual SPMD fixes both: each shard holds a contiguous KV slice, computes a
partial flash-decode (m, l, num) over its slice, and combines with two
tiny psums; the new token's KV is written ONLY by the owning shard
(dynamic-slice write of one slot). Exactness is tested against the dense
reference in tests/test_seq_parallel.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.sharding import get_mesh

Array = jax.Array
NEG_INF = -1e30


def _partial_attend(qg, kc, vc, slot_valid):
    """qg: (b,KV,g,dh); kc/vc: (b,S_loc,KV,dh); slot_valid: (S_loc,) bool.
    Returns partial (num (b,KV,g,dh), den (b,KV,g,1), m (b,KV,g,1))."""
    dh = qg.shape[-1]
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) / jnp.sqrt(dh)
    scores = jnp.where(slot_valid[None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    den = jnp.sum(e, axis=-1, keepdims=True)
    num = jnp.einsum("bkgs,bskd->bkgd", e, vc.astype(jnp.float32))
    return num, den, m


def seq_sharded_decode_attend(q: Array, k_cache: Array, v_cache: Array,
                              pos: Array, axis: str = "data") -> Array:
    """Exact single-token GQA attention over a cache whose SEQ dim is
    sharded over `axis`. q: (b,1,H,dh); k/v: (b,S,KV,dh) [S sharded].
    Returns (b,1,H,dh), replicated."""
    mesh = get_mesh()
    b, _, H, dh = q.shape
    KV = k_cache.shape[2]
    g = H // KV

    def local(q, kc, vc, pos):
        idx = jax.lax.axis_index(axis)
        S_loc = kc.shape[1]
        slot = idx * S_loc + jnp.arange(S_loc)
        qg = q.reshape(b, KV, g, dh)
        num, den, m = _partial_attend(qg, kc, vc, slot <= pos)
        # two-pass exact combine across shards
        m_star = jax.lax.pmax(m, axis)
        scale = jnp.exp(m - m_star)
        num = jax.lax.psum(num * scale, axis)
        den = jax.lax.psum(den * scale, axis)
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape(b, 1, H, dh).astype(q.dtype)

    spec_kv = P(None, axis, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(P(), spec_kv, spec_kv, P()),
                     out_specs=P(),
                     check_rep=False)(q, k_cache, v_cache, pos)


def _partial_attend_chunk(qg, kc, vc, valid):
    """qg: (b,w,KV,g,dh); kc/vc: (b,S_loc,KV,dh); valid: (w,S_loc) bool.
    Returns partial (num (b,KV,g,w,dh), den (b,KV,g,w,1), m (...,1))."""
    dh = qg.shape[-1]
    scores = jnp.einsum("bwkgd,bskd->bkgws", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) / jnp.sqrt(dh)
    scores = jnp.where(valid[None, None, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    den = jnp.sum(e, axis=-1, keepdims=True)
    num = jnp.einsum("bkgws,bskd->bkgwd", e, vc.astype(jnp.float32))
    return num, den, m


def seq_sharded_prefill_chunk_attend(q: Array, k_cache: Array,
                                     v_cache: Array, k_new: Array,
                                     v_new: Array, p0: int,
                                     axis: str = "data") -> Array:
    """Exact chunked-prefill attention over a seq-sharded cache: the
    (b,w) query chunk attends over cache tokens [0, p0) — each shard's
    contiguous slice contributes a partial flash reduction — plus the
    chunk's own causal block (replicated, folded in AFTER the psum so
    it is counted exactly once).  Same two-psum combine as
    ``seq_sharded_decode_attend``; ``p0`` is static (one trace per
    (p0, w) pair, like Model.prefill_chunk).  q: (b,w,H,dh);
    k/v_cache: (b,S,KV,dh) [S sharded]; k/v_new: (b,w,KV,dh) the
    chunk's exact keys/values.  Returns (b,w,H,dh), replicated."""
    mesh = get_mesh()
    b, w, H, dh = q.shape
    KV = k_cache.shape[2]
    g = H // KV

    def local(q, kc, vc, kn, vn):
        idx = jax.lax.axis_index(axis)
        S_loc = kc.shape[1]
        slot = idx * S_loc + jnp.arange(S_loc)
        qg = q.reshape(b, w, KV, g, dh)
        # sharded prefix [0, p0): every chunk row sees the same keys
        pre_valid = jnp.broadcast_to(slot[None, :] < p0, (w, S_loc))
        num1, den1, m1 = _partial_attend_chunk(qg, kc, vc, pre_valid)
        m_star = jax.lax.pmax(m1, axis)
        scale = jnp.exp(m1 - m_star)
        num1 = jax.lax.psum(num1 * scale, axis)
        den1 = jax.lax.psum(den1 * scale, axis)
        # the chunk's own causal block, exact (un-downcast) K/V
        causal = (jnp.arange(w)[:, None] >= jnp.arange(w)[None, :])
        num2, den2, m2 = _partial_attend_chunk(qg, kn, vn, causal)
        m_all = jnp.maximum(m_star, m2)
        s1, s2 = jnp.exp(m_star - m_all), jnp.exp(m2 - m_all)
        out = (num1 * s1 + num2 * s2) / jnp.maximum(
            den1 * s1 + den2 * s2, 1e-30)
        # (b,KV,g,w,dh) -> (b,w,KV*g,dh)
        out = jnp.moveaxis(out, 3, 1).reshape(b, w, H, dh)
        return out.astype(q.dtype)

    spec_kv = P(None, axis, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(P(), spec_kv, spec_kv, P(), P()),
                     out_specs=P(),
                     check_rep=False)(q, k_cache, v_cache, k_new, v_new)


def seq_sharded_update_kv_chunk(k_cache: Array, v_cache: Array,
                                k_new: Array, v_new: Array, p0: int,
                                axis: str = "data"
                                ) -> Tuple[Array, Array]:
    """Write a (b,w,KV,dh) chunk at global positions [p0, p0 + w) into
    seq-sharded caches.  Each shard read-modify-writes one w-wide
    window of its own slice; rows of the window whose global position
    falls outside the chunk keep their current values, so a chunk
    straddling a shard boundary lands exactly once with no cross-shard
    traffic.  Requires w <= S/num_shards (the engine's chunk widths
    are far below per-shard slices in any realistic topology)."""
    mesh = get_mesh()
    w = k_new.shape[1]

    def local(kc, vc, kn, vn):
        idx = jax.lax.axis_index(axis)
        S_loc = kc.shape[1]
        if w > S_loc:
            raise ValueError(
                f"chunk width {w} exceeds the {S_loc}-token per-shard "
                f"cache slice; lower prefill_chunk or the shard count")
        lp = jnp.clip(p0 - idx * S_loc, 0, S_loc - w)
        gpos = idx * S_loc + lp + jnp.arange(w)   # window's global rows
        j = gpos - p0                             # chunk row per window row
        ok = (j >= 0) & (j < w)
        jc = jnp.clip(j, 0, w - 1)
        cur_k = jax.lax.dynamic_slice(
            kc, (0, lp, 0, 0), (kc.shape[0], w) + kc.shape[2:])
        cur_v = jax.lax.dynamic_slice(
            vc, (0, lp, 0, 0), (vc.shape[0], w) + vc.shape[2:])
        sel = ok[None, :, None, None]
        kw = jnp.where(sel, jnp.take(kn, jc, axis=1).astype(kc.dtype),
                       cur_k)
        vw = jnp.where(sel, jnp.take(vn, jc, axis=1).astype(vc.dtype),
                       cur_v)
        kc = jax.lax.dynamic_update_slice(kc, kw, (0, lp, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vw, (0, lp, 0, 0))
        return kc, vc

    spec_kv = P(None, axis, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(spec_kv, spec_kv, P(), P()),
                     out_specs=(spec_kv, spec_kv),
                     check_rep=False)(k_cache, v_cache, k_new, v_new)


def seq_sharded_update_kv(k_cache: Array, v_cache: Array, k_new: Array,
                          v_new: Array, pos: Array, axis: str = "data"
                          ) -> Tuple[Array, Array]:
    """Write the (b,1,KV,dh) new entries at global position `pos` into
    seq-sharded caches — only the owning shard writes one slot (no
    whole-cache rewrite)."""
    mesh = get_mesh()

    def local(kc, vc, k_new, v_new, pos):
        idx = jax.lax.axis_index(axis)
        S_loc = kc.shape[1]
        local_pos = pos - idx * S_loc
        in_range = (local_pos >= 0) & (local_pos < S_loc)
        lp = jnp.clip(local_pos, 0, S_loc - 1)
        cur_k = jax.lax.dynamic_slice(kc, (0, lp, 0, 0), k_new.shape)
        cur_v = jax.lax.dynamic_slice(vc, (0, lp, 0, 0), v_new.shape)
        kw = jnp.where(in_range, k_new.astype(kc.dtype), cur_k)
        vw = jnp.where(in_range, v_new.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice(kc, kw, (0, lp, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vw, (0, lp, 0, 0))
        return kc, vc

    spec_kv = P(None, axis, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(spec_kv, spec_kv, P(), P(), P()),
                     out_specs=(spec_kv, spec_kv),
                     check_rep=False)(k_cache, v_cache, k_new, v_new, pos)
