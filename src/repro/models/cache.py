"""KV cache / recurrent-state pytrees and decode-time cache ops."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import NEG_INF
from repro.models.sharding import shard

Array = jax.Array


def update_kv(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array,
              pos: Array, ring: bool = False,
              masked: bool = False) -> Tuple[Array, Array]:
    """Write (b, 1, KV, dh) new entries at position `pos` (ring: pos % S).

    masked=True uses a one-hot where-write instead of dynamic_update_slice:
    required when the cache SEQ dim is sharded across devices (GSPMD
    partitions elementwise selects perfectly, while a dynamic slice on a
    sharded dim may force a gather). Costs a full cache rewrite — the
    shard_map one-shard write in models/seq_parallel.py removes that.
    """
    S = k_cache.shape[1]
    idx = pos % S if ring else pos
    if masked:
        hot = (jnp.arange(S) == idx)[None, :, None, None]
        k_cache = jnp.where(hot, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(hot, v_new.astype(v_cache.dtype), v_cache)
        return k_cache, v_cache
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, idx, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, idx, 0, 0))
    return k_cache, v_cache


def decode_attend(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                  ring: bool = False,
                  kv_start: Optional[Array] = None) -> Array:
    """Single-token GQA attention over a cache.

    q: (b, 1, H, dh); k/v_cache: (b, S, KV, dh); pos: current position.
    ring=True -> all slots older than S are valid (sliding window cache).
    kv_start: optional (b,) first valid slot per row — slots before it
    (a left-padded ragged prefill) are masked to zero weight.
    Returns (b, 1, H, dh).
    """
    b, S, KV, dh = k_cache.shape
    H = q.shape[2]
    g = H // KV
    qg = q.reshape(b, KV, g, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    slot = jnp.arange(S)
    valid = jnp.ones((S,), bool) if ring else (slot <= pos)
    if ring:
        valid = (slot <= pos)  # until the ring wraps, later slots are empty
        valid = valid | (pos >= S)
    if kv_start is not None:
        valid = valid[None, :] & (slot[None, :] >= kv_start[:, None])
        valid = valid[:, None, None, :]        # (b, 1, 1, S)
    else:
        valid = valid[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, H, dh)


def broadcast_slots(one_cache, num_slots: int):
    """Stack a b=1 cache pytree into a (num_slots, ...) slot pytree
    (bootstrap for iteration-level batching engines)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (num_slots,) + a.shape).copy(),
        one_cache)


def splice_slot(slots_cache, one_cache, slot: int):
    """Write a b=1 cache pytree into slot `slot` of a stacked slot
    pytree (iteration-level admission on the resident path)."""
    def put(dst, src):
        return jax.lax.dynamic_update_slice(
            dst, src[None].astype(dst.dtype),
            (slot,) + (0,) * (dst.ndim - 1))
    return jax.tree.map(put, slots_cache, one_cache)


def init_kv(batch: int, S: int, KV: int, dh: int, dtype,
            n_layers: Optional[int] = None) -> Tuple[Array, Array]:
    shape = (batch, S, KV, dh) if n_layers is None else (n_layers, batch, S, KV, dh)
    k = jnp.zeros(shape, dtype)
    return k, jnp.zeros(shape, dtype)
