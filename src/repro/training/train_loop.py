"""Training step + loop: CE loss (vocab-padding masked) + MoE aux loss,
AdamW, runs under an optional mesh with logical-axis shardings."""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import Model
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state)

PyTree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean CE over non-padding labels (-100 = ignore)."""
    mask = labels >= 0
    labels = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    aux_weight: float = 0.01):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch["tokens"],
                                    batch.get("extra"))
        ce = cross_entropy(logits, batch["labels"], cfg.padded_vocab)
        return ce + aux_weight * aux, (ce, aux)

    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, info = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **info}
        return params, opt_state, metrics

    return train_step


def train(model: Model, params, data_iter, steps: int,
          opt_cfg: Optional[AdamWConfig] = None,
          log_every: int = 10, jit: bool = True) -> Dict[str, list]:
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    opt_state = init_opt_state(params)
    step_fn = make_train_step(model, opt_cfg)
    if jit:
        step_fn = jax.jit(step_fn)
    history = {"loss": [], "step_time": []}
    for step in range(steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        history["loss"].append(loss)
        history["step_time"].append(dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
    return history, params, opt_state
