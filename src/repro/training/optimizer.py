"""AdamW + gradient clipping + LR schedules, raw JAX (no optax offline)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig) -> Tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
