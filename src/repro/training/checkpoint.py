"""Msgpack checkpointing (orbax is not available offline). Arrays are
stored as (dtype, shape, bytes) triples; the pytree structure is preserved
for dicts/lists/scalars."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any
_ARR = "__arr__"


def _pack(obj):
    if isinstance(obj, (jax.Array, np.ndarray)):
        a = np.asarray(obj)
        if a.dtype == jnp.bfloat16:
            return {_ARR: ["bfloat16", list(a.shape),
                           a.view(np.uint16).tobytes()]}
        return {_ARR: [a.dtype.str, list(a.shape), a.tobytes()]}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_pack(v) for v in obj]
    return obj


def _unpack(obj):
    if isinstance(obj, dict):
        if _ARR in obj:
            dt, shape, buf = obj[_ARR]
            if dt == "bfloat16":
                a = np.frombuffer(buf, np.uint16).reshape(shape)
                return jnp.asarray(a.view(jnp.bfloat16))
            return jnp.asarray(np.frombuffer(buf, np.dtype(dt)).reshape(shape))
        return {k: _unpack(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unpack(v) for v in obj]
    return obj


def save(path: str, tree: PyTree) -> None:
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(tree), use_bin_type=True))
    os.replace(tmp, path)


def load(path: str) -> PyTree:
    with open(path, "rb") as f:
        return _unpack(msgpack.unpackb(f.read(), raw=False,
                                       strict_map_key=False))
