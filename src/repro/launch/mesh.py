"""Production meshes. A function, not a module constant, so importing
this module never touches jax device state.

``MeshConfig`` is the serving-facing half: a frozen (data, model)
topology declaration that ``EngineConfig(mesh=...)`` carries through
scheduler → runtime → store (docs/scaling.md).  The *model* axis is
what the KVPR pipeline shards over — each model-axis shard owns a KV
head-slice and a 1/model share of the host link — while the *data*
axis replicates whole engines (the router tier's concern).  It stays a
pure description until ``build()`` is called, so configs can be
constructed, validated, and hashed without touching jax device state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Declarative (data, model) mesh for the serving engine.

    ``model`` is the tensor-parallel degree: KV heads, per-shard
    transfer streams, and the scheduler's per-shard split all partition
    across it.  ``model = 1`` (the default) is the unsharded path and
    is required to behave bit-identically to a mesh-free engine.
    ``data`` is carried for sequence-parallel prefill and replica
    placement; the single-process engine requires shards to fit the
    KV-head count but does not require physical devices for the data
    axis (the data-plane shards are streams, not devices — see
    docs/scaling.md for what does need an emulated device mesh).
    """
    model: int = 1
    data: int = 1

    def validate(self) -> "MeshConfig":
        if self.model < 1:
            raise ValueError(f"mesh model axis must be >= 1, got "
                             f"{self.model}")
        if self.data < 1:
            raise ValueError(f"mesh data axis must be >= 1, got "
                             f"{self.data}")
        return self

    @property
    def size(self) -> int:
        return self.model * self.data

    def build(self):
        """Materialize a ``jax.Mesh`` with (data, model) axes.  Needs
        ``jax.device_count() >= size`` — on CPU that means the
        ``--xla_force_host_platform_device_count`` flag was set before
        jax initialized (tests/conftest.py's ``xla_device_count``
        helper composes it)."""
        n = jax.device_count()
        if n < self.size:
            raise ValueError(
                f"mesh ({self.data} data x {self.model} model) needs "
                f"{self.size} devices, have {n}")
        return jax.make_mesh((self.data, self.model), ("data", "model"))


def resolve_mesh(mesh: Union[None, str, MeshConfig]) -> MeshConfig:
    """Normalize ``EngineConfig.mesh``: None -> 1x1, "auto" -> every
    visible device on the model axis (the decode-dominant choice per
    ``launch/autoshard.py`` finding 2), or a MeshConfig passed through
    validated."""
    if mesh is None:
        return MeshConfig()
    if isinstance(mesh, str):
        if mesh != "auto":
            raise ValueError(f"mesh must be None, 'auto' or a "
                             f"MeshConfig, got {mesh!r}")
        return MeshConfig(model=max(1, jax.device_count()), data=1)
    if not isinstance(mesh, MeshConfig):
        raise ValueError(f"mesh must be None, 'auto' or a MeshConfig, "
                         f"got {type(mesh).__name__}")
    return mesh.validate()


def place_tp_decode_params(cfg, params, mesh):
    """Finding-2 decode placement (``launch/autoshard.py``): params
    stay tensor-parallel over the "model" axis with FSDP off, so no
    weight regather happens per token step.  ``mesh`` is a built
    ``jax.Mesh`` (``MeshConfig.build()``); the strategy flip is scoped
    — the process-global sharding strategy is restored on exit.
    Returns the params tree device_put onto its TP shardings."""
    from repro.launch import shardings as SH
    prev = SH.get_strategy()
    SH.set_strategy(
        tp="model", fsdp=(),
        dp=tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    try:
        shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        shardings = SH.param_shardings(cfg, shapes, mesh)
        return jax.tree_util.tree_map(jax.device_put, params, shardings)
    finally:
        SH.set_strategy(**prev)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
