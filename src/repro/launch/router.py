"""Router launcher: the multi-replica serving tier over N in-process
``LLMEngine`` replicas (serving.router — see docs/serving.md).

    PYTHONPATH=src python -m repro.launch.router --arch tinyllama-1.1b \
        --replicas 2 --policy prefix --requests 12 --shared-prefix 12
    PYTHONPATH=src python -m repro.launch.router --arch tinyllama-1.1b \
        --policy round_robin --backend offload
    PYTHONPATH=src python -m repro.launch.router --smoke
        # CI round-trip: 2 replicas, mixed-priority shared-prefix
        # batch; asserts token identity vs the single-engine
        # reference, warm hits > 0, and preempt-resume identity

Always uses the reduced (smoke) config on this CPU container, like
``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.cost_model import TPU_V5E
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, LLMEngine, PrefixCacheConfig,
                           Request, SamplingParams)
from repro.serving.router import RouterConfig, RouterEngine


def _shared_prefix_requests(cfg, rng, n: int, shared: int, tail: int,
                            families: int = 2):
    """n requests over ``families`` shared-prefix families: family f's
    requests all start with the same ``shared``-token prefix and differ
    in a ``tail``-token suffix — the RAG/system-prompt workload prefix
    placement exists for."""
    bases = [rng.integers(1, cfg.vocab_size, shared).astype(np.int32)
             for _ in range(families)]
    reqs = []
    for i in range(n):
        base = bases[i % families]
        suffix = rng.integers(1, cfg.vocab_size, tail).astype(np.int32)
        reqs.append(Request(uid=i,
                            prompt=np.concatenate([base, suffix]),
                            priority=i % 3,
                            slo=("interactive", "standard",
                                 "batch")[i % 3]))
    return reqs


def run_smoke() -> None:
    """CI round-trip for the router tier: 2 replicas over a
    mixed-priority shared-prefix batch.  Asserts

      - routed outputs token-identical to the single-engine reference
        (any placement, any batch composition — the sampling-stream
        invariant one level up);
      - warm-prefix hits > 0 (placement kept at least one family on a
        warm replica);
      - preempt-resume identity: a preempted + resumed request emits
        exactly the tokens of its uninterrupted reference run;
      - per-request timing populated (queue_wait / ttft / tpot).
    """
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sched = Scheduler(TPU_V5E)
    reqs = _shared_prefix_requests(cfg, rng, n=8, shared=12, tail=3)
    sps = [SamplingParams(max_tokens=4) if i % 2 == 0 else
           SamplingParams(max_tokens=4, temperature=0.8, seed=i)
           for i in range(len(reqs))]

    with LLMEngine.from_config(model, params, EngineConfig(),
                               scheduler=sched) as eng:
        refs = [eng.generate([r], [sp])[0]
                for r, sp in zip(reqs, sps)]

    ec = EngineConfig(prefix_cache=PrefixCacheConfig(min_prefix=4))
    with RouterEngine(model, params, ec,
                      RouterConfig(replicas=2, policy="prefix"),
                      scheduler=sched) as router:
        t0 = time.perf_counter()
        # two waves: the first request of each family lands cold and
        # warms its replica's prefix cache; the second wave's placement
        # must then route each family to its warm replica (warm hits)
        outs = router.generate(reqs[:2], sps[:2])
        outs += router.generate(reqs[2:], sps[2:])
        dt = time.perf_counter() - t0
        st = router.stats()
    for r, o, ref in zip(reqs, outs, refs):
        assert list(o.tokens) == list(ref.tokens), \
            (r.uid, list(o.tokens), list(ref.tokens))
        assert o.finish_reason == ref.finish_reason
        assert o.t_enqueue > 0 and o.t_finish >= o.t_first_token > 0
        assert o.queue_wait >= 0 and o.ttft > 0
    assert st.warm_hit_rate > 0, "no warm-prefix hits under placement"
    n_tok = sum(len(o.tokens) for o in outs)
    print(f"  routed {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s "
          f"across 2 replicas: token-identical to single-engine "
          f"reference ok")
    print(f"  warm-prefix: hit_rate={st.warm_hit_rate:.2f} "
          f"warm_tokens={st.warm_tokens} "
          f"placement={[r.dispatched for r in st.replicas]}")

    # preempt-resume identity: run a long low-priority decode on a
    # 1-replica router, then submit a high-priority request that
    # preempts it; the stitched output must equal the uninterrupted
    # reference
    long_req = Request(uid=100, prompt=rng.integers(
        1, cfg.vocab_size, 10).astype(np.int32), priority=0)
    hi_req = Request(uid=101, prompt=rng.integers(
        1, cfg.vocab_size, 8).astype(np.int32), priority=5)
    long_sp = SamplingParams(max_tokens=24, temperature=0.6, seed=9)
    hi_sp = SamplingParams(max_tokens=4)
    with LLMEngine.from_config(model, params, EngineConfig(),
                               scheduler=sched) as eng:
        ref_long = eng.generate([long_req], [long_sp])[0]
    with RouterEngine(model, params, ec,
                      RouterConfig(replicas=1, policy="least_loaded",
                                   max_batch=1),
                      scheduler=sched) as router:
        u0 = router.submit(long_req, long_sp)
        while router.stats().replicas[0].running == 0:
            time.sleep(0.005)       # let the decode start
        u1 = router.submit(hi_req, hi_sp)
        out_long = router.wait(u0)
        router.wait(u1)
    assert list(out_long.tokens) == list(ref_long.tokens), \
        (out_long.preemptions, list(out_long.tokens),
         list(ref_long.tokens))
    assert out_long.preemptions >= 1, \
        "high-priority arrival failed to preempt the running decode"
    print(f"  preemption: resumed after {out_long.preemptions} "
          f"preempt(s), stitched tokens identical to uninterrupted "
          f"reference ok")
    print("router --smoke: all checks passed")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="prefix",
                    choices=["prefix", "round_robin", "least_loaded"])
    ap.add_argument("--backend", default="resident",
                    choices=["resident", "offload"])
    ap.add_argument("--batching", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt", type=int, default=24,
                    help="total prompt length per request")
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="tokens of each request's prompt shared with "
                         "its family")
    ap.add_argument("--families", type=int, default=2,
                    help="number of shared-prefix families")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--no-preemption", action="store_true")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the per-replica shared-prefix cache "
                         "(prefix placement degrades to least-loaded)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI round-trip (identity + warm hits + "
                         "preemption)")
    args = ap.parse_args(argv)

    if args.smoke:
        run_smoke()
        return
    if args.arch is None:
        ap.error("--arch is required (unless --smoke)")

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    tail = max(args.prompt - args.shared_prefix, 1)
    reqs = _shared_prefix_requests(cfg, rng, args.requests,
                                   args.shared_prefix, tail,
                                   families=args.families)
    ec = EngineConfig(
        backend=args.backend, batching=args.batching,
        max_len=args.prompt + args.gen + 8, seed=args.seed,
        prefix_cache=(None if args.no_prefix_cache
                      else PrefixCacheConfig(min_prefix=4)))
    rc = RouterConfig(replicas=args.replicas, policy=args.policy,
                      max_batch=args.max_batch,
                      preemption=not args.no_preemption)
    sched = Scheduler(TPU_V5E)
    with RouterEngine(model, params, ec, rc,
                      scheduler=sched) as router:
        t0 = time.perf_counter()
        outs = router.generate(reqs,
                               SamplingParams(max_tokens=args.gen))
        dt = time.perf_counter() - t0
        st = router.stats()
        classes = router.per_class(outs)

    total = sum(len(o.tokens) for o in outs)
    print(f"{args.arch} router[{args.policy} x{args.replicas}] "
          f"[{args.backend}/{args.batching}]: {len(reqs)} requests, "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)")
    print(f"  warm-prefix: hit_rate={st.warm_hit_rate:.2f} "
          f"warm_tokens={st.warm_tokens}  preemptions="
          f"{st.preemptions}  deadline_drops={st.deadline_drops}")
    for rs in st.replicas:
        print(f"  replica {rs.index}: dispatched={rs.dispatched} "
              f"batches={rs.batches} preempted={rs.preemptions}")
    waits = sorted(o.queue_wait for o in outs)
    ttfts = sorted(o.ttft for o in outs)
    print(f"  queue_wait p50={waits[len(waits) // 2] * 1e3:.1f}ms "
          f"max={waits[-1] * 1e3:.1f}ms   "
          f"ttft p50={ttfts[len(ttfts) // 2] * 1e3:.1f}ms "
          f"max={ttfts[-1] * 1e3:.1f}ms")
    for name, row in classes.items():
        print(f"  slo[{name}]: n={row['n']} "
              f"attained={row['attained']:.2f} "
              f"mean_ttft={row['mean_ttft_s'] * 1e3:.1f}ms "
              f"mean_tpot={row['mean_tpot_s'] * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
