"""Roofline-term extraction from AOT-compiled dry-run artifacts.

  compute term    = HLO_FLOPs(per device) / peak_FLOP/s
  memory term     = HLO_bytes(per device) / HBM_bw
  collective term = collective_bytes(per device) / link_bw

cost_analysis() gives FLOPs/bytes for one device's partitioned program;
collective bytes are parsed from the compiled HLO text (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute operand
sizes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.cost_model import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.:  %ag = bf16[2,1024,128]{2,1,0:T(8,128)} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" +
    "|".join(_COLLECTIVES) + r")[-a-z]*\(")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes of every collective op, by op kind."""
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[kind] += nbytes
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_detail: Dict[str, float]
    per_device_memory: Optional[dict] = None
    model_flops: float = 0.0      # 6·N·D (or analogue) / device

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / V5E_PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / V5E_HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / V5E_ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops_per_dev if self.flops_per_dev \
            else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_per_dev": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "coll_detail": {k: v for k, v in self.coll_detail.items()
                            if k != "_counts"},
            "coll_counts": self.coll_detail.get("_counts", {}),
            "memory": self.per_device_memory,
        }


def from_compiled(compiled, arch: str, shape: str, mesh_name: str,
                  model_flops_per_dev: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = parse_collective_bytes(hlo)
    coll_total = sum(v for k, v in coll.items() if k != "_counts")
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        pass
    return Roofline(arch, shape, mesh_name, flops, nbytes, coll_total,
                    coll, mem, model_flops_per_dev)


def param_count(cfg) -> float:
    """Approximate parameter count N (active params for MoE noted
    separately)."""
    h, L = cfg.d_model, cfg.num_layers
    dh, H, KV = cfg.dh, cfg.num_heads, cfg.num_kv_heads
    emb = cfg.padded_vocab * h * (1 if cfg.tie_embeddings else 2)
    attn = h * (H + 2 * KV) * dh + H * dh * h
    if cfg.arch_type == "moe":
        ff_total = 3 * h * cfg.moe.d_ff_expert * cfg.moe.num_experts
        ff_active = 3 * h * cfg.moe.d_ff_expert * cfg.moe.top_k
        per_layer = attn + ff_total
        n_total = emb + L * per_layer
        n_active = emb + L * (attn + ff_active)
        return n_total, n_active
    if cfg.arch_type == "ssm":
        up = cfg.ssm.expand * h
        per = h * up * 2 + up * h + 3 * up * (h // (cfg.ssm.num_heads or 1)) \
            + h * h
        n = emb + L * per
        return n, n
    if cfg.arch_type == "hybrid":
        d_inner = cfg.ssm.expand * h
        nh = d_inner // cfg.ssm.head_dim
        mamba = h * (2 * d_inner + 2 * cfg.ssm.state_dim + nh) + d_inner * h
        shared = attn + 3 * h * cfg.d_ff
        n = emb + L * mamba + shared
        return n, n
    ff = (3 if cfg.gated_mlp else 2) * h * cfg.d_ff
    n = emb + L * (attn + ff)
    if cfg.arch_type == "audio":
        n += cfg.encoder_layers * (attn + ff) + L * attn  # enc + cross
    return n, n


def model_flops_per_device(cfg, ishape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference fwd), D = tokens,
    using N_active for MoE; divided across devices."""
    n_total, n_active = param_count(cfg)
    if ishape.kind == "train":
        tokens = ishape.global_batch * ishape.seq_len
        total = 6.0 * n_active * tokens
    elif ishape.kind == "prefill":
        tokens = ishape.global_batch * ishape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = ishape.global_batch
        total = 2.0 * n_active * tokens
    return total / n_devices
