"""ShapeDtypeStruct stand-ins for every model input, per assigned input
shape — weak-type-correct, shardable, no device allocation."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic decode memory: run for SSM/hybrid and the
# sliding-window dense arch; skip for pure full-attention archs + enc-dec
# (documented in DESIGN.md §4).
LONG_OK = {"gemma3-12b", "zamba2-1.2b", "xlstm-350m"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """vlm prepends patch embeddings; keep total context == seq_len."""
    if cfg.arch_type == "vlm":
        return seq_len - cfg.num_patch_tokens
    return seq_len


def extra_specs(cfg: ModelConfig, batch: int,
                dtype=jnp.bfloat16) -> Optional[Dict[str, Any]]:
    if cfg.arch_type == "audio":
        return {"frames": _sds((batch, cfg.encoder_seq_len, cfg.d_model),
                               dtype)}
    if cfg.arch_type == "vlm":
        return {"patches": _sds((batch, cfg.num_patch_tokens, cfg.d_model),
                                dtype)}
    return None


def train_batch_specs(cfg: ModelConfig, ishape: InputShape,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    b = ishape.global_batch
    s = text_len(cfg, ishape.seq_len)
    out = {"tokens": _sds((b, s), jnp.int32),
           "labels": _sds((b, s), jnp.int32)}
    ex = extra_specs(cfg, b, dtype)
    if ex:
        out["extra"] = ex
    return out


def prefill_specs(cfg: ModelConfig, ishape: InputShape) -> Tuple:
    b = ishape.global_batch
    s = text_len(cfg, ishape.seq_len)
    return _sds((b, s), jnp.int32), extra_specs(cfg, b)


def decode_token_spec(ishape: InputShape):
    return _sds((ishape.global_batch, 1), jnp.int32)


def cache_specs(model, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, dtype))


def params_specs(model, dtype=jnp.bfloat16) -> PyTree:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: model.init_params(k, dtype), key)
