"""Per-architecture partitioning rules: params (FSDP over "data" +
tensor-parallel over "model"), optimizer state, KV caches, and inputs.

Specs are derived from pytree key paths + array shapes, checking axis
divisibility against the mesh so e.g. whisper's 6 heads or granite's 40
experts fall back to replication on that dim instead of failing to lower.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

# Global parallelism strategy (hillclimb knob): which mesh axis carries
# tensor parallelism, which carry FSDP param sharding, and which carry
# data parallelism for inputs/activations. Defaults = the baseline
# production layout. set_strategy(tp=None, fsdp=("data","model"),
# dp=("pod","data","model")) turns the model axis into extra data/FSDP
# parallelism (right for small archs where TP collectives dominate).
_STRATEGY = {"tp": "model", "fsdp": ("data",), "dp": ("pod", "data")}


def set_strategy(tp="model", fsdp=("data",), dp=("pod", "data")):
    _STRATEGY["tp"] = tp
    _STRATEGY["fsdp"] = tuple(fsdp) if fsdp else ()
    _STRATEGY["dp"] = tuple(dp) if dp else ()


def get_strategy():
    return dict(_STRATEGY)


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1

def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in _STRATEGY["dp"] if a in mesh.axis_names)

def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= _axsize(mesh, a)
        return dim % n == 0
    return dim % _axsize(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis):
    return axis if _fits(dim, mesh, axis) else None


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_spec(path_s: str, shape: Tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh) -> P:
    """FSDP ("data") + tensor-parallel ("model") spec for one param."""
    nd = len(shape)

    def lead(n_used: int):
        return [None] * (nd - n_used)

    fsdp = tuple(a for a in _STRATEGY["fsdp"] if a in mesh.axis_names)
    data = fsdp if fsdp else None
    tp = _STRATEGY["tp"]
    model = tp if tp in mesh.axis_names else None

    name = path_s.rsplit("/", 1)[-1]
    if name in ("gamma", "beta", "A_log", "D", "dt_bias", "conv_b",
                "norm", "o_norm"):
        return P()
    if name == "tok":                       # (V, h)
        return P(_maybe(shape[0], mesh, model), _maybe(shape[1], mesh, data))
    if name in ("pos", "enc_pos"):          # (S, h)
        return P(None, _maybe(shape[1], mesh, data))
    if name == "unembed":                   # (h, V)
        return P(_maybe(shape[0], mesh, data), _maybe(shape[1], mesh, model))
    if name in ("wq", "wk", "wv") and nd >= 3:  # (..., h, n_heads, dh)
        return P(*lead(3), _maybe(shape[-3], mesh, data),
                 _maybe(shape[-2], mesh, model), None)
    if name == "wo":                        # (..., H*dh, h)
        return P(*lead(2), _maybe(shape[-2], mesh, model),
                 _maybe(shape[-1], mesh, data))
    if name in ("w1", "wg") and "moe" in path_s:  # (..., E, h, f)
        if cfg.moe and cfg.moe.sharding == "expert":
            return P(*lead(3), _maybe(shape[-3], mesh, model),
                     _maybe(shape[-2], mesh, data), None)
        return P(*lead(3), None, _maybe(shape[-2], mesh, data),
                 _maybe(shape[-1], mesh, model))
    if name == "w2" and "moe" in path_s:    # (..., E, f, h)
        if cfg.moe and cfg.moe.sharding == "expert":
            return P(*lead(3), _maybe(shape[-3], mesh, model), None,
                     _maybe(shape[-1], mesh, data))
        return P(*lead(3), None, _maybe(shape[-2], mesh, model),
                 _maybe(shape[-1], mesh, data))
    if name == "router":                    # (h, E)
        return P(_maybe(shape[0], mesh, data), None)
    if name in ("w1", "wg"):                # (..., h, f)
        return P(*lead(2), _maybe(shape[-2], mesh, data),
                 _maybe(shape[-1], mesh, model))
    if name == "w2":                        # (..., f, h)
        return P(*lead(2), _maybe(shape[-2], mesh, model),
                 _maybe(shape[-1], mesh, data))
    if name in ("in_proj", "w_up", "w_z", "w_gates", "w_down",
                "out_proj"):                # (..., in, out...)
        return P(*lead(2), _maybe(shape[-2], mesh, data),
                 _maybe(shape[-1], mesh, model))
    if name == "conv_w":                    # (..., width, d_inner)
        return P(*lead(2), None, _maybe(shape[-1], mesh, model))
    if name == "w_if":                      # (..., up, nh, 2)
        return P(*lead(3), _maybe(shape[-3], mesh, data), None, None)
    if name == "r_gates":                   # (..., nh, dh, 4dh)
        return P(*lead(3), None, None, _maybe(shape[-1], mesh, model))
    return P()


def param_shardings(cfg: ModelConfig, params_shapes: PyTree,
                    mesh: Mesh) -> PyTree:
    def f(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape,
                                              cfg, mesh))
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def opt_state_shardings(cfg: ModelConfig, opt_shapes: PyTree,
                        mesh: Mesh) -> PyTree:
    """mu/nu mirror the params; step is replicated."""
    def f(path, leaf):
        ps = _path_str(path)
        if ps == "step":
            return NamedSharding(mesh, P())
        ps2 = ps.split("/", 1)[1] if "/" in ps else ps  # strip mu|nu
        return NamedSharding(mesh, param_spec(ps2, leaf.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(f, opt_shapes)


def cache_shardings(cfg: ModelConfig, cache_shapes: PyTree, mesh: Mesh,
                    batch: int, seq_shard: bool = False,
                    seq_axis: str = "data") -> PyTree:
    """KV caches: batch over ("pod","data") when divisible; optionally
    shard the KV sequence dim (seq-parallel attention — the beyond-paper
    lever): over "data" for b=1 long decode, or over the "model" axis
    ALONGSIDE batch sharding when GQA kv_heads can't fill that axis
    (e.g. decode_32k: kv=8 < model=16 leaves "model" idle; seq 32k
    shards it 16-way, cutting per-device KV bytes by 16x)."""
    dp = _dp_axes(mesh)
    dp_n = _dp_size(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def f(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps == "pos" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if ps in ("k", "v", "k_cross", "v_cross", "k_global", "v_global"):
            # (L, b, S, KV, dh)
            bspec = dp if batch % dp_n == 0 and batch > 1 else None
            sspec = None
            if seq_shard and _fits(shape[2], mesh, seq_axis):
                conflict = bspec is not None and (
                    seq_axis in (bspec if isinstance(bspec, tuple)
                                 else (bspec,)))
                if not conflict:
                    sspec = seq_axis
            kvspec = _maybe(shape[3], mesh, model) if sspec is None else None
            return NamedSharding(mesh, P(None, bspec, sspec, kvspec, None))
        if ps in ("k_local", "v_local"):    # (n_super, ge-1, b, W, KV, dh)
            bspec = dp if batch % dp_n == 0 and batch > 1 else None
            return NamedSharding(
                mesh, P(None, None, bspec, None,
                        _maybe(shape[4], mesh, model), None))
        if ps.startswith("mamba"):          # (G, E, b, ...) conv or ssd
            bspec = dp if batch % dp_n == 0 and batch > 1 else None
            rest = [None] * (leaf.ndim - 3)
            if leaf.ndim >= 4:
                rest[0] = _maybe(shape[3], mesh, model)
            return NamedSharding(mesh, P(None, None, bspec, *rest))
        if ps.startswith(("mlstm", "slstm")):  # (L, b, ...)
            bspec = dp if batch % dp_n == 0 and batch > 1 else None
            rest = [None] * (leaf.ndim - 2)
            if leaf.ndim >= 3:
                rest[0] = _maybe(shape[2], mesh, model)
            return NamedSharding(mesh, P(None, bspec, *rest))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def batch_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    dp = _dp_axes(mesh)
    ok = batch % _dp_size(mesh) == 0 and batch > 1
    return NamedSharding(mesh, P(dp if ok else None, None))


def extra_shardings(cfg: ModelConfig, mesh: Mesh, batch: int) -> Dict:
    dp = _dp_axes(mesh)
    ok = batch % _dp_size(mesh) == 0 and batch > 1
    b = dp if ok else None
    out = {}
    if cfg.arch_type == "audio":
        out["frames"] = NamedSharding(mesh, P(b, None, None))
    if cfg.arch_type == "vlm":
        out["patches"] = NamedSharding(mesh, P(b, None, None))
    return out
