import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, print memory/cost analysis, dump roofline rows.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.json

The XLA_FLAGS line above MUST run before any other jax-importing module
(jax locks the device count on first init) — hence its position before
this docstring.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import roofline as RL
from repro.launch import shardings as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import DEFAULT_RULES, logical_rules
from repro.models.transformer import Model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step

ASSIGNED = [a for a in ARCH_IDS
            if not a.startswith(("opt-", "llama2-"))]


def _lower_train(model, cfg, ishape, mesh):
    params_s = SP.params_specs(model, jnp.bfloat16)
    opt_s = jax.eval_shape(init_opt_state, params_s)
    batch_s = SP.train_batch_specs(cfg, ishape)

    p_sh = SH.param_shardings(cfg, params_s, mesh)
    o_sh = SH.opt_state_shardings(cfg, opt_s, mesh)
    b_sh = {"tokens": SH.batch_sharding(mesh, ishape.global_batch),
            "labels": SH.batch_sharding(mesh, ishape.global_batch)}
    if "extra" in batch_s:
        b_sh["extra"] = SH.extra_shardings(cfg, mesh, ishape.global_batch)

    train_model = Model(cfg, remat=getattr(model, "train_remat", True),
                        scan_layers=model.scan_layers,
                        q_block=model.q_block, moe_impl=model.moe_impl)
    step = make_train_step(train_model, AdamWConfig(total_steps=1000))
    jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 donate_argnums=(0, 1))
    return jf.lower(params_s, opt_s, batch_s)


def _lower_prefill(model, cfg, ishape, mesh):
    params_s = SP.params_specs(model, jnp.bfloat16)
    tok_s, extra_s = SP.prefill_specs(cfg, ishape)
    p_sh = SH.param_shardings(cfg, params_s, mesh)
    t_sh = SH.batch_sharding(mesh, ishape.global_batch)
    e_sh = SH.extra_shardings(cfg, mesh, ishape.global_batch) or None

    def prefill_step(params, tokens, extra):
        return model.prefill(params, tokens, extra,
                             max_len=ishape.seq_len)

    jf = jax.jit(prefill_step, in_shardings=(p_sh, t_sh, e_sh))
    return jf.lower(params_s, tok_s, extra_s)


def _lower_decode(model, cfg, ishape, mesh):
    b = ishape.global_batch
    params_s = SP.params_specs(model, jnp.bfloat16)
    cache_s = SP.cache_specs(model, b, ishape.seq_len)
    tok_s = SP.decode_token_spec(ishape)

    p_sh = SH.param_shardings(cfg, params_s, mesh)
    c_sh = SH.cache_shardings(cfg, cache_s, mesh, b,
                              seq_shard=model.seq_shard,
                              seq_axis=getattr(model, "seq_axis", "data"))
    t_sh = SH.batch_sharding(mesh, b)

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)

    jf = jax.jit(serve_step, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return jf.lower(params_s, cache_s, tok_s)


def run_one(arch: str, shape: str, mesh_name: str,
            verbose: bool = True, fast: bool = False,
            layers: Optional[int] = None,
            auto: bool = False) -> Optional[dict]:
    if not SP.applicable(arch, shape):
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skipped (see DESIGN.md §4)"}
    cfg = get_config(arch)
    if layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    ishape = SP.INPUT_SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.devices.size
    seq_shard = (ishape.kind == "decode" and ishape.global_batch == 1)
    # Layer loops (and attention q-block loops) are UNROLLED for the
    # roofline dry-run: XLA's cost analysis counts a scan body once, which
    # would undercount FLOPs/collectives by ~num_layers. A larger q_block
    # keeps the unrolled HLO tractable. SSM inner chunk scans stay scans;
    # their compute floor is reported via MODEL_FLOPS (EXPERIMENTS.md).
    # fast=True keeps scans (used for the multi-pod lowering proof, where
    # only compile success matters — the roofline table is single-pod).
    if auto:
        # §Perf-optimized strategy from the hillclimb findings
        from repro.launch.autoshard import recommend
        from repro.launch.shardings import set_strategy
        plan = recommend(cfg, ishape, mesh)
        set_strategy(**plan.strategy)
        model = Model(cfg, scan_layers=fast, q_block=4096,
                      **plan.model_kwargs)
        model.seq_axis = plan.seq_axis
        rules = plan.rules
        if verbose and plan.rationale:
            for r in plan.rationale:
                print(f"  [auto] {r}")
    else:
        model = Model(cfg, seq_shard=seq_shard, scan_layers=fast,
                      q_block=4096)
        rules = dict(DEFAULT_RULES)
        if seq_shard:
            rules["kv_seq"] = "data"  # b=1: shard KV seq, not batch
            rules["batch"] = None
    t0 = time.perf_counter()
    with logical_rules(rules, mesh):
        with mesh:
            if ishape.kind == "train":
                lowered = _lower_train(model, cfg, ishape, mesh)
            elif ishape.kind == "prefill":
                lowered = _lower_prefill(model, cfg, ishape, mesh)
            else:
                lowered = _lower_decode(model, cfg, ishape, mesh)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

    mf = RL.model_flops_per_device(cfg, ishape, n_dev)
    rf = RL.from_compiled(compiled, arch, shape, mesh_name, mf)
    row = rf.row()
    row.update({"status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1), "n_devices": n_dev})
    if verbose:
        mem = row.get("memory") or {}
        print(f"[{arch} x {shape} x {mesh_name}] OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  memory/device: args={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB")
        print(f"  flops/dev={row['flops_per_dev']:.3e} "
              f"bytes/dev={row['bytes_per_dev']:.3e} "
              f"coll/dev={row['coll_bytes_per_dev']:.3e}")
        print(f"  roofline: compute={row['t_compute_s']*1e3:.2f}ms "
              f"memory={row['t_memory_s']*1e3:.2f}ms "
              f"collective={row['t_collective_s']*1e3:.2f}ms "
              f"-> {row['bottleneck']}-bound; "
              f"useful_flops={row['useful_flops_ratio']:.2f}")
        cd = {k: f"{v/2**20:.0f}MiB/{row['coll_counts'].get(k, 0)}ops"
              for k, v in row["coll_detail"].items() if v}
        print(f"  collectives: {cd}")
    return row


# Archs whose full-depth UNROLLED single-pod compile is intractable on
# this 1-core container: roofline terms come from a two-point linear
# extrapolation over reduced depths (slope = per-layer cost, intercept =
# embed/unembed/loss), while the FULL config still proves lower+compile
# via the scanned-layers path. Depth pairs respect layer-pattern cadence
# (gemma3 local:global 5:1, zamba2 shared-attn every 6).
EXTRAP_DEPTHS = {
    "qwen3-moe-30b-a3b": (4, 8),
    "granite-moe-3b-a800m": (4, 8),
    "internvl2-76b": (4, 8),
    "gemma3-12b": (6, 12),
    "zamba2-1.2b": (6, 12),
}

_LIN_FIELDS = ("flops_per_dev", "bytes_per_dev", "coll_bytes_per_dev",
               "model_flops_per_dev")


def _lerp_field(r1, r2, l1, l2, lf, key):
    slope = (r2[key] - r1[key]) / (l2 - l1)
    return r1[key] + slope * (lf - l1)


def run_extrapolated(arch: str, shape: str, verbose: bool = True,
                     auto: bool = False) -> Optional[dict]:
    """Single-pod roofline row for a heavy arch: full-config scanned
    compile (the lowering/compile proof + memory analysis) + two reduced
    unrolled compiles extrapolated to full depth for the cost terms."""
    if not SP.applicable(arch, shape):
        return {"arch": arch, "shape": shape, "mesh": "single",
                "status": "skipped (see DESIGN.md §4)"}
    l1, l2 = EXTRAP_DEPTHS[arch]
    cfg_full = get_config(arch)
    lf = cfg_full.num_layers
    ishape = SP.INPUT_SHAPES[shape]

    proof = run_one(arch, shape, "single", verbose=False, fast=True,
                    auto=auto)
    if proof["status"] != "ok":
        return proof
    r1 = run_one(arch, shape, "single", verbose=False, layers=l1,
                 auto=auto)
    r2 = run_one(arch, shape, "single", verbose=False, layers=l2,
                 auto=auto)

    row = dict(proof)   # memory analysis + compile proof from full config
    for key in _LIN_FIELDS:
        row[key] = _lerp_field(r1, r2, l1, l2, lf, key)
    row["coll_detail"] = {
        k: _lerp_field(r1["coll_detail"], r2["coll_detail"], l1, l2, lf, k)
        for k in r1["coll_detail"]}
    row["coll_counts"] = {
        k: round(_lerp_field(r1["coll_counts"], r2["coll_counts"],
                             l1, l2, lf, k))
        for k in r1["coll_counts"]}
    # recompute derived terms from extrapolated counts
    mf = RL.model_flops_per_device(cfg_full, ishape,
                                   proof["n_devices"])
    rf = RL.Roofline(arch, shape, "single", row["flops_per_dev"],
                     row["bytes_per_dev"], row["coll_bytes_per_dev"],
                     dict(row["coll_detail"],
                          _counts=row["coll_counts"]),
                     row.get("memory"), mf)
    out = rf.row()
    out.update({"status": "ok", "n_devices": proof["n_devices"],
                "lower_s": proof["lower_s"],
                "compile_s": proof["compile_s"],
                "roofline_source":
                    f"extrapolated from unrolled L={l1},{l2} "
                    f"(full L={lf} compiled scanned)"})
    if verbose:
        print(f"[{arch} x {shape} x single] OK (extrapolated "
              f"L={l1},{l2}->{lf})")
        print(f"  flops/dev={out['flops_per_dev']:.3e} "
              f"bytes/dev={out['bytes_per_dev']:.3e} "
              f"coll/dev={out['coll_bytes_per_dev']:.3e}")
        print(f"  roofline: compute={out['t_compute_s']*1e3:.2f}ms "
              f"memory={out['t_memory_s']*1e3:.2f}ms "
              f"collective={out['t_collective_s']*1e3:.2f}ms "
              f"-> {out['bottleneck']}-bound; "
              f"useful_flops={out['useful_flops_ratio']:.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None,
                    choices=list(SP.INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape)")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--fast", action="store_true",
                    help="scan layers (fast compile, inexact cost counts)")
    ap.add_argument("--extrap", action="store_true",
                    help="heavy-arch mode: full-config scanned compile + "
                         "reduced-depth unrolled roofline extrapolation")
    ap.add_argument("--auto", action="store_true",
                    help="apply the §Perf-optimized autoshard strategy")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        combos = [(a, s) for a in ASSIGNED for s in SP.INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    rows = []
    for (a, s) in combos:
        for m in meshes:
            try:
                if args.extrap and m == "single" and a in EXTRAP_DEPTHS:
                    rows.append(run_extrapolated(a, s, auto=args.auto))
                else:
                    rows.append(run_one(a, s, m, fast=args.fast,
                                        auto=args.auto))
            except Exception as e:
                traceback.print_exc()
                rows.append({"arch": a, "shape": s, "mesh": m,
                             "status": f"FAILED: {e}"})
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"].startswith("skip") for r in rows)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, "
          f"{len(rows) - n_ok - n_skip} failed / {len(rows)} total ==")
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print("wrote", args.out)
    return rows


if __name__ == "__main__":
    main()
