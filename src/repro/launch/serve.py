"""Serving launcher: batch of synthetic requests through the
request-level API (serving.api — EngineConfig + SamplingParams).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --backend resident --requests 8 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch opt-6.7b \
        --backend offload --compress int4    # KVPR host-offload path
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batching continuous --slots 2      # iteration-level batching
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --backend offload --batching continuous --slots 2
    PYTHONPATH=src python -m repro.launch.serve --smoke
        # CI round-trip: static+continuous x resident+offload

The legacy ``--mode`` strings (resident / offload / continuous /
continuous-offload) still work via ``EngineConfig.from_mode``.  Every
combination runs through one Scheduler (profiler → scheduler → runtime,
paper §3).  Always uses the reduced (smoke) config on this CPU
container; the full configs are exercised by the dry-run
(`repro.launch.dryrun`).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.cost_model import TPU_V5E
from repro.core.profiler import profile_system
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, KVTiersConfig, LLMEngine,
                           MeshConfig, PrefixCacheConfig, Request,
                           SamplingParams)


def run_smoke() -> None:
    """CI round-trip over the serve API: all four backend x batching
    combinations, greedy exactness across backends, and a mixed batch
    with an early-EOS request."""
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 8 + 2 * i).astype(np.int32),
        max_new_tokens=4) for i in range(3)]
    sched = Scheduler(TPU_V5E)

    outs = {}
    for backend in ("resident", "offload"):
        for batching in ("static", "continuous"):
            with LLMEngine.from_config(
                    model, params,
                    EngineConfig(backend=backend, batching=batching,
                                 slots=2, max_len=32),
                    scheduler=sched) as eng:
                t0 = time.perf_counter()
                outs[(backend, batching)] = eng.generate(reqs)
                dt = time.perf_counter() - t0
            n = sum(len(o.tokens) for o in outs[(backend, batching)])
            assert all(o.finish_reason == "length"
                       for o in outs[(backend, batching)])
            print(f"  {backend:8s} x {batching:10s}: {n} tokens "
                  f"in {dt:.2f}s ok")
    # per-request timing must be populated on every combo — SLO
    # attainment is computed from these fields (docs/serving.md)
    for combo, got in outs.items():
        for o in got:
            assert o.t_enqueue > 0 and o.t_finish >= o.t_first_token \
                > o.t_enqueue, (combo, o.uid)
            assert o.queue_wait >= 0 and o.ttft > 0 and o.tpot > 0, \
                (combo, o.uid)
    print("  per-request timing (t_enqueue/t_first_token/t_finish) "
          "populated on all 4 combos ok")
    # greedy decode is path-independent: the RAGGED static batch (8/10/
    # 12-token prompts) must agree with the per-request continuous runs
    # across every backend x batching combination
    base = outs[("resident", "continuous")]
    for combo, got in outs.items():
        for a, b in zip(base, got):
            assert np.array_equal(a.tokens, b.tokens), \
                f"ragged-batch mismatch under {combo} (uid={a.uid})"
    # mixed batch: greedy + temperature + early EOS, streamed
    ref = outs[("resident", "static")][0].tokens
    sps = [SamplingParams(max_tokens=4, eos_id=int(ref[1])),
           SamplingParams(max_tokens=4, temperature=0.8, seed=11),
           SamplingParams(max_tokens=4)]
    with LLMEngine.from_config(model, params,
                               EngineConfig(backend="offload"),
                               scheduler=sched) as eng:
        events = list(eng.generate_stream(reqs, sps))
    finals = {e.uid: e.finish_reason for e in events
              if e.finish_reason is not None}
    assert finals[0] == "stop" and finals[1] == "length" \
        and finals[2] == "length", finals
    print(f"  mixed batch (greedy+temperature+eos): "
          f"{len(events)} events, finish={finals} ok")
    # chunked prefill is an execution strategy, not a semantics change:
    # every backend x batching combo must emit tokens identical to its
    # inline-prefill run — chunks streamed to the host store behind
    # write-back fences on offload, token-budgeted mixed
    # prefill/decode steps under continuous batching
    for backend in ("resident", "offload"):
        for batching in ("static", "continuous"):
            kw = dict(prefill_chunk=5)
            if batching == "continuous":
                kw["max_step_tokens"] = 6
            with LLMEngine.from_config(
                    model, params,
                    EngineConfig(backend=backend, batching=batching,
                                 slots=2, max_len=32, **kw),
                    scheduler=sched) as eng:
                got = eng.generate(reqs)
            for a, b in zip(outs[(backend, batching)], got):
                assert np.array_equal(a.tokens, b.tokens), \
                    f"chunked-prefill mismatch under {(backend, batching)}"
    print("  chunked prefill: token-identical to inline on all "
          "4 combos ok")
    # shared-prefix cache: the second request extends the first's
    # prompt; its prefill must be restored, not recomputed, and its
    # tokens must match the cold run
    shared = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
    ext = np.concatenate([shared, rng.integers(
        1, cfg.vocab_size, 4).astype(np.int32)])
    with LLMEngine.from_config(
            model, params, EngineConfig(backend="offload"),
            scheduler=sched) as eng:
        cold = eng.generate([Request(uid=0, prompt=ext,
                                     max_new_tokens=4)])
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend="offload",
                         prefix_cache=PrefixCacheConfig()),
            scheduler=sched) as eng:
        eng.generate([Request(uid=0, prompt=shared, max_new_tokens=4)])
        warm = eng.generate([Request(uid=1, prompt=ext,
                                     max_new_tokens=4)])
        st = eng.prefix_stats
    assert np.array_equal(cold[0].tokens, warm[0].tokens)
    assert warm[0].cached_prefix == len(shared), warm[0].cached_prefix
    print(f"  prefix cache: {warm[0].cached_prefix} tokens restored "
          f"(split l={warm[0].restore.recomputed}), hit_rate="
          f"{st.hit_rate:.2f} ok")
    print("serve --smoke: all checks passed")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--backend", default="resident",
                    choices=["resident", "offload"])
    ap.add_argument("--batching", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--mode", default=None,
                    choices=["resident", "offload", "continuous",
                             "continuous-offload"],
                    help="legacy mode string (overrides "
                         "--backend/--batching)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--compress", default=None, choices=[None, "int4"])
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="terminate a request early on this token")
    ap.add_argument("--sampler", default=None,
                    choices=[None, "greedy", "temperature"],
                    help="legacy alias: temperature -> 0.8")
    ap.add_argument("--stream", action="store_true",
                    help="print per-token events as they are produced")
    ap.add_argument("--no-kvpr", action="store_true",
                    help="offload: stream full KV (FlexGen baseline)")
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "on", "off", "interpret"],
                    help="Pallas kernel dispatch for the offload decode "
                         "hot path (auto: native on TPU, jnp elsewhere; "
                         "on: kernels everywhere, interpret off-TPU)")
    ap.add_argument("--prefill-chunk", default=None,
                    help="chunked prefill: a chunk width in tokens, or "
                         "'auto' for the scheduler's chunk_split "
                         "decision (default: inline prefill)")
    ap.add_argument("--max-step-tokens", type=int, default=None,
                    help="continuous batching: per-step token budget "
                         "shared by decodes and admission prefill "
                         "chunks (requires --prefill-chunk)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the shared-prefix KV cache (cross-"
                         "request prompt reuse with KVPR-split restore)")
    ap.add_argument("--prefix-capacity", type=int, default=65536,
                    help="prefix cache capacity in tokens (LRU beyond)")
    ap.add_argument("--kv-host-capacity", type=int, default=None,
                    help="tiered KV store: accounted host DRAM budget "
                         "in tokens — tokens past it demote to the "
                         "mmap disk tier (enables tiering; offload "
                         "backend only)")
    ap.add_argument("--kv-tier-block", type=int, default=32,
                    help="tiered KV store: demotion block width in "
                         "tokens")
    ap.add_argument("--kv-tier-ttl", type=float, default=None,
                    help="tiered KV store: idle slots demote after "
                         "this many seconds (dual LRU+TTL eviction)")
    ap.add_argument("--kv-compress-on-demote", action="store_true",
                    help="tiered KV store: int4-quantize cold blocks "
                         "on demotion to disk (lossy, like the host "
                         "int4 path)")
    ap.add_argument("--kv-disk-read-bw", type=float, default=None,
                    help="tiered KV store: emulated disk read "
                         "bandwidth in bytes/s (also prices the "
                         "tier_split plan's disk crossing)")
    ap.add_argument("--kv-tier-policy", default="tier_split",
                    choices=["tier_split", "demand"],
                    help="tiered KV store: hierarchy-aware split "
                         "(tier_split) vs naive demand paging")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-axis mesh size k: per-shard KV "
                         "head-slices stream over 1/k of the link and "
                         "plans solve per shard (1 = unsharded; see "
                         "docs/scaling.md)")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data-axis mesh size (replica placement / "
                         "sequence-parallel prefill)")
    ap.add_argument("--profile", action="store_true",
                    help="measure the link/GEMM profile instead of preset")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI round-trip over all four engine combos")
    args = ap.parse_args(argv)

    if args.smoke:
        run_smoke()
        return
    if args.arch is None:
        ap.error("--arch is required (unless --smoke)")

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        args.prompt).astype(np.int32))
            for i in range(args.requests)]
    temp = args.temperature
    if args.sampler == "temperature" and temp <= 0:
        temp = 0.8
    sampling = SamplingParams(max_tokens=args.gen, temperature=temp,
                              top_k=args.top_k, eos_id=args.eos_id)

    chunk = args.prefill_chunk
    if chunk is not None and chunk != "auto":
        chunk = int(chunk)
    kv_tiers = None
    if args.kv_host_capacity is not None:
        kv_tiers = KVTiersConfig(
            host_capacity_tokens=args.kv_host_capacity,
            block_tokens=args.kv_tier_block,
            ttl_s=args.kv_tier_ttl,
            compress_on_demote=args.kv_compress_on_demote,
            disk_read_bytes_per_s=args.kv_disk_read_bw,
            policy=args.kv_tier_policy)
    mesh = None
    if args.mesh_model != 1 or args.mesh_data != 1:
        mesh = MeshConfig(model=args.mesh_model, data=args.mesh_data)
    base = dict(slots=args.slots, max_len=args.prompt + args.gen + 8,
                kvpr=not args.no_kvpr, compress=args.compress,
                kernels=args.kernels,
                seed=args.seed, prefill_chunk=chunk,
                max_step_tokens=args.max_step_tokens,
                kv_tiers=kv_tiers, mesh=mesh,
                prefix_cache=(PrefixCacheConfig(
                    capacity_tokens=args.prefix_capacity)
                    if args.prefix_cache else None))
    if args.mode is not None:
        config = EngineConfig.from_mode(args.mode, **base)
    else:
        config = EngineConfig(backend=args.backend,
                              batching=args.batching, **base)
    sched = Scheduler(profile_system() if args.profile else TPU_V5E)
    with LLMEngine.from_config(model, params, config,
                               scheduler=sched) as engine:
        t0 = time.perf_counter()
        if args.stream:
            total = 0
            for ev in engine.generate_stream(reqs, sampling):
                total += 1
                tail = (f" [{ev.finish_reason}]" if ev.finish_reason
                        else "")
                print(f"  step {ev.step:3d} uid={ev.uid} "
                      f"tok={ev.token}{tail}")
        else:
            outs = engine.generate(reqs, sampling)
            total = sum(len(o.tokens) for o in outs)
        dt = time.perf_counter() - t0

        print(f"{args.arch} [{config.backend}/{config.batching}"
              f"{'/int4' if args.compress else ''}]: "
              f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
              f"({total/dt:.1f} tok/s) "
              f"plan_cache[hits={sched.hits} misses={sched.misses}]")
        rt = engine.runtime
        if rt is not None:
            print(f"  hot path: xla_traces={rt.compute.traces()} "
                  f"staging_buffers={rt.xfer.staging_allocs}")
        ps = engine.prefix_stats
        if ps is not None:
            print(f"  prefix cache: hit_rate={ps.hit_rate:.2f} "
                  f"saved_tokens={ps.tokens_matched} "
                  f"entries={ps.entries} evictions={ps.evictions}")
        if not args.stream:
            waits = sorted(o.queue_wait for o in outs)
            ttfts = sorted(o.ttft for o in outs)
            tpots = [o.tpot for o in outs if o.tpot > 0]
            print(f"  latency: queue_wait p50="
                  f"{waits[len(waits) // 2] * 1e3:.1f}ms "
                  f"ttft p50={ttfts[len(ttfts) // 2] * 1e3:.1f}ms "
                  f"max={ttfts[-1] * 1e3:.1f}ms "
                  f"tpot mean="
                  f"{np.mean(tpots) * 1e3 if tpots else 0.0:.1f}ms")
            for o in outs[:4]:
                print(f"  uid={o.uid} [{o.finish_reason}]: "
                      f"{np.asarray(o.tokens)[:8]}...")


if __name__ == "__main__":
    main()
