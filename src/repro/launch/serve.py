"""Serving launcher: batch of synthetic requests through any engine mode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode resident --requests 8 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch opt-6.7b \
        --mode offload --compress int4          # KVPR host-offload path
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode continuous --slots 2             # iteration-level batching
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --mode continuous-offload --slots 2     # KVPR + admission

Every mode runs through one Scheduler (profiler → scheduler → runtime,
paper §3): the launcher builds it once and both engines draw their
ExecutionPlans from its cache.  Always uses the reduced (smoke) config
on this CPU container; the full configs are exercised by the dry-run
(`repro.launch.dryrun`).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.cost_model import TPU_V5E
from repro.core.profiler import profile_system
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--mode", default="resident",
                    choices=["resident", "offload", "continuous",
                             "continuous-offload"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--compress", default=None, choices=[None, "int4"])
    ap.add_argument("--sampler", default="greedy",
                    choices=["greedy", "temperature"])
    ap.add_argument("--no-kvpr", action="store_true",
                    help="offload modes: stream full KV (FlexGen baseline)")
    ap.add_argument("--profile", action="store_true",
                    help="measure the link/GEMM profile instead of preset")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        args.prompt).astype(np.int32),
                    max_new_tokens=args.gen)
            for i in range(args.requests)]

    sched = Scheduler(profile_system() if args.profile else TPU_V5E)
    if args.mode.startswith("continuous"):
        engine = ContinuousBatchingEngine(
            model, params, num_slots=args.slots,
            max_len=args.prompt + args.gen + 8,
            mode="offload" if args.mode.endswith("offload") else "resident",
            scheduler=sched, kvpr=not args.no_kvpr,
            compress=args.compress)
    else:
        engine = ServingEngine(model, params, mode=args.mode,
                               kvpr=not args.no_kvpr, sampler=args.sampler,
                               scheduler=sched, compress=args.compress)
    t0 = time.perf_counter()
    gens = engine.serve(reqs)
    dt = time.perf_counter() - t0

    total = sum(len(g.tokens) for g in gens)
    print(f"{args.arch} [{args.mode}"
          f"{'/int4' if args.compress else ''}]: "
          f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) "
          f"plan_cache[hits={sched.hits} misses={sched.misses}]")
    rt = getattr(engine, "runtime", None)
    if rt is not None:
        print(f"  hot path: xla_traces={rt.compute.traces()} "
              f"staging_buffers={rt.xfer.staging_allocs}")
    for g in gens[:4]:
        print(f"  uid={g.uid}: {np.asarray(g.tokens)[:8]}...")


if __name__ == "__main__":
    main()
