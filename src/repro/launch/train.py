"""Production training launcher: any assigned arch x a production mesh
(or single-device smoke), sharded params/optimizer/batch, data pipeline,
checkpointing.

    # single-device smoke (actually runs on this container)
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 20

    # production mesh path (same code the dry-run validates); on CPU use
    # --dry-run to stop after lower+compile instead of executing 256
    # emulated chips
    XLA_FLAGS=--xla_force_host_platform_device_count=512 \
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --mesh single --dry-run
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, make_stream
from repro.launch import shardings as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import DEFAULT_RULES, logical_rules
from repro.models.transformer import Model
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (runs on CPU)")
    ap.add_argument("--dry-run", action="store_true",
                    help="stop after lower+compile (no execution)")
    ap.add_argument("--moe-impl", default="gspmd",
                    choices=["gspmd", "shard_map"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len,
                                                   args.seq * 2))
    opt_cfg = AdamWConfig(total_steps=max(args.steps, 10))

    mesh = None if args.mesh == "none" else \
        make_production_mesh(multi_pod=(args.mesh == "multi"))
    model = Model(cfg, remat=(mesh is not None), moe_impl=args.moe_impl)
    step_fn = make_train_step(model, opt_cfg)

    data = make_stream(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  batch_size=args.batch, seed=0))

    def run(params, opt_state, step):
        t0 = time.perf_counter()
        losses = []
        for i in range(args.steps):
            batch = next(data)
            params, opt_state, metrics = step(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                l = float(metrics["loss"])
                losses.append(l)
                dt = time.perf_counter() - t0
                tps = args.batch * args.seq * (i + 1) / dt
                print(f"step {i:5d}  loss {l:.4f}  {tps:,.0f} tok/s")
        assert np.isfinite(losses[-1]), "training diverged"
        if args.steps >= 50:    # too noisy to assert on shorter runs
            assert losses[-1] < losses[0], "loss did not decrease"
        if args.ckpt:
            checkpoint.save(args.ckpt, {"params": params,
                                        "opt": opt_state})
            print("checkpoint ->", args.ckpt)
        return params

    if mesh is None:
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        n = sum(p.size for p in jax.tree.leaves(params))
        print(f"{args.arch}: {n/1e6:.1f}M params, single device")
        run(params, opt_state, jax.jit(step_fn))
        return

    # production-mesh path: shard params/optimizer/batch like the dry-run
    with logical_rules(dict(DEFAULT_RULES), mesh):
        with mesh:
            params_s = SP.params_specs(model, jnp.bfloat16)
            opt_s = jax.eval_shape(init_opt_state, params_s)
            p_sh = SH.param_shardings(cfg, params_s, mesh)
            o_sh = SH.opt_state_shardings(cfg, opt_s, mesh)
            b_sh = {"tokens": SH.batch_sharding(mesh, args.batch),
                    "labels": SH.batch_sharding(mesh, args.batch)}
            jf = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            batch_s = {
                "tokens": jax.ShapeDtypeStruct((args.batch, args.seq),
                                               jnp.int32),
                "labels": jax.ShapeDtypeStruct((args.batch, args.seq),
                                               jnp.int32)}
            lowered = jf.lower(params_s, opt_s, batch_s)
            compiled = lowered.compile()
            print(f"compiled for {mesh.devices.size} devices; "
                  f"per-device memory:")
            print(compiled.memory_analysis())
            if args.dry_run:
                return
            init = jax.jit(
                lambda k: (model.init_params(k, jnp.bfloat16),),
                out_shardings=(p_sh,))
            (params,) = init(jax.random.PRNGKey(0))
            opt_state = jax.jit(init_opt_state,
                                out_shardings=o_sh)(params)
            run(params, opt_state, jf)


if __name__ == "__main__":
    main()
