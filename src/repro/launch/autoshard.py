"""Auto-sharding recommendations distilled from the §Perf hillclimbs
(EXPERIMENTS.md): given (config, input shape, mesh), return the
parallelism strategy, logical activation rules, and Model kwargs that the
measured iterations showed to dominate the baseline.

Findings encoded (pair → measured gain on the dominant roofline term):
  1. Sub-~2B-param models: tensor parallelism over a 16-wide axis feeds
     the MXU 64-wide shards and pays activation regathers at every
     boundary — drop TP, run FSDP+DP over ALL axes.
     (xlstm-350m train: collective 62x down, bound 3.8x; zamba2-1.2b
     train: collective 38x down, bound 2.9x.)
  2. Decode: never FSDP-regather weights per token step — params stay
     TP-sharded over "model", replicated over batch axes.
     (mistral-nemo decode_32k: collective 143x down.)
  3. Decode with GQA kv_heads < model-axis size: the model axis idles for
     the KV cache — shard the cache SEQUENCE dim over it.
     (mistral-nemo decode_32k: memory term 8.2x down.)
  4. MoE: GSPMD global-capacity dispatch leaves the data axis idle during
     the expert FFN and all-gathers the (E, C_global, h) buffer — use the
     shard_map local-dispatch block (expert-parallel when E divides the
     axis, per-expert tensor-parallel otherwise).
     (qwen3 train: compute 181x down, bound 14.6x; granite train:
     bound 7.7x.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.launch.roofline import param_count
from repro.launch.specs import InputShape
from repro.models.sharding import DEFAULT_RULES

# Below this many total params, TP over a 16-wide axis costs more in
# activation regathers than it saves (hillclimb finding 1).
SMALL_MODEL_PARAMS = 2e9


@dataclasses.dataclass
class Plan:
    strategy: Dict[str, Any]          # shardings.set_strategy kwargs
    rules: Dict[str, Any]             # logical activation rules
    model_kwargs: Dict[str, Any]      # Model(...) extras
    seq_axis: str = "data"
    rationale: Tuple[str, ...] = ()


def recommend(cfg: ModelConfig, ishape: InputShape, mesh) -> Plan:
    axes = tuple(mesh.axis_names)
    model_ax = "model" if "model" in axes else None
    dp_default = tuple(a for a in ("pod", "data") if a in axes)
    n_total, _ = param_count(cfg)

    strategy = {"tp": model_ax, "fsdp": ("data",), "dp": dp_default}
    rules = dict(DEFAULT_RULES)
    mk: Dict[str, Any] = {}
    seq_axis = "data"
    why: List[str] = []

    small = n_total < SMALL_MODEL_PARAMS
    decode = ishape.kind == "decode"

    all_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
    n_all = 1
    for a in all_axes:
        n_all *= mesh.shape[a]
    # finding 1 only pays when the batch actually fills the widened data
    # axis — otherwise batch falls back to replication and the memory term
    # explodes (measured: tinyllama prefill_32k b=32 on 256 chips went
    # 5.2s -> 37.9s before this guard)
    if small and not decode and ishape.global_batch % n_all == 0:
        strategy = {"tp": None, "fsdp": all_axes, "dp": all_axes}
        rules["batch"] = all_axes
        for k in ("heads", "mlp", "vocab", "ssm_heads"):
            rules[k] = None
        why.append(f"{n_total/1e9:.1f}B params < 2B and batch fills "
                   f"{n_all} ways: drop TP, FSDP+DP over all "
                   f"{len(all_axes)} axes (finding 1)")

    if decode and ishape.global_batch == 1:
        # b=1 long decode: keep the FULL baseline plan. Measured:
        # applying findings 2/3 here REGRESSED every b=1 row (gemma3
        # long_500k 29ms -> 342ms, xlstm 0.3ms -> 2.5ms) — per-step work
        # is so small that stationary params just move gather traffic to
        # per-step HBM reads, and the ring/local caches prefer the
        # baseline's data-axis seq sharding.
        mk["seq_shard"] = True
        seq_axis = "data"
        rules["kv_seq"] = "data"
        rules["batch"] = None
        why.append("b=1 long decode: baseline layout kept (findings 2/3 "
                   "measured as regressions at this batch size)")
    elif decode:
        strategy["fsdp"] = ()   # weights stationary (finding 2)
        why.append("decode: params stay TP-sharded, no per-step FSDP "
                   "regather (finding 2)")
        if (model_ax and cfg.num_kv_heads < mesh.shape[model_ax]
                and ishape.seq_len % mesh.shape[model_ax] == 0
                and cfg.arch_type in ("dense", "vlm", "moe", "audio",
                                      "hybrid")):
            mk["seq_shard"] = True
            seq_axis = model_ax
            rules["kv_seq"] = model_ax
            why.append(f"kv_heads={cfg.num_kv_heads} < "
                       f"model={mesh.shape[model_ax]}: shard KV seq over "
                       f"'{model_ax}' (finding 3)")

    if cfg.arch_type == "moe" and model_ax:
        mk["moe_impl"] = "shard_map"
        why.append("MoE: shard_map local dispatch (finding 4)")

    return Plan(strategy=strategy, rules=rules, model_kwargs=mk,
                seq_axis=seq_axis, rationale=tuple(why))
