"""Analytic pipeline timelines (paper Figs. 3-5): given a hardware profile
and a per-layer workload, compute decode-step timelines for

  - flexgen  : full KV transfer, overlapped with previous-layer compute
               (the paper's baseline; Fig. 3a)
  - kvpr     : partial recompute + concurrent KV transfer (Fig. 3b),
               coarse-grained (recompute waits for all MHA weights)
  - kvpr-fine: fine-grained MHA pipeline (Fig. 5b) — W_K, W_V are loaded
               first so recomputation hides under the remaining weight load

Both row-by-row (weights resident or streamed per layer) and column-by-
column (weights streamed, reused across batches) schedules are modeled.
This simulator is what EXPERIMENTS.md §Perf validates against the paper's
reported gains; the executable counterpart is core/runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost_model import HardwareProfile, Workload
from repro.core.solver import SplitDecision, optimal_split


@dataclasses.dataclass(frozen=True)
class StepTimeline:
    """Per-layer decode-step timing breakdown (seconds)."""
    method: str
    t_weights: float        # MHA+FFN weight transfer (0 if resident)
    t_act: float            # activation transfer (column schedule)
    t_kv: float             # KV cache transfer
    t_recomp: float         # GPU KV recompute
    t_attn: float           # attention + FFN compute
    t_layer: float          # critical-path per-layer time
    split: Optional[SplitDecision] = None

    @property
    def transfer_total(self) -> float:
        return self.t_weights + self.t_act + self.t_kv

    @property
    def gpu_busy(self) -> float:
        return self.t_recomp + self.t_attn

    @property
    def utilization(self) -> float:
        return min(1.0, self.gpu_busy / max(self.t_layer, 1e-12))


def _attn_ffn_time(wl: Workload, hw: HardwareProfile,
                   d_ff_flops: float = 0.0) -> float:
    """Decode attention (1 token vs s' KV) + FFN compute time. Memory-bound
    on the device: bytes = KV read; compute = 2*b*s'*kv_dim*2 MACs."""
    attn_bytes = wl.total_kv_bytes
    attn_flops = 4 * wl.batch * wl.seq_len * wl.kv_dim
    t_attn = max(attn_bytes / hw.hbm_bandwidth, attn_flops / hw.v_gpu)
    t_ffn = d_ff_flops / hw.v_gpu
    return t_attn + t_ffn


def flexgen_step(wl: Workload, hw: HardwareProfile,
                 weights_resident: bool = True,
                 d_ff_flops: float = 0.0) -> StepTimeline:
    """Baseline: stream the whole KV cache; transfer overlaps previous
    compute, so per-layer time = max(transfer, compute) + epsilon. We
    report the steady-state critical path."""
    t_w = 0.0 if weights_resident else wl.mha_weight_bytes / hw.v_com
    t_kv = wl.total_kv_bytes / hw.v_com
    t_c = _attn_ffn_time(wl, hw, d_ff_flops)
    t_layer = max(t_w + t_kv, t_c)
    return StepTimeline("flexgen", t_w, 0.0, t_kv, 0.0, t_c, t_layer)


def kvpr_step(wl: Workload, hw: HardwareProfile,
              schedule: str = "column",
              weights_resident: bool = True,
              fine_grained: bool = False,
              d_ff_flops: float = 0.0,
              align: int = 1,
              split: Optional[SplitDecision] = None) -> StepTimeline:
    """KVPR: transfer X[0:l], recompute KV[0:l] while KV[l:s'] streams."""
    if split is None:
        split = optimal_split(wl, hw, schedule=schedule, align=align)
    l = split.l
    t_act = wl.act_bytes(l) / hw.v_com if schedule == "column" else 0.0
    t_recomp = wl.recompute_flops(l) / hw.v_gpu
    t_kv = wl.kv_bytes(wl.seq_len - l) / hw.v_com
    t_c = _attn_ffn_time(wl, hw, d_ff_flops)
    t_w = 0.0 if weights_resident else wl.mha_weight_bytes / hw.v_com

    if weights_resident:
        # act transfer, then max(recompute, kv stream), then attention
        t_layer = t_act + max(t_recomp, t_kv) + t_c
        # steady state: attention of layer i overlaps transfers of i+1
        t_layer = max(t_act + max(t_recomp, t_kv), t_c)
    elif fine_grained:
        # Fig. 5b: W_K, W_V arrive after half the weight load; recompute
        # overlaps the remaining W_Q, W_O load. Worst case == weight-bound
        # baseline (paper: "no worse than the baseline").
        t_wkv = t_w / 2.0
        gpu_start = max(t_wkv, t_act)
        recompute_done = gpu_start + t_recomp
        transfers_done = max(t_w, t_act + t_kv)
        t_layer = max(max(recompute_done, transfers_done) + 0.0, t_c)
    else:
        # Fig. 5a: recompute waits for the full MHA weight load
        gpu_start = max(t_w, t_act)
        recompute_done = gpu_start + t_recomp
        transfers_done = max(t_w, t_act + t_kv)
        t_layer = max(max(recompute_done, transfers_done), t_c)

    name = "kvpr-fine" if fine_grained else "kvpr"
    return StepTimeline(name, t_w, t_act, t_kv, t_recomp, t_c, t_layer,
                        split)


def decode_latency(wl_fn, hw: HardwareProfile, num_layers: int,
                   gen_len: int, method: str = "kvpr",
                   schedule: str = "row", weights_resident: bool = True,
                   d_ff_flops: float = 0.0, align: int = 1,
                   overhead_s: float = 0.0, scheduler=None) -> float:
    """Total decode latency over `gen_len` steps. `wl_fn(step)` returns the
    Workload at that generation step (seq grows during generation).
    `overhead_s` is a fixed per-layer system overhead (framework + launch)
    calibrated from a measured baseline; applied identically to every
    method.

    Pass a `core.scheduler.Scheduler` to draw splits from a cached
    ExecutionPlan (amortized re-solve at bucket granularity) instead of
    re-solving every simulated step — the same planner the executable
    runtime uses."""
    plan = None
    if scheduler is not None and method != "flexgen":
        if scheduler.hw != hw:
            raise ValueError(
                f"scheduler profiles {scheduler.hw.name!r} but timings "
                f"use {hw.name!r}; splits would be optimal for the "
                "wrong machine")
        plan = scheduler.plan_for_workload(
            wl_fn(0), mode="kvpr", schedule=schedule, align=align)
    total = 0.0
    for g in range(gen_len):
        wl = wl_fn(g)
        if method == "flexgen":
            st = flexgen_step(wl, hw, weights_resident, d_ff_flops)
        else:
            split = (plan.split_for(wl.seq_len, batch=wl.batch)
                     if plan is not None else None)
            st = kvpr_step(wl, hw, schedule, weights_resident,
                           fine_grained=(method == "kvpr-fine"),
                           d_ff_flops=d_ff_flops, align=align,
                           split=split)
        total += (st.t_layer + overhead_s) * num_layers
    return total
