"""KVPR automation loop (paper §3): profiler → scheduler → runtime.

The paper's system is "fully automated": the profiler measures link
bandwidth and GEMM throughput once (§3.1), the scheduler solves the
KV-split LP for the workload (§3.2), and the runtime merely *executes*
the schedule (§3.3).  This module is the scheduler half of that loop:

  - ``PlanKey``       — the identity of a plan.  Everything the split
                        decision depends on (hardware profile, mode,
                        schedule, alignment, batch, model dims, dtype,
                        compression) is part of the key, so changing any
                        of them naturally invalidates the cached plan.
  - ``ExecutionPlan`` — per-sequence-length ``SplitDecision``s for a
                        workload trajectory.  Solves are amortized: the
                        plan re-solves only every ``resolve_every``
                        tokens of sequence growth (decisions are reused
                        within a bucket, and bucketing rounds *down* so
                        ``l <= seq_len`` always holds), and memoizes per
                        (bucket, batch) so ragged per-slot lookups under
                        continuous batching share work across slots.
                        The plan also owns the *pad geometry* of the
                        decode hot path: every decision carries
                        ``(l_pad, s_pad)`` rounded UP to ``pad_every``
                        buckets, and ``step_geometry`` aggregates them
                        per step, so the jitted layer step's static
                        shapes take O(#buckets) distinct values and the
                        XLA trace cache stops growing with sequence
                        length.  Runtimes and engines never choose pads
                        themselves.
  - ``Scheduler``     — the plan cache + profiler glue.  Engines ask it
                        for a plan; identical requests hit the cache,
                        and ``invalidate()`` drops all plans (e.g. after
                        re-profiling the hardware).

The runtimes (``core/runtime.py``) contain **no** solver calls: the
``ExecutionPlan`` is the only call site of ``optimal_split`` on the
decode path.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import (HardwareProfile, Workload,
                                   int4_kv_bytes_per_el)
from repro.core.solver import (ChunkDecision, SplitDecision,
                               TierSplitDecision, optimal_chunk,
                               optimal_split, optimal_tier_split)


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Everything a split decision depends on.  Frozen + hashable so it
    doubles as the plan-cache key: any change (new hardware profile,
    different batch, compression toggled, ...) yields a different key and
    therefore a fresh plan — invalidation by construction."""
    hw: HardwareProfile
    mode: str                    # "kvpr" | "flexgen"
    schedule: str                # "row" | "column"
    align: int
    batch: int
    d_model: int
    kv_dim: int
    dtype_bytes: int
    compress: Optional[str]
    # effective link bytes per KV element (None -> dtype_bytes); set by
    # the Scheduler from `compress` so the solver prices the compressed
    # stream correctly instead of ~8x over for int4
    kv_bytes_per_el: Optional[float] = None
    # effective DISK bytes per KV element for tier_split plans (None ->
    # the host stream width): a tiered store with compress-on-demote
    # moves int4-packed bytes across the disk rung while the host rung
    # still streams full-width, and the solver must price each rung at
    # its own width
    disk_bytes_per_el: Optional[float] = None
    # model-axis mesh size: every solve sees ONE shard's workload
    # (kv_dim / shards via Workload.per_shard) over one shard's link
    # share (bandwidths / shards via HardwareProfile.per_shard), so
    # plans re-memoize per topology.  1 = the unsharded path, and both
    # per_shard calls return their inputs unchanged there, so the
    # default key solves bit-identically to a pre-mesh scheduler.
    shards: int = 1


@dataclasses.dataclass
class StepGeometry:
    """Everything the runtime needs to execute one decode step: per-slot
    recompute / streamed lengths and the bucket-padded static shapes for
    the jitted layer.  Produced only by ``ExecutionPlan.step_geometry``
    — the runtime executes it verbatim."""
    ls: np.ndarray               # (b,) per-slot recompute lengths
    s_strs: np.ndarray           # (b,) per-slot streamed valid lengths
    l_pad: int                   # static recompute buffer length
    s_pad: int                   # static streamed buffer length
    uniform: bool                # every slot at the same length


class ExecutionPlan:
    """Split decisions for a decode trajectory, solved lazily and reused.

    ``split_for(seq_len)`` returns the decision for decoding with
    ``seq_len`` tokens already cached.  Decisions are solved at bucket
    granularity (``resolve_every`` tokens) so a growing sequence re-uses
    one solve per bucket instead of solving every step; buckets round
    down, which keeps the chosen ``l`` within the actually-available
    prefix.  ``splits_for_slots`` is the continuous-batching entry point:
    one decision per slot at that slot's own (ragged) length, solved for
    a batch-1 workload since each slot streams independently.
    """

    def __init__(self, key: PlanKey, resolve_every: int = 16,
                 pad_every: Optional[int] = None):
        self.key = key
        self.resolve_every = max(1, int(resolve_every))
        # pad bucket for the static shapes of the jitted layer step; one
        # XLA trace serves pad_every tokens of sequence growth
        self.pad_every = max(1, int(pad_every if pad_every is not None
                                    else self.resolve_every))
        self._splits: Dict[Tuple[int, int], SplitDecision] = {}
        self._tier_splits: Dict[Tuple[int, int, int],
                                TierSplitDecision] = {}
        self._lock = threading.Lock()
        self.solves = 0
        self.lookups = 0

    def _bucket(self, seq_len: int) -> int:
        b = (seq_len // self.resolve_every) * self.resolve_every
        return b if b > 0 else seq_len

    def _pad_up(self, n: int) -> int:
        return -(-int(n) // self.pad_every) * self.pad_every if n > 0 else 0

    def split_for(self, seq_len: int,
                  batch: Optional[int] = None) -> SplitDecision:
        """Decision for the current sequence length (bucketed, memoized).

        The returned decision carries pad geometry for THIS seq_len:
        ``l_pad`` / ``s_pad`` rounded up to ``pad_every`` (the solve is
        memoized per bucket; the pads are recomputed per lookup since the
        streamed length keeps growing inside a solve bucket)."""
        self.lookups += 1
        if seq_len <= 0:
            return SplitDecision.flexgen(0, self.key.schedule)
        batch = self.key.batch if batch is None else batch
        s = self._bucket(seq_len)
        ck = (s, batch)
        with self._lock:
            hit = self._splits.get(ck)
        if hit is None:
            k = self.key
            if k.mode == "flexgen":
                hit = SplitDecision.flexgen(s, k.schedule)
            else:
                wl = Workload(batch=batch, seq_len=s, d_model=k.d_model,
                              kv_dim=k.kv_dim, dtype_bytes=k.dtype_bytes,
                              kv_bytes_per_el=k.kv_bytes_per_el)
                hit = optimal_split(wl.per_shard(k.shards),
                                    k.hw.per_shard(k.shards),
                                    schedule=k.schedule, align=k.align)
            with self._lock:
                self._splits[ck] = hit
                self.solves += 1
        return dataclasses.replace(
            hit, l_pad=self._pad_up(hit.l),
            s_pad=self._pad_up(seq_len - hit.l))

    def tier_split_for(self, seq_len: int, disk_tokens: int,
                       batch: Optional[int] = None) -> TierSplitDecision:
        """The fourth plan kind: the transfer-vs-recompute split for a
        fetch whose leading ``disk_tokens`` are resident on the
        profile's disk rung (``hw.tiers``).  Bucketed and memoized per
        (seq bucket, disk bucket, batch) exactly like ``split_for`` —
        the disk bucket rounds DOWN too, so a chosen ``l`` never
        exceeds the actually-available prefix.  With no ladder on the
        profile (or nothing demoted) this degenerates to the plain
        decode split re-expressed as a ``TierSplitDecision``."""
        self.lookups += 1
        batch = self.key.batch if batch is None else batch
        d = max(0, min(int(disk_tokens), int(seq_len)))
        k = self.key
        rung = k.hw.tier("disk") or (k.hw.tiers[0] if k.hw.tiers
                                     else None)
        if seq_len <= 0 or rung is None or d == 0 or k.mode == "flexgen":
            dec = self.split_for(seq_len, batch=batch)
            return TierSplitDecision(
                l=dec.l, disk_tokens=d, paged_tokens=max(0, d - dec.l),
                t_total=dec.t_total, t_recomp=dec.t_recomp,
                t_kv=dec.t_kv, t_disk=0.0, bound=dec.bound)
        s = self._bucket(seq_len)
        db = min((d // self.resolve_every) * self.resolve_every, s)
        ck = (s, db, batch)
        with self._lock:
            hit = self._tier_splits.get(ck)
        if hit is None:
            wl = Workload(batch=batch, seq_len=s, d_model=k.d_model,
                          kv_dim=k.kv_dim, dtype_bytes=k.dtype_bytes,
                          kv_bytes_per_el=k.kv_bytes_per_el)
            hw_s = k.hw.per_shard(k.shards)
            rung_s = hw_s.tier(rung.name) or rung
            hit = optimal_tier_split(
                wl.per_shard(k.shards), hw_s, disk_tokens=db,
                disk_read_bandwidth=rung_s.read_bandwidth,
                disk_bytes_per_el=k.disk_bytes_per_el, align=k.align)
            with self._lock:
                self._tier_splits[ck] = hit
                self.solves += 1
        # the memo hit is for the bucketed d; report paging vs the REAL
        # residency so the runtime's accounting matches what it fetches
        return dataclasses.replace(hit, disk_tokens=d,
                                   paged_tokens=max(0, d - hit.l))

    def splits_for_slots(self, seq_lens: Sequence[int]
                         ) -> List[SplitDecision]:
        """Per-slot decisions for ragged lengths (iteration-level
        batching): each slot's KV streams independently, so each is a
        batch-1 workload at its own length."""
        return [self.split_for(int(s), batch=1) for s in seq_lens]

    def step_geometry(self, seq_lens: Sequence[int],
                      max_len: Optional[int] = None,
                      disk_tokens: Optional[Sequence[int]] = None
                      ) -> StepGeometry:
        """Geometry for one decode step over every slot.

        Aggregates the per-slot decisions into the step's static shapes:
        ``l_pad`` / ``s_pad`` are the bucket-padded maxima over slots
        (the max of bucket multiples is a bucket multiple, so the trace
        count stays O(#buckets)), clamped to the store capacity
        ``max_len`` so padded fetch windows never run past the
        preallocated host buffers.

        With ``disk_tokens`` (per-slot counts of leading demoted
        tokens, from ``TieredKVStore.disk_tokens``) the per-slot
        decision is the fourth plan kind (``tier_split_for``): same
        geometry contract, but ``l`` also weighs the disk rung's
        page-in cost — a mostly-demoted slot leans harder on
        recompute.  The pad buckets are shared with the plain path, so
        the tiered store draws from the SAME O(#buckets) trace budget
        and a warm engine toggling tiers recompiles nothing."""
        seq = np.asarray(seq_lens, np.int64)
        if disk_tokens is None:
            uniform = bool((seq == seq[0]).all())
            if uniform:
                decs = [self.split_for(int(seq[0]))]
                ls = np.full(seq.shape[0], decs[0].l, np.int64)
            else:
                decs = self.splits_for_slots(seq)
                ls = np.array([d.l for d in decs], np.int64)
            l_pads = [d.l_pad for d in decs]
            s_pads = [d.s_pad for d in decs]
        else:
            dk = np.asarray(disk_tokens, np.int64)
            uniform = bool((seq == seq[0]).all() and (dk == dk[0]).all())
            if uniform:
                decs = [self.tier_split_for(int(seq[0]), int(dk[0]))]
                ls = np.full(seq.shape[0], decs[0].l, np.int64)
            else:
                decs = [self.tier_split_for(int(s), int(di), batch=1)
                        for s, di in zip(seq, dk)]
                ls = np.array([d.l for d in decs], np.int64)
            l_pads = [self._pad_up(d.l) for d in decs]
            s_pads = [self._pad_up(int(s) - d.l)
                      for s, d in zip(seq, decs)]
        s_strs = seq - ls
        # max over bucket multiples is a bucket multiple: the step's
        # static shapes aggregate the decisions' own pad geometry
        l_pad = max(l_pads)
        s_pad = max(s_pads)
        if max_len is not None:
            l_pad = min(l_pad, int(max_len))
            s_pad = min(s_pad, int(max_len) - int(ls.min()))
        return StepGeometry(ls=ls, s_strs=s_strs, l_pad=l_pad,
                            s_pad=s_pad, uniform=uniform)

    def fallback_geometry(self, seq_lens: Sequence[int],
                          max_len: Optional[int] = None) -> StepGeometry:
        """Degradation-ladder geometry: the split at the l = p endpoint
        — every slot's FULL prefix is recomputed from activations and
        nothing streams over the link (``s_pad = 0``).  The runtime
        uses this when a streamed-KV fetch has stalled or failed: the
        link is taken out of the step's critical path entirely, at the
        recompute cost the solver's endpoint already prices.  Pad
        bucketing matches ``step_geometry`` so the fallback draws from
        the same O(#buckets) trace budget."""
        seq = np.asarray(seq_lens, np.int64)
        ls = seq.copy()
        l_pad = self._pad_up(int(seq.max()))
        if max_len is not None:
            l_pad = min(l_pad, int(max_len))
        return StepGeometry(ls=ls, s_strs=np.zeros_like(seq),
                            l_pad=l_pad, s_pad=0,
                            uniform=bool((seq == seq[0]).all()))


class Scheduler:
    """Plan cache keyed by ``PlanKey``; the scheduler half of the
    profiler → scheduler → runtime loop.

    Construct with a measured or preset ``HardwareProfile``; with none,
    the profiler runs (once, memoized) on first use.  ``plan_for``
    returns a cached ``ExecutionPlan`` when the key matches a previous
    request and a fresh one otherwise; ``invalidate()`` clears the cache,
    optionally installing a re-measured profile.
    """

    _MAX_PLANS = 64              # LRU bound; plans are small but unbounded
                                 # workloads shouldn't grow the cache forever

    def __init__(self, hw: Optional[HardwareProfile] = None,
                 resolve_every: int = 16,
                 pad_every: Optional[int] = None):
        self._hw = hw
        self.resolve_every = resolve_every
        self.pad_every = pad_every
        self._plans: "OrderedDict[PlanKey, ExecutionPlan]" = OrderedDict()
        self._chunks: "OrderedDict[tuple, ChunkDecision]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    @property
    def hw(self) -> HardwareProfile:
        if self._hw is None:
            from repro.core import profiler
            self._hw = profiler.profile_system()
            # a later profile_system(force=True) re-measure pushes the
            # fresh profile into this scheduler (invalidate(hw=...))
            profiler.register_scheduler(self)
        return self._hw

    # ------------------------------------------------------------ planning

    @staticmethod
    def _kv_el_bytes(compress: Optional[str], dtype_bytes: int,
                     group: int) -> Optional[float]:
        if compress == "int4":
            return int4_kv_bytes_per_el(group)
        return None                          # uncompressed: dtype_bytes

    def plan_for(self, cfg, batch: int, mode: str = "kvpr",
                 schedule: str = "row", align: int = 1,
                 compress: Optional[str] = None,
                 dtype_bytes: int = 4, group: int = 32,
                 hw: Optional[HardwareProfile] = None,
                 disk_bytes_per_el: Optional[float] = None,
                 shards: int = 1) -> ExecutionPlan:
        """Plan for a model config (engines' entry point).  ``hw``
        overrides the scheduler's profile for this plan only — the
        tiered runtime passes its ladder-extended profile here, so
        tier_split plans key on (and price) the ladder while every
        other plan keeps the base profile's cache entries.  ``shards``
        is the model-axis mesh size: the plan prices one shard's
        head-slice over one shard's link share and re-memoizes per
        topology (shards is part of the PlanKey)."""
        key = PlanKey(hw=hw or self.hw, mode=mode, schedule=schedule,
                      align=align, batch=batch, d_model=cfg.d_model,
                      kv_dim=cfg.num_kv_heads * cfg.dh,
                      dtype_bytes=dtype_bytes, compress=compress,
                      kv_bytes_per_el=self._kv_el_bytes(
                          compress, dtype_bytes, group),
                      disk_bytes_per_el=disk_bytes_per_el,
                      shards=int(shards))
        return self._get(key)

    def restore_split(self, cfg, p: int, mode: str = "kvpr",
                      align: int = 1, dtype_bytes: int = 4,
                      shards: int = 1):
        """Admission-time restore split for a cached p-token prompt
        prefix (shared-prefix KV cache): how many of the matched tokens
        the device recomputes from cached activations ([0, l)) versus
        streams as KV over the link ([l, p)).

        This is the paper's decode-time transfer-vs-recompute LP
        applied once at admission: a batch-1 workload at seq_len p
        under the COLUMN schedule, because the activations for the
        recomputed part must cross the link too (unlike the row
        schedule's already-resident decode activations).  The decision
        is cached under its own batch-1/column ``PlanKey``, so decode
        plans are untouched and identical restores share one solve.
        ``mode="flexgen"`` degrades to stream-everything (l = 0).
        """
        plan = self.plan_for(cfg, batch=1, mode=mode, schedule="column",
                             align=align, dtype_bytes=dtype_bytes,
                             shards=shards)
        return plan.split_for(int(p))

    def chunk_split(self, cfg, n: int, batch: int = 1, align: int = 16,
                    dtype_bytes: int = 4,
                    compress: Optional[str] = None,
                    group: int = 32, shards: int = 1) -> ChunkDecision:
        """The third plan kind (after ``plan_for``'s decode split and
        ``restore_split``): the prefill chunk width for an ``n``-token
        prompt whose finished chunks stream to the host while the next
        chunk computes.  Same profiler-backed cost model — the solve
        balances chunk-i compute (GEMM throughput) against chunk-(i-1)
        write-back (link bandwidth) plus the per-chunk dispatch
        overhead, and is memoized per (dims, n, batch) so repeated
        admissions of same-length prompts share one solve."""
        mlp_mults = 3 if getattr(cfg, "gated_mlp", True) else 2
        shards = int(shards)
        key = (self.hw, int(n), int(batch), cfg.d_model,
               cfg.num_kv_heads * cfg.dh, cfg.num_layers, cfg.d_ff,
               align, dtype_bytes, compress, mlp_mults, shards)
        with self._lock:
            hit = self._chunks.get(key)
        if hit is not None:
            return hit
        wl = Workload(batch=batch, seq_len=int(n), d_model=cfg.d_model,
                      kv_dim=cfg.num_kv_heads * cfg.dh,
                      dtype_bytes=dtype_bytes,
                      kv_bytes_per_el=self._kv_el_bytes(
                          compress, dtype_bytes, group))
        # per-shard chunk economics: the shard prefills its KV
        # head-slice (wl.per_shard) and writes it back over its link
        # share (hw.per_shard); the MLP width divides across the model
        # axis too.  The residual-width GEMM terms stay whole — a
        # conservative compute estimate that is exact at shards = 1.
        dec = optimal_chunk(int(n), wl.per_shard(shards),
                            self.hw.per_shard(shards), cfg.num_layers,
                            max(1, cfg.d_ff // shards), align=align,
                            mlp_mults=mlp_mults)
        with self._lock:
            self._chunks[key] = dec
            while len(self._chunks) > self._MAX_PLANS:
                self._chunks.popitem(last=False)
        return dec

    def plan_for_workload(self, wl: Workload, mode: str = "kvpr",
                          schedule: str = "row", align: int = 1,
                          compress: Optional[str] = None,
                          shards: int = 1) -> ExecutionPlan:
        """Plan from a raw Workload (analytic pipeline entry point)."""
        key = PlanKey(hw=self.hw, mode=mode, schedule=schedule, align=align,
                      batch=wl.batch, d_model=wl.d_model, kv_dim=wl.kv_dim,
                      dtype_bytes=wl.dtype_bytes, compress=compress,
                      kv_bytes_per_el=wl.kv_bytes_per_el,
                      shards=int(shards))
        return self._get(key)

    def _get(self, key: PlanKey) -> ExecutionPlan:
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
            plan = ExecutionPlan(key, self.resolve_every, self.pad_every)
            self._plans[key] = plan
            while len(self._plans) > self._MAX_PLANS:
                self._plans.popitem(last=False)
            return plan

    def invalidate(self, hw: Optional[HardwareProfile] = None) -> None:
        """Drop every cached plan; optionally install a new profile."""
        with self._lock:
            if hw is not None:
                self._hw = hw
            self._plans.clear()
            self._chunks.clear()
