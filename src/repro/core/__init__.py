"""KVPR core: the paper's contribution (profiler, scheduler, runtime)."""
from repro.core.cost_model import (
    A100_PCIE4, PROFILES, RTX5000_PCIE4X8, TPU_V5E,
    HardwareProfile, TierLink, Workload, layer_times, tier_layer_times,
)
from repro.core.solver import (
    SplitDecision, TierSplitDecision, brute_force_split,
    brute_force_tier_split, optimal_split, optimal_tier_split,
)
from repro.core.scheduler import ExecutionPlan, PlanKey, Scheduler
from repro.core.prefix_cache import (
    PrefixCache, PrefixCacheConfig, PrefixCacheStats, PrefixEntry,
    RadixPrefixIndex,
)
from repro.core.pipeline import (
    StepTimeline, decode_latency, flexgen_step, kvpr_step,
)

__all__ = [
    "A100_PCIE4", "PROFILES", "RTX5000_PCIE4X8", "TPU_V5E",
    "HardwareProfile", "TierLink", "Workload", "layer_times",
    "tier_layer_times",
    "SplitDecision", "TierSplitDecision", "brute_force_split",
    "brute_force_tier_split", "optimal_split", "optimal_tier_split",
    "ExecutionPlan", "PlanKey", "Scheduler",
    "PrefixCache", "PrefixCacheConfig", "PrefixCacheStats",
    "PrefixEntry", "RadixPrefixIndex",
    "StepTimeline", "decode_latency", "flexgen_step", "kvpr_step",
]
