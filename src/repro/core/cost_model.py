"""Hardware + workload cost model for the KVPR scheduler (paper Eq. 6-10).

All times in seconds, sizes in bytes, compute in FLOPs. The profile is
either measured (core/profiler.py) or taken from presets matching the
paper's systems and our TPU target.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TierLink:
    """One rung of the storage-bandwidth ladder below the host tier.

    The base ``HardwareProfile`` prices a single host→device link
    (``link_bandwidth``); a tiered store adds rungs BEHIND it — e.g. an
    NVMe mmap tier whose blocks must first cross disk→host and then
    host→device.  Frozen (and nested in the frozen profile) so the
    whole ladder stays hashable and ``PlanKey`` memoization keeps
    working unchanged."""
    name: str
    read_bandwidth: float        # tier -> host bytes/s (page-in)
    write_bandwidth: float       # host -> tier bytes/s (demotion)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    link_bandwidth: float        # host->device bytes/s (PCIe / host-DMA)
    gpu_flops: float             # accelerator matmul FLOP/s (achievable)
    hbm_bandwidth: float         # device memory bytes/s
    # efficiency factor applied to peak for small-GEMM recompute workloads
    gemm_efficiency: float = 1.0
    # fixed per-kernel-launch latency (seconds): one jitted dispatch on
    # the device queue.  The chunked-prefill planner charges it once per
    # chunk — it is what makes very small chunks lose (measured by
    # core/profiler.measure_dispatch_overhead on live systems).
    dispatch_overhead: float = 5e-4
    # bandwidth ladder below host DRAM, fastest first.  Empty = the
    # classic single-link profile; a tiered KV store installs its disk
    # rung here (with_tiers) so tier_split plans can price a fetch that
    # crosses disk->host AND host->device.
    tiers: Tuple[TierLink, ...] = ()

    @property
    def v_com(self) -> float:
        return self.link_bandwidth

    @property
    def v_gpu(self) -> float:
        return self.gpu_flops * self.gemm_efficiency

    def tier(self, name: str) -> Optional[TierLink]:
        for t in self.tiers:
            if t.name == name:
                return t
        return None

    def with_tiers(self, *tiers: TierLink) -> "HardwareProfile":
        """A copy of this profile with the given ladder installed (a
        NEW frozen value: plans keyed on the old profile are untouched,
        plans for the tiered store key on this one)."""
        return dataclasses.replace(self, tiers=tuple(tiers))

    def per_shard(self, shards: int) -> "HardwareProfile":
        """The link budget ONE shard of a ``shards``-way tensor-parallel
        mesh sees: the host link (and every tier rung below it) is
        shared by ``shards`` concurrent per-shard streams, so each
        stream gets a 1/shards slice of the bandwidth.  Compute rates
        are untouched — each shard runs on its own accelerator; the
        per-shard FLOP reduction lives in ``Workload.per_shard``.
        Returns ``self`` unchanged at shards == 1, so single-shard
        plans are keyed and solved bit-identically to the unsharded
        path (docs/scaling.md)."""
        if shards <= 1:
            return self
        return dataclasses.replace(
            self,
            name=f"{self.name}/tp{shards}",
            link_bandwidth=self.link_bandwidth / shards,
            tiers=tuple(dataclasses.replace(
                t,
                read_bandwidth=t.read_bandwidth / shards,
                write_bandwidth=t.write_bandwidth / shards)
                for t in self.tiers))


# The paper's primary system: A100-40GB + PCIe 4.0 x16.
A100_PCIE4 = HardwareProfile(
    name="a100-pcie4",
    link_bandwidth=32e9,
    gpu_flops=312e12,            # A100 bf16/fp16 dense peak
    hbm_bandwidth=2.0e12,
    gemm_efficiency=0.45,        # decode-shape GEMMs don't hit peak
)

# The paper's low-end system (Appendix A.5): RTX 5000 + PCIe 4.0 x8.
RTX5000_PCIE4X8 = HardwareProfile(
    name="rtx5000-pcie4x8",
    link_bandwidth=16e9,
    gpu_flops=89.2e12,
    hbm_bandwidth=448e9,
    gemm_efficiency=0.45,
)

# Our target: TPU v5e chip, host-attached over PCIe-class link.
TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    link_bandwidth=32e9,
    gpu_flops=197e12,            # bf16 peak per chip
    hbm_bandwidth=819e9,
    gemm_efficiency=0.5,
)

PROFILES = {p.name: p for p in (A100_PCIE4, RTX5000_PCIE4X8, TPU_V5E)}

# v5e interconnect (for the roofline, launch/roofline.py)
V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9  # per link


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-layer decode workload at current sequence length s' (paper §3.2).

    Sizes follow Eq. 6: activations X[0:l] are (b, l, h); the KV cache for
    the rest is 2 x (b, s'-l, kv_dim). For GQA models kv_dim < h, which
    CHANGES the optimal split vs the paper's MHA assumption: recomputing
    token t costs transferring h bytes to save 2*kv_dim bytes, so the
    activation:KV byte ratio is h/(2*kv_dim) rather than 1/2.
    """
    batch: int
    seq_len: int                 # current s' (prompt + generated so far)
    d_model: int                 # h (activation width)
    kv_dim: int                  # num_kv_heads * head_dim (per K or V)
    dtype_bytes: int = 2
    # Effective bytes per KV *element* on the link.  None -> dtype_bytes
    # (uncompressed).  With int4 compression the stream carries packed
    # codes + group scales/zeros, so each element costs far less than
    # dtype_bytes — activations stay exact at dtype_bytes either way.
    # The solver must see this, or it overestimates streamed KV bytes
    # ~8x and picks an over-large recompute prefix l.
    kv_bytes_per_el: Optional[float] = None
    # recompute FLOPs per token: K and V projections (Eq. 8 generalizes
    # from 4*b*l*h^2 to 2 GEMMs of h x kv_dim each)
    mha_weight_bytes: int = 0    # for the fine-grained pipeline (Fig. 5)

    @property
    def kv_el_bytes(self) -> float:
        return (self.dtype_bytes if self.kv_bytes_per_el is None
                else self.kv_bytes_per_el)

    def act_bytes(self, l: int) -> int:
        return self.batch * l * self.d_model * self.dtype_bytes

    def kv_bytes(self, tokens: int) -> int:
        return int(2 * self.batch * tokens * self.kv_dim
                   * self.kv_el_bytes)

    def recompute_flops(self, l: int) -> int:
        # K = X Wk, V = X Wv : 2 GEMMs, 2*b*l*h*kv_dim MACs each
        return 4 * self.batch * l * self.d_model * self.kv_dim

    @property
    def total_kv_bytes(self) -> int:
        return self.kv_bytes(self.seq_len)

    def per_shard(self, shards: int) -> "Workload":
        """The slice of this workload ONE shard of a ``shards``-way
        tensor-parallel mesh owns: KV heads partition across the model
        axis, so the per-shard KV width (and with it both the streamed
        KV bytes and the K/V-projection recompute FLOPs) divides by
        ``shards``.  Activations do NOT divide — every shard needs the
        full (b, l, h) input to recompute its head-slice, which is what
        moves the optimal split toward more recomputation as shards
        grow (docs/scaling.md).  Returns ``self`` unchanged at
        shards == 1 so single-shard solves stay bit-identical to the
        unsharded path."""
        if shards <= 1:
            return self
        if self.kv_dim % shards:
            raise ValueError(
                f"kv_dim={self.kv_dim} does not divide across "
                f"{shards} shards (num_kv_heads * dh must be a "
                f"multiple of the model-axis size)")
        return dataclasses.replace(
            self, kv_dim=self.kv_dim // shards,
            mha_weight_bytes=self.mha_weight_bytes // shards)


def int4_kv_bytes_per_el(group: int = 32) -> float:
    """Link bytes per KV element for the group-wise int4 stream
    (core/kvquant.py layout): a packed half-byte code plus two f32
    (scale, zero) values amortized over each ``group`` elements."""
    return 0.5 + 8.0 / group


def chunk_compute_flops(wl: Workload, n_layers: int, d_ff: int,
                        prefix: int, c: int, mlp_mults: int = 3) -> float:
    """Device FLOPs to prefill one ``c``-token chunk whose queries attend
    over ``prefix`` already-cached tokens plus their own causal block.

    Linear part (QKVO + MLP GEMMs) is per-token; the attention part is
    the quadratic term that chunking cannot remove — query t of the
    chunk scores against prefix + t + 1 keys (QK^T and PV, 2 MACs per
    key per channel).  ``mlp_mults`` is the number of h x d_ff matmuls
    in the MLP (2 plain, 3 gated)."""
    h, kv, b = wl.d_model, wl.kv_dim, wl.batch
    linear = 4 * h * h + 4 * h * kv + 2 * h * d_ff * mlp_mults
    attn = 4 * h * (prefix * c + c * (c + 1) / 2)
    return float(b * n_layers * (c * linear + attn))


def chunk_writeback_bytes(wl: Workload, n_layers: int, c: int) -> float:
    """Host write-back bytes for one finished c-token chunk: K + V
    (at the effective streamed element width) plus the attention-input
    activations KVPR keeps for later recomputation."""
    kv_b = 2 * wl.kv_dim * wl.kv_el_bytes
    act_b = wl.d_model * wl.dtype_bytes
    return float(wl.batch * n_layers * c * (kv_b + act_b))


def layer_times(wl: Workload, hw: HardwareProfile, l: int,
                include_act_transfer: bool = True) -> dict:
    """Eq. 9-10: timing components for split point l."""
    t_act = wl.act_bytes(l) / hw.v_com if include_act_transfer else 0.0
    t_recomp = wl.recompute_flops(l) / hw.v_gpu
    t_kv = wl.kv_bytes(wl.seq_len - l) / hw.v_com
    total = t_act + max(t_recomp, t_kv)
    return {"t_act": t_act, "t_recomp": t_recomp, "t_kv": t_kv,
            "total": total}


def tier_layer_times(wl: Workload, hw: HardwareProfile, l: int,
                     disk_tokens: int, disk_read_bandwidth: float,
                     disk_bytes_per_el: Optional[float] = None,
                     include_act_transfer: bool = False) -> dict:
    """Eq. 9-10 generalized to a two-rung ladder: the leading
    ``disk_tokens`` of the prefix are resident on a slow tier (the
    tiered store keeps disk residency a PREFIX of each slot), the rest
    in host DRAM.  Recomputing ``[0, l)`` skips the disk read for every
    demoted token below l; a demoted token ABOVE l must cross
    disk→host (at ``disk_read_bandwidth``, possibly at a compressed
    ``disk_bytes_per_el`` width) before it can cross host→device.  The
    page-in overlaps the previous layer's compute exactly like the
    PCIe stream does, so the streamed arm is the SUM of the two link
    crossings for the disk share plus the host crossing for the warm
    share — and the whole expression degenerates to ``layer_times``
    at ``disk_tokens = 0``."""
    d = max(0, min(int(disk_tokens), wl.seq_len))
    t_act = wl.act_bytes(l) / hw.v_com if include_act_transfer else 0.0
    t_recomp = wl.recompute_flops(l) / hw.v_gpu
    cold = max(0, d - l)                   # demoted tokens still streamed
    warm = (wl.seq_len - l) - cold
    p_disk = (wl.kv_el_bytes if disk_bytes_per_el is None
              else disk_bytes_per_el)
    disk_bytes = 2 * wl.batch * cold * wl.kv_dim * p_disk
    t_disk = disk_bytes / float(disk_read_bandwidth)
    t_kv = (wl.kv_bytes(wl.seq_len - l) / hw.v_com) + t_disk
    total = t_act + max(t_recomp, t_kv)
    return {"t_act": t_act, "t_recomp": t_recomp, "t_kv": t_kv,
            "t_disk": t_disk, "total": total}
