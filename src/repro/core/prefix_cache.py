"""Host-side shared-prefix KV cache: a radix-trie index over host-
resident KV + activation blocks, keyed by token prefixes.

This is the ROADMAP's cross-request prompt-reuse step: a request whose
prompt extends a prefix some earlier request already prefilled skips
prefill for the matched tokens — the scheduler's *restore split*
(``Scheduler.restore_split``, the paper's transfer-vs-recompute LP
applied at admission time) decides how much of the match is recomputed
on device from the cached activations versus streamed as KV over the
link (``core.runtime.restore_prefix_kv``).

The index is a radix (compressed) trie modeled on prompt-cache-engine's
``RadixTrie``, with two serving-oriented twists:

  - nodes index ``PrefixEntry`` objects (the host KV/activation blocks)
    directly instead of opaque cache keys;
  - lookups count PARTIAL edge matches: if a new prompt diverges k
    tokens into an entry's edge, every entry below that edge still
    shares the first ``matched`` tokens, so its blocks are valid for
    them — the "prefix longer than the match" case costs nothing.

Capacity is bounded in TOKENS (the blocks dominate memory, and their
size is linear in tokens); eviction is LRU over whole entries.
Thread-safe: continuous engines admit from their serving loop while
other engines sharing the cache do the same.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Knobs for the shared-prefix cache (``EngineConfig.prefix_cache``).

    capacity_tokens: total tokens of stored prefixes before LRU
        eviction kicks in.
    min_prefix: shortest prefix worth matching or inserting — tiny
        matches cost more restore bookkeeping than they save.
    insert_on_finish: record each finished request's prompt blocks
        (the serving engine captures them at admission).
    ttl_s: idle time-to-live in seconds — an entry unused for this
        long is evicted regardless of capacity pressure (dual LRU+TTL,
        matching the tiered KV store's eviction).  Expired entries are
        swept on every insert and lookup; a hit refreshes the entry's
        deadline.  None disables.
    """
    capacity_tokens: int = 65536
    min_prefix: int = 4
    insert_on_finish: bool = True
    ttl_s: Optional[float] = None

    def validate(self) -> "PrefixCacheConfig":
        if self.capacity_tokens < 1:
            raise ValueError(f"capacity_tokens must be >= 1, got "
                             f"{self.capacity_tokens}")
        if self.min_prefix < 1:
            raise ValueError(f"min_prefix must be >= 1, got "
                             f"{self.min_prefix}")
        if self.ttl_s is not None and self.ttl_s <= 0:
            raise ValueError(f"ttl_s must be positive or None, got "
                             f"{self.ttl_s}")
        return self


@dataclasses.dataclass
class PrefixEntry:
    """One cached prefix: its tokens and host-resident blocks.

    ks/vs: (L, 1, p, KV, dh) float32; hs: (L, 1, p, h) float32 —
    exactly what ``prefill_with_activations`` returns for a b=1
    prefill, position-native (block index == RoPE position).
    """
    tokens: Tuple[int, ...]
    ks: np.ndarray
    vs: np.ndarray
    hs: np.ndarray
    last_used: int = 0
    hits: int = 0
    # absolute monotonic TTL deadline (None = no TTL); refreshed on hit
    deadline: Optional[float] = None

    def __len__(self) -> int:
        return len(self.tokens)

    @property
    def nbytes(self) -> int:
        return self.ks.nbytes + self.vs.nbytes + self.hs.nbytes


class _Node:
    """Radix-trie node: ``tokens`` is the edge label leading INTO this
    node; ``entry`` is the entry whose token sequence ends exactly
    here.  Invariant: every non-root node's subtree contains at least
    one entry (``remove`` prunes otherwise)."""

    __slots__ = ("tokens", "children", "entry")

    def __init__(self, tokens: Tuple[int, ...] = ()):
        self.tokens = tokens
        self.children: Dict[int, _Node] = {}
        self.entry: Optional[PrefixEntry] = None


class RadixPrefixIndex:
    """Radix trie over token sequences -> ``PrefixEntry``."""

    def __init__(self) -> None:
        self.root = _Node()
        self._size = 0

    @property
    def size(self) -> int:
        return self._size

    # ------------------------------------------------------------ insert

    def insert(self, tokens: Tuple[int, ...], entry: PrefixEntry) -> None:
        if not tokens:
            return
        node = self.root
        pos = 0
        while pos < len(tokens):
            child = node.children.get(tokens[pos])
            if child is None:
                leaf = _Node(tokens[pos:])
                leaf.entry = entry
                node.children[tokens[pos]] = leaf
                self._size += 1
                return
            et = child.tokens
            m = 0
            while (m < len(et) and pos + m < len(tokens)
                   and et[m] == tokens[pos + m]):
                m += 1
            if m == len(et):
                pos += m
                node = child
                continue
            # partial match: split the edge at m
            split = _Node(et[:m])
            child.tokens = et[m:]
            split.children[child.tokens[0]] = child
            rest = tokens[pos + m:]
            if rest:
                leaf = _Node(rest)
                leaf.entry = entry
                split.children[rest[0]] = leaf
            else:
                split.entry = entry
            node.children[tokens[pos]] = split
            self._size += 1
            return
        # landed exactly on an existing node
        if node.entry is None:
            self._size += 1
        node.entry = entry

    # ------------------------------------------------------------- match

    def match(self, tokens) -> Tuple[int, Optional[PrefixEntry]]:
        """Longest usable prefix of ``tokens`` covered by some entry.

        Returns (matched_len, entry) where ``entry.tokens[:matched_len]
        == tokens[:matched_len]``.  Partial edge matches count: when the
        walk diverges k tokens into an edge, every entry in that edge's
        subtree shares the matched span, so one of them is returned
        even though none ends there."""
        node = self.root
        pos = 0
        n = len(tokens)
        while pos < n:
            child = node.children.get(int(tokens[pos]))
            if child is None:
                break
            et = child.tokens
            m = 0
            while (m < len(et) and pos + m < n
                   and et[m] == tokens[pos + m]):
                m += 1
            pos += m
            if m < len(et):
                # diverged (or ran out of query) inside the edge: the
                # subtree below still covers the matched span
                return pos, self._any_entry(child)
            node = child
        if pos == 0 or node is self.root:
            return 0, None
        return pos, self._any_entry(node)

    def _any_entry(self, node: _Node) -> PrefixEntry:
        while node.entry is None:
            node = next(iter(node.children.values()))
        return node.entry

    # ------------------------------------------------------------ remove

    def remove(self, tokens: Tuple[int, ...]) -> bool:
        """Remove the entry ending exactly at ``tokens``; prune nodes
        left with neither entry nor children (keeps the every-subtree-
        has-an-entry invariant ``match`` relies on)."""
        if not tokens:
            return False
        node = self.root
        pos = 0
        path: List[Tuple[_Node, int]] = []       # (parent, first_token)
        while pos < len(tokens):
            child = node.children.get(tokens[pos])
            if child is None:
                return False
            et = child.tokens
            if tokens[pos:pos + len(et)] != et:
                return False
            path.append((node, tokens[pos]))
            pos += len(et)
            node = child
        if node.entry is None:
            return False
        node.entry = None
        self._size -= 1
        # prune upward: drop entry-less leaves
        while path:
            parent, tok = path.pop()
            if node.entry is None and not node.children:
                del parent.children[tok]
            node = parent
        return True

    def entries(self) -> List[PrefixEntry]:
        out: List[PrefixEntry] = []

        def walk(node: _Node) -> None:
            if node.entry is not None:
                out.append(node.entry)
            for c in node.children.values():
                walk(c)

        walk(self.root)
        return out


@dataclasses.dataclass
class PrefixCacheStats:
    """Cumulative counters (a snapshot; see ``PrefixCache.stats``)."""
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    peeks: int = 0               # non-mutating warmth probes (router
                                 # placement; never touch LRU recency)
    tokens_matched: int = 0      # prefill tokens skipped via restore
    tokens_inserted: int = 0
    entries: int = 0
    tokens_stored: int = 0
    bytes_stored: int = 0
    evictions: int = 0
    ttl_evictions: int = 0       # entries expired past ttl_s (swept on
                                 # insert/lookup)
    invalidations: int = 0       # poisoned entries evicted after a
                                 # failed restore (degradation ladder)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


class PrefixCache:
    """The host-side shared-prefix store: radix index + LRU eviction.

    ``lookup`` caps the match at ``len(prompt) - 1`` so at least one
    prompt token always goes through (partial) prefill — the engine
    needs that position's logits to sample the first output token.
    """

    def __init__(self, config: Optional[PrefixCacheConfig] = None):
        self.config = (config or PrefixCacheConfig()).validate()
        self.index = RadixPrefixIndex()
        self._entries: Dict[Tuple[int, ...], PrefixEntry] = {}
        self._lock = threading.Lock()
        self._clock = 0
        self._tokens_stored = 0      # running total (O(1) eviction test)
        self._stats = PrefixCacheStats()

    # ------------------------------------------------------------ lookup

    def lookup(self, prompt) -> Tuple[int, Optional[PrefixEntry]]:
        """Longest cached prefix usable for ``prompt`` (a 1-D int
        sequence): returns (matched_len, entry), (0, None) on miss.
        Bumps the entry's LRU clock and the hit counters."""
        toks = [int(t) for t in prompt]
        with self._lock:
            self._sweep_ttl_locked()
            self._stats.lookups += 1
            p, entry = self.index.match(toks)
            p = min(p, len(toks) - 1)
            if entry is None or p < self.config.min_prefix:
                self._stats.misses += 1
                return 0, None
            self._clock += 1
            entry.last_used = self._clock
            entry.hits += 1
            if self.config.ttl_s is not None:
                entry.deadline = time.monotonic() + self.config.ttl_s
            self._stats.hits += 1
            self._stats.tokens_matched += p
            return p, entry

    # -------------------------------------------------------------- peek

    def peek(self, prompt) -> Tuple[int, Optional[PrefixEntry]]:
        """Non-mutating warmth probe: what ``lookup(prompt)`` WOULD
        return, without touching LRU recency, hit counters, or the
        entry's own stats.

        This is the router's placement probe: scoring every replica's
        cache for warm-prefix overlap must not count as use, or load
        probing itself would distort eviction order (an entry probed by
        every placement decision would look permanently hot).  Applies
        the same ``min_prefix`` / ``len - 1`` caps as ``lookup`` so the
        probe exactly predicts the admission-time match."""
        toks = [int(t) for t in prompt]
        with self._lock:
            self._stats.peeks += 1
            p, entry = self.index.match(toks)
            p = min(p, len(toks) - 1)
            if entry is None or p < self.config.min_prefix:
                return 0, None
            if (entry.deadline is not None
                    and entry.deadline < time.monotonic()):
                # expired but not yet swept (peek never mutates): report
                # the miss the next lookup would see
                return 0, None
            return p, entry

    # ------------------------------------------------------------ insert

    def insert(self, prompt, ks: np.ndarray, vs: np.ndarray,
               hs: np.ndarray) -> bool:
        """Store ``prompt``'s blocks (host copies are taken).  Skipped
        when an existing entry already covers the whole prompt, or the
        prompt is shorter than ``min_prefix``.  Evicts LRU entries when
        over ``capacity_tokens``."""
        toks = tuple(int(t) for t in prompt)
        if len(toks) < self.config.min_prefix:
            return False
        if len(toks) > self.config.capacity_tokens:
            return False
        with self._lock:
            self._sweep_ttl_locked()
            covered, _ = self.index.match(list(toks))
            if covered == len(toks):
                return False
            entry = PrefixEntry(toks, np.array(ks, np.float32, copy=True),
                                np.array(vs, np.float32, copy=True),
                                np.array(hs, np.float32, copy=True))
            self._clock += 1
            entry.last_used = self._clock
            if self.config.ttl_s is not None:
                entry.deadline = time.monotonic() + self.config.ttl_s
            self.index.insert(toks, entry)
            self._entries[toks] = entry
            self._tokens_stored += len(toks)
            self._stats.tokens_inserted += len(toks)
            self._evict_locked()
            return True

    def invalidate(self, tokens) -> bool:
        """Evict the entry ending exactly at ``tokens`` — the poisoned-
        node path of the degradation ladder: when restoring an entry's
        blocks fails, the serving engine falls back to cold prefill and
        invalidates the entry so later lookups don't keep rediscovering
        a bad block.  Returns whether an entry was removed."""
        toks = tuple(int(t) for t in tokens)
        with self._lock:
            entry = self._entries.pop(toks, None)
            if entry is None:
                return False
            self.index.remove(toks)
            self._tokens_stored -= len(toks)
            self._stats.invalidations += 1
            return True

    def _sweep_ttl_locked(self) -> None:
        """Drop every entry idle past ``ttl_s`` (no-op without a TTL).
        Runs under the lock at each insert/lookup — the sweep is O(n)
        in entries but entries are few and the blocks dominate cost."""
        if self.config.ttl_s is None:
            return
        now = time.monotonic()
        dead = [e for e in self._entries.values()
                if e.deadline is not None and e.deadline < now]
        for e in dead:
            self.index.remove(e.tokens)
            del self._entries[e.tokens]
            self._tokens_stored -= len(e.tokens)
            self._stats.ttl_evictions += 1

    def _evict_locked(self) -> None:
        while (self._tokens_stored > self.config.capacity_tokens
               and len(self._entries) > 1):
            victim = min(self._entries.values(),
                         key=lambda e: e.last_used)
            self.index.remove(victim.tokens)
            del self._entries[victim.tokens]
            self._tokens_stored -= len(victim.tokens)
            self._stats.evictions += 1

    # ------------------------------------------------------------- stats

    @property
    def stats(self) -> PrefixCacheStats:
        with self._lock:
            s = dataclasses.replace(self._stats)
            s.entries = len(self._entries)
            s.tokens_stored = self._tokens_stored
            s.bytes_stored = sum(e.nbytes for e in self._entries.values())
            return s

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
