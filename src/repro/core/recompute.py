"""Exact KV-cache partial recomputation (the paper's core mechanism), as
composable JAX ops plus a whole-model offload decode step.

Host-side state per layer (column-by-column schedule, paper §3.2):
  - attention-input activations  H[0:s']  (b, s', h)   [normed layer input]
  - KV cache                     KV[l:s'] (b, s'-l, KV, dh)
Each decode step receives X[0:l] = H[0:l] and KV[l:s']; the device
recomputes KV[0:l] = rope(H[0:l] W_K), ... and runs exact attention over
[recomputed | streamed | new-token] segments. No approximation: tested
against the resident-cache decode path.

`kvpr_decode_step` is the jit/dry-run entry point: its *inputs* are the
streamed tensors, so the compiled graph shows the paper's transfer/compute
structure (fewer host bytes in, extra recompute FLOPs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import shard

Array = jax.Array


def recompute_kv(h_resident: Array, wk: Array, wv: Array,
                 cfg: ModelConfig, pos_offset: int = 0,
                 use_kernel: bool = False) -> Tuple[Array, Array]:
    """Recompute K/V for resident activations (paper Eq. 7).

    h_resident: (b, l, h) attention-input activations for tokens
    [pos_offset, pos_offset + l). Returns k, v: (b, l, KV, dh), roped.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        k, v = kops.kv_recompute(h_resident, wk, wv)
    else:
        k = jnp.einsum("blh,hnd->blnd", h_resident, wk)
        v = jnp.einsum("blh,hnd->blnd", h_resident, wv)
    if cfg.pos_embedding == "rope":
        l = h_resident.shape[1]
        positions = jnp.arange(l) + pos_offset
        k = L.apply_rope(k, jnp.broadcast_to(positions,
                                             (h_resident.shape[0], l)),
                         cfg.rope_theta)
    return k, v


def merged_decode_attention(q: Array, segments, pos: Array,
                            use_kernel: bool = False) -> Array:
    """Exact single-token GQA attention over a list of KV segments
    [(k, v, valid_len_or_None), ...] without materializing the merged
    cache. q: (b, 1, H, dh). Softmax is computed jointly via the
    standard two-pass (max, sum) combine across segments.

    `valid` may be a scalar (uniform batch) or a (b,) vector of per-slot
    valid lengths — the latter is what ragged continuous batching needs:
    each slot attends over exactly its own prefix of the padded segment.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.two_segment_decode_attention(q, segments, pos)
    b, _, H, dh = q.shape
    KV = segments[0][0].shape[2]
    g = H // KV
    qg = q.reshape(b, KV, g, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    maxes, exps, vals = [], [], []
    for (k, v, valid) in segments:
        s = k.shape[1]
        if s == 0:  # empty segment (e.g. split l=0 -> nothing recomputed)
            continue
        scores = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
        scores = scores * scale
        if valid is not None:
            valid = jnp.asarray(valid)
            if valid.ndim == 0:
                mask = (jnp.arange(s) < valid)[None, None, None, :]
            else:                       # (b,) per-slot lengths
                mask = (jnp.arange(s)[None, :]
                        < valid[:, None])[:, None, None, :]
            scores = jnp.where(mask, scores, L.NEG_INF)
        maxes.append(jnp.max(scores, axis=-1, keepdims=True))
        exps.append(scores)
        vals.append(v)

    m = maxes[0]
    for i in range(1, len(maxes)):
        m = jnp.maximum(m, maxes[i])
    num = jnp.zeros((b, KV, g, dh), jnp.float32)
    den = jnp.zeros((b, KV, g, 1), jnp.float32)
    for scores, v in zip(exps, vals):
        e = jnp.exp(scores - m)
        num = num + jnp.einsum("bkgs,bskd->bkgd", e,
                               v.astype(jnp.float32))
        den = den + jnp.sum(e, axis=-1, keepdims=True)
    out = num / den
    return out.reshape(b, 1, H, dh)


def kvpr_decode_step(params, cfg: ModelConfig, token: Array, pos: Array,
                     h_resident: Array, k_streamed: Array,
                     v_streamed: Array, split_l: int,
                     use_kernel: bool = False
                     ) -> Tuple[Array, Array, Array, Array]:
    """Whole-model offload decode step for dense-family archs.

    token      : (b, 1) new token ids
    pos        : () current position (= s', number of cached tokens)
    h_resident : (L, b, l, h)  attention-input activations, tokens [0, l)
    k_streamed : (L, b, S_str, KV, dh) KV for tokens [l, s'), padded to
                 a static S_str; valid length = pos - split_l
    returns (logits (b,1,V), k_new (L,b,1,KV,dh), v_new, h_new (L,b,1,h))
    — the new-token KV and activations go back to host storage.
    """
    b = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x = L.embed(token, params["embed"], cfg, positions[0])
    valid_streamed = pos - split_l

    def body(x, inp):
        lp, h_res, k_str, v_str = inp
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wq"])
        k_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wk"])
        v_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wv"])
        if cfg.pos_embedding == "rope":
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new = L.apply_rope(k_new, positions, cfg.rope_theta)
        # paper Eq. 7: recompute the first-l KV from activations
        k_rec, v_rec = recompute_kv(h_res, lp["attn"]["wk"],
                                    lp["attn"]["wv"], cfg, pos_offset=0,
                                    use_kernel=use_kernel)
        out = merged_decode_attention(
            q,
            [(k_rec, v_rec, None),
             (k_str, v_str, valid_streamed),
             (k_new, v_new, None)],
            pos, use_kernel=use_kernel)
        out = out.reshape(b, 1, cfg.num_heads * cfg.dh).astype(x.dtype)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, (k_new, v_new, h)

    x, (k_new, v_new, h_new) = jax.lax.scan(
        body, x, (params["layers"], h_resident, k_streamed, v_streamed))
    x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(x, params["embed"], cfg)
    return logits, k_new, v_new, h_new
