"""Group-wise 4-bit KV quantization for the offload stream (paper §4.4,
made executable; the paper cites FlexGen's group-wise scheme).

Quantize on the HOST when KV pairs are stored (they were just computed on
the device, so quantization error enters exactly once), stream packed
codes + scales over the link (≈¼ of bf16 / ⅛ of f32 bytes), dequantize
on the DEVICE — either as a standalone op or fused inside the attention
kernel (kernels/kv_dequant_attention.py).

Layout (group size G along the head dim dh):
  packed (..., dh//2) uint8 — code i lives at byte i//2; even i in the
                              low nibble, odd i in the high nibble
  scale  (..., dh//G) f32
  zero   (..., dh//G) f32   — dequant: x ≈ code * scale + zero

Both numpy (host store) and jnp (device/oracle) implementations; the
numpy path is what core/runtime.py calls per decode step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class QuantizedKV(NamedTuple):
    packed: np.ndarray   # uint8 (..., S, dh//2)
    scale: np.ndarray    # f32   (..., S, dh//G)
    zero: np.ndarray     # f32   (..., S, dh//G)

    @property
    def nbytes(self) -> int:
        return self.packed.nbytes + self.scale.nbytes + self.zero.nbytes


def quantize_np(x: np.ndarray, group: int = 32) -> QuantizedKV:
    """x: (..., dh) f32/bf16 -> group-wise asymmetric int4."""
    dh = x.shape[-1]
    assert dh % group == 0 and dh % 2 == 0
    g = x.reshape(*x.shape[:-1], dh // group, group).astype(np.float32)
    lo = g.min(axis=-1)
    hi = g.max(axis=-1)
    scale = np.maximum((hi - lo) / 15.0, 1e-8)
    codes = np.clip(np.rint((g - lo[..., None]) / scale[..., None]),
                    0, 15).astype(np.uint8)
    codes = codes.reshape(*x.shape[:-1], dh)
    packed = (codes[..., 0::2] | (codes[..., 1::2] << 4))
    return QuantizedKV(packed, scale.reshape(*x.shape[:-1], dh // group),
                       lo.reshape(*x.shape[:-1], dh // group))


def dequantize_np(q: QuantizedKV, group: int = 32) -> np.ndarray:
    dh = q.packed.shape[-1] * 2
    codes = np.empty((*q.packed.shape[:-1], dh), np.uint8)
    codes[..., 0::2] = q.packed & 0xF
    codes[..., 1::2] = q.packed >> 4
    s = np.repeat(q.scale, group, axis=-1)
    z = np.repeat(q.zero, group, axis=-1)
    return codes.astype(np.float32) * s + z


def quantize_jnp(x: Array, group: int = 32
                 ) -> Tuple[Array, Array, Array]:
    dh = x.shape[-1]
    g = x.reshape(*x.shape[:-1], dh // group, group).astype(jnp.float32)
    lo = g.min(axis=-1)
    hi = g.max(axis=-1)
    scale = jnp.maximum((hi - lo) / 15.0, 1e-8)
    codes = jnp.clip(jnp.rint((g - lo[..., None]) / scale[..., None]),
                     0, 15).astype(jnp.uint8)
    codes = codes.reshape(*x.shape[:-1], dh)
    packed = codes[..., 0::2] | (codes[..., 1::2] << 4)
    return packed, scale, lo


def dequantize_jnp(packed: Array, scale: Array, zero: Array,
                   group: int = 32, dtype=jnp.float32) -> Array:
    dh = packed.shape[-1] * 2
    low = (packed & 0xF).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)
    codes = jnp.stack([low, high], axis=-1).reshape(*packed.shape[:-1], dh)
    s = jnp.repeat(scale, group, axis=-1)
    z = jnp.repeat(zero, group, axis=-1)
    return (codes * s + z).astype(dtype)
