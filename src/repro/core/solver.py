"""KVPR scheduler: optimal KV-cache split point (paper §3.2, Eq. 10-11).

    min_l   t(l) = M_X(l)/v_com + max( N(l)/v_gpu , M_KV(l:s')/v_com )
    s.t.    0 <= l <= bound

The objective is piecewise linear in the single integer variable l:
 - the recompute term N(l)/v_gpu increases in l,
 - the KV transfer term M_KV/v_com decreases in l,
so t(l) is convex; the optimum is at the crossing of the two max() arms
(or at a boundary). We solve in closed form and refine on integers, then
round DOWN to a multiple of `align` (TPU adaptation: the Pallas recompute
kernel wants MXU-aligned token counts; see DESIGN.md §2).

Row-by-row schedule = same problem without the activation-transfer term
(activations for the current batch are already on-device, paper §3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost_model import (HardwareProfile, Workload,
                                   chunk_compute_flops,
                                   chunk_writeback_bytes, layer_times,
                                   tier_layer_times)


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    l: int                      # tokens recomputed on the accelerator
    t_total: float              # predicted per-layer time (s)
    t_recomp: float
    t_kv: float
    t_act: float
    schedule: str               # "row" | "column"
    bound: int                  # upper bound used (prompt len s for column)
    # Pad geometry, filled by the ExecutionPlan: static shapes for the
    # jitted layer step, rounded up to the plan's pad bucket so the XLA
    # trace cache converges to O(#buckets) entries instead of retracing
    # as the streamed length grows token by token.  Valid lengths are
    # masked exactly in attention, so padding never changes tokens.
    l_pad: int = 0              # recompute buffer length (>= l)
    s_pad: int = 0              # streamed KV buffer length (>= s' - l)

    @classmethod
    def flexgen(cls, seq_len: int, schedule: str = "row") -> "SplitDecision":
        """The no-recompute decision (full KV transfer baseline)."""
        return cls(l=0, t_total=0.0, t_recomp=0.0, t_kv=0.0, t_act=0.0,
                   schedule=schedule, bound=seq_len)


def _clamp(x: float, lo: float, hi: float) -> float:
    return max(lo, min(hi, x))


def optimal_split(wl: Workload, hw: HardwareProfile,
                  schedule: str = "column",
                  bound: Optional[int] = None,
                  align: int = 1) -> SplitDecision:
    """Closed-form + integer refinement solution of Eq. 11."""
    include_act = schedule == "column"
    s = wl.seq_len
    bound = min(bound if bound is not None else s, s)

    B = wl.batch
    p = wl.dtype_bytes
    p_kv = wl.kv_el_bytes        # compressed streams move fewer bytes/el
    h = wl.d_model
    kv = wl.kv_dim

    # t(l) = include_act * (B l h p)/v_com
    #        + max( 4 B l h kv / v_gpu , 2 B (s-l) kv p_kv / v_com )
    # crossing point of the two max arms:
    #   4 B h kv / v_gpu * l = 2 B kv p_kv / v_com * (s - l)
    a = 4 * B * h * kv / hw.v_gpu              # recompute slope
    c = 2 * B * kv * p_kv / hw.v_com           # kv transfer slope
    l_cross = c * s / (a + c) if (a + c) > 0 else 0.0

    # The act-transfer term grows in l, so if it is included the optimum can
    # sit below the crossing: for l < l_cross, t = act(l) + kv(s-l), slope
    # = B h p / v_com - c. If that slope is >= 0 the optimum is l = 0.
    act_slope = (B * h * p / hw.v_com) if include_act else 0.0
    if act_slope - c >= 0:
        cand = [0.0]
    else:
        cand = [l_cross]
    # beyond the crossing slope is act_slope + a > 0, never better.

    best = None
    seen = set()
    for lc in cand:
        base = int(_clamp(lc, 0, bound))
        for li in {0, bound,
                   (base // align) * align,
                   min(((base // align) + 1) * align, bound),
                   base, max(base - 1, 0), min(base + 1, bound)}:
            li = max(0, min(li, bound))
            if align > 1:
                li = (li // align) * align
            if li in seen:
                continue
            seen.add(li)
            t = layer_times(wl, hw, li, include_act)
            if best is None or t["total"] < best[1]["total"]:
                best = (li, t)

    li, t = best
    return SplitDecision(l=li, t_total=t["total"], t_recomp=t["t_recomp"],
                         t_kv=t["t_kv"], t_act=t["t_act"],
                         schedule=schedule, bound=bound)


def optimal_shard_split(wl: Workload, hw: HardwareProfile, shards: int,
                        schedule: str = "column",
                        bound: Optional[int] = None,
                        align: int = 1) -> SplitDecision:
    """Eq. 11 solved from ONE shard's point of view on a ``shards``-way
    tensor-parallel mesh: the shard recomputes its own KV head-slice
    (FLOPs and streamed KV bytes divide by ``shards`` via
    ``Workload.per_shard``) but shares the host link with every other
    shard's concurrent stream (bandwidth divides via
    ``HardwareProfile.per_shard``) and still needs the FULL activation
    window.  Net effect on the arms: the streamed-KV time is UNCHANGED
    (1/shards the bytes over 1/shards the bandwidth) while the
    recompute time divides by ``shards``, so the crossing — and with
    it the optimal l — moves toward MORE recomputation as the mesh
    grows; meanwhile the (replicated) activation upload crosses the
    shard's narrowed link, which is what pushes column-schedule
    sharded splits toward l = 0 instead.  At ``shards = 1`` both
    ``per_shard`` calls return their inputs unchanged, so this IS
    ``optimal_split``, bit for bit."""
    return optimal_split(wl.per_shard(shards), hw.per_shard(shards),
                         schedule=schedule, bound=bound, align=align)


# -------------------------------------------------------- chunked prefill
# The third plan kind (after the decode split and the admission-time
# restore split): pick the prefill chunk width c so chunk i's device
# compute overlaps chunk i-1's host write-back.  Both steady-state terms
# are ~linear in c, so the pipeline's per-token rate is fixed at
# max(compute, write-back); what the choice of c actually trades is the
# fixed dispatch overhead paid once per chunk (favoring LARGE chunks)
# against the un-overlapped pipeline fill (first chunk's compute) and
# drain (last chunk's write-back) plus the quadratic attention term
# (favoring SMALL chunks).


@dataclasses.dataclass(frozen=True)
class ChunkDecision:
    """Chunk width for a pipelined (streamed write-back) prefill."""
    chunk: int                  # chosen chunk width (tokens)
    n_chunks: int
    t_total: float              # predicted pipelined prefill time (s)
    t_monolithic: float         # c = n endpoint: compute then write back
    t_compute: float            # total device compute across chunks
    t_writeback: float          # total host write-back across chunks
    bound: int                  # prompt length n


def chunk_pipeline_time(n: int, c: int, wl: Workload, hw: HardwareProfile,
                        n_layers: int, d_ff: int,
                        overhead: Optional[float] = None,
                        mlp_mults: int = 3) -> dict:
    """Predicted wall time of prefilling ``n`` tokens in ``c``-token
    chunks with each finished chunk's write-back overlapping the next
    chunk's compute:

        T = t_comp(1) + sum_{i>=2} max(t_comp(i), t_wb(i-1)) + t_wb(m)

    plus one dispatch overhead per chunk (charged inside t_comp)."""
    o = hw.dispatch_overhead if overhead is None else overhead
    c = max(1, min(int(c), int(n)))
    widths = [c] * (n // c) + ([n % c] if n % c else [])
    t_comps, t_wbs, prefix = [], [], 0
    for w in widths:
        t_comps.append(chunk_compute_flops(wl, n_layers, d_ff, prefix, w,
                                           mlp_mults) / hw.v_gpu + o)
        t_wbs.append(chunk_writeback_bytes(wl, n_layers, w) / hw.v_com)
        prefix += w
    total = t_comps[0]
    for i in range(1, len(widths)):
        total += max(t_comps[i], t_wbs[i - 1])
    total += t_wbs[-1]
    return {"total": total, "t_compute": sum(t_comps),
            "t_writeback": sum(t_wbs), "n_chunks": len(widths)}


def optimal_chunk(n: int, wl: Workload, hw: HardwareProfile,
                  n_layers: int, d_ff: int, align: int = 16,
                  min_chunk: int = 16,
                  overhead: Optional[float] = None,
                  mlp_mults: int = 3) -> ChunkDecision:
    """Pick the chunk width minimizing ``chunk_pipeline_time`` over
    power-of-two candidates in [min_chunk, n] (plus n itself — the
    monolithic endpoint, so chunking is never predicted to lose).
    Candidates are rounded down to ``align`` (the same MXU-alignment
    knob the decode split honors)."""
    n = int(n)
    if n <= 0:
        return ChunkDecision(chunk=0, n_chunks=0, t_total=0.0,
                             t_monolithic=0.0, t_compute=0.0,
                             t_writeback=0.0, bound=0)
    min_chunk = max(1, min(min_chunk, n))
    cands = {n, min_chunk}
    c = min_chunk
    while c < n:
        cands.add(c)
        c *= 2
    if align > 1:
        cands = {max(min((cc // align) * align, n), min(align, n))
                 for cc in cands} | {n}
    best = None
    for cc in sorted(cands):
        t = chunk_pipeline_time(n, cc, wl, hw, n_layers, d_ff, overhead,
                                mlp_mults)
        if best is None or t["total"] < best[1]["total"]:
            best = (cc, t)
    mono = chunk_pipeline_time(n, n, wl, hw, n_layers, d_ff, overhead,
                               mlp_mults)
    cc, t = best
    return ChunkDecision(chunk=cc, n_chunks=t["n_chunks"],
                         t_total=t["total"], t_monolithic=mono["total"],
                         t_compute=t["t_compute"],
                         t_writeback=t["t_writeback"], bound=n)


# ---------------------------------------------------------- tiered split
# The fourth plan kind: the same transfer-vs-recompute LP solved over a
# bandwidth HIERARCHY instead of one link.  With the leading
# ``disk_tokens`` of the prefix demoted to a slow tier, the streamed arm
# gains a second (steeper) segment below l = d — every recomputed token
# under d saves BOTH link crossings — so t(l) is still piecewise-linear
# convex, now with (at most) two crossings to check: one per regime,
# split at the l = d breakpoint.


@dataclasses.dataclass(frozen=True)
class TierSplitDecision:
    """Split for a fetch whose prefix partially lives on a slow tier."""
    l: int                      # tokens recomputed on the accelerator
    disk_tokens: int            # leading demoted tokens (the d input)
    paged_tokens: int           # demoted tokens the fetch must page in
    t_total: float              # predicted per-layer time (s)
    t_recomp: float
    t_kv: float                 # full streamed arm (host + disk shares)
    t_disk: float               # the disk->host share of t_kv
    bound: int


def optimal_tier_split(wl: Workload, hw: HardwareProfile,
                       disk_tokens: int,
                       disk_read_bandwidth: float,
                       disk_bytes_per_el: Optional[float] = None,
                       bound: Optional[int] = None,
                       align: int = 1) -> TierSplitDecision:
    """Closed-form-per-regime + integer refinement over the two-rung
    ladder.  Degenerates exactly to ``optimal_split`` (row schedule)
    at ``disk_tokens = 0``."""
    s = wl.seq_len
    bound = min(bound if bound is not None else s, s)
    d = max(0, min(int(disk_tokens), bound))

    B = wl.batch
    p_kv = wl.kv_el_bytes
    p_d = p_kv if disk_bytes_per_el is None else disk_bytes_per_el
    a = 4 * B * wl.d_model * wl.kv_dim / hw.v_gpu    # recompute slope
    c = 2 * B * wl.kv_dim * p_kv / hw.v_com          # host-link slope
    c_d = 2 * B * wl.kv_dim * p_d / float(disk_read_bandwidth)

    cand = {0.0, float(d), float(bound)}
    # regime l <= d: a*l = c*(s-l) + c_d*(d-l)
    if a + c + c_d > 0:
        cand.add(_clamp((c * s + c_d * d) / (a + c + c_d), 0, d))
    # regime l >= d: a*l = c*(s-l)
    if a + c > 0:
        cand.add(_clamp(c * s / (a + c), d, bound))

    best = None
    seen = set()
    for lc in cand:
        base = int(lc)
        for li in {base, max(base - 1, 0), min(base + 1, bound),
                   (base // align) * align,
                   min(((base // align) + 1) * align, bound)}:
            li = max(0, min(li, bound))
            if align > 1:
                li = (li // align) * align
            if li in seen:
                continue
            seen.add(li)
            t = tier_layer_times(wl, hw, li, d, disk_read_bandwidth,
                                 disk_bytes_per_el)
            if best is None or t["total"] < best[1]["total"]:
                best = (li, t)

    li, t = best
    return TierSplitDecision(
        l=li, disk_tokens=d, paged_tokens=max(0, d - li),
        t_total=t["total"], t_recomp=t["t_recomp"], t_kv=t["t_kv"],
        t_disk=t["t_disk"], bound=bound)


def brute_force_tier_split(wl: Workload, hw: HardwareProfile,
                           disk_tokens: int,
                           disk_read_bandwidth: float,
                           disk_bytes_per_el: Optional[float] = None,
                           bound: Optional[int] = None,
                           align: int = 1) -> TierSplitDecision:
    """O(s) exhaustive reference used by property tests."""
    s = wl.seq_len
    bound = min(bound if bound is not None else s, s)
    d = max(0, min(int(disk_tokens), bound))
    best = None
    for li in range(0, bound + 1, align):
        t = tier_layer_times(wl, hw, li, d, disk_read_bandwidth,
                             disk_bytes_per_el)
        if best is None or t["total"] < best[1]["total"]:
            best = (li, t)
    li, t = best
    return TierSplitDecision(
        l=li, disk_tokens=d, paged_tokens=max(0, d - li),
        t_total=t["total"], t_recomp=t["t_recomp"], t_kv=t["t_kv"],
        t_disk=t["t_disk"], bound=bound)


def brute_force_split(wl: Workload, hw: HardwareProfile,
                      schedule: str = "column",
                      bound: Optional[int] = None,
                      align: int = 1) -> SplitDecision:
    """O(s) exhaustive reference used by property tests."""
    include_act = schedule == "column"
    bound = min(bound if bound is not None else wl.seq_len, wl.seq_len)
    best = None
    for li in range(0, bound + 1, align):
        t = layer_times(wl, hw, li, include_act)
        if best is None or t["total"] < best[1]["total"]:
            best = (li, t)
    li, t = best
    return SplitDecision(l=li, t_total=t["total"], t_recomp=t["t_recomp"],
                         t_kv=t["t_kv"], t_act=t["t_act"],
                         schedule=schedule, bound=bound)
