"""KVPR scheduler: optimal KV-cache split point (paper §3.2, Eq. 10-11).

    min_l   t(l) = M_X(l)/v_com + max( N(l)/v_gpu , M_KV(l:s')/v_com )
    s.t.    0 <= l <= bound

The objective is piecewise linear in the single integer variable l:
 - the recompute term N(l)/v_gpu increases in l,
 - the KV transfer term M_KV/v_com decreases in l,
so t(l) is convex; the optimum is at the crossing of the two max() arms
(or at a boundary). We solve in closed form and refine on integers, then
round DOWN to a multiple of `align` (TPU adaptation: the Pallas recompute
kernel wants MXU-aligned token counts; see DESIGN.md §2).

Row-by-row schedule = same problem without the activation-transfer term
(activations for the current batch are already on-device, paper §3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost_model import HardwareProfile, Workload, layer_times


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    l: int                      # tokens recomputed on the accelerator
    t_total: float              # predicted per-layer time (s)
    t_recomp: float
    t_kv: float
    t_act: float
    schedule: str               # "row" | "column"
    bound: int                  # upper bound used (prompt len s for column)
    # Pad geometry, filled by the ExecutionPlan: static shapes for the
    # jitted layer step, rounded up to the plan's pad bucket so the XLA
    # trace cache converges to O(#buckets) entries instead of retracing
    # as the streamed length grows token by token.  Valid lengths are
    # masked exactly in attention, so padding never changes tokens.
    l_pad: int = 0              # recompute buffer length (>= l)
    s_pad: int = 0              # streamed KV buffer length (>= s' - l)

    @classmethod
    def flexgen(cls, seq_len: int, schedule: str = "row") -> "SplitDecision":
        """The no-recompute decision (full KV transfer baseline)."""
        return cls(l=0, t_total=0.0, t_recomp=0.0, t_kv=0.0, t_act=0.0,
                   schedule=schedule, bound=seq_len)


def _clamp(x: float, lo: float, hi: float) -> float:
    return max(lo, min(hi, x))


def optimal_split(wl: Workload, hw: HardwareProfile,
                  schedule: str = "column",
                  bound: Optional[int] = None,
                  align: int = 1) -> SplitDecision:
    """Closed-form + integer refinement solution of Eq. 11."""
    include_act = schedule == "column"
    s = wl.seq_len
    bound = min(bound if bound is not None else s, s)

    B = wl.batch
    p = wl.dtype_bytes
    p_kv = wl.kv_el_bytes        # compressed streams move fewer bytes/el
    h = wl.d_model
    kv = wl.kv_dim

    # t(l) = include_act * (B l h p)/v_com
    #        + max( 4 B l h kv / v_gpu , 2 B (s-l) kv p_kv / v_com )
    # crossing point of the two max arms:
    #   4 B h kv / v_gpu * l = 2 B kv p_kv / v_com * (s - l)
    a = 4 * B * h * kv / hw.v_gpu              # recompute slope
    c = 2 * B * kv * p_kv / hw.v_com           # kv transfer slope
    l_cross = c * s / (a + c) if (a + c) > 0 else 0.0

    # The act-transfer term grows in l, so if it is included the optimum can
    # sit below the crossing: for l < l_cross, t = act(l) + kv(s-l), slope
    # = B h p / v_com - c. If that slope is >= 0 the optimum is l = 0.
    act_slope = (B * h * p / hw.v_com) if include_act else 0.0
    if act_slope - c >= 0:
        cand = [0.0]
    else:
        cand = [l_cross]
    # beyond the crossing slope is act_slope + a > 0, never better.

    best = None
    seen = set()
    for lc in cand:
        base = int(_clamp(lc, 0, bound))
        for li in {0, bound,
                   (base // align) * align,
                   min(((base // align) + 1) * align, bound),
                   base, max(base - 1, 0), min(base + 1, bound)}:
            li = max(0, min(li, bound))
            if align > 1:
                li = (li // align) * align
            if li in seen:
                continue
            seen.add(li)
            t = layer_times(wl, hw, li, include_act)
            if best is None or t["total"] < best[1]["total"]:
                best = (li, t)

    li, t = best
    return SplitDecision(l=li, t_total=t["total"], t_recomp=t["t_recomp"],
                         t_kv=t["t_kv"], t_act=t["t_act"],
                         schedule=schedule, bound=bound)


def brute_force_split(wl: Workload, hw: HardwareProfile,
                      schedule: str = "column",
                      bound: Optional[int] = None,
                      align: int = 1) -> SplitDecision:
    """O(s) exhaustive reference used by property tests."""
    include_act = schedule == "column"
    bound = min(bound if bound is not None else wl.seq_len, wl.seq_len)
    best = None
    for li in range(0, bound + 1, align):
        t = layer_times(wl, hw, li, include_act)
        if best is None or t["total"] < best[1]["total"]:
            best = (li, t)
    li, t = best
    return SplitDecision(l=li, t_total=t["total"], t_recomp=t["t_recomp"],
                         t_kv=t["t_kv"], t_act=t["t_act"],
                         schedule=schedule, bound=bound)
