"""Fault model for the offload pipeline: the typed error taxonomy and
the deterministic fault-injection policy.

KVPR's premise is a GPU kept busy while KV streams over an unreliable,
contended PCIe link — so the runtime has to assume transfers CAN stall,
fail transiently, or die outright, and every failure mode has to be
reproducible in a test.  This module supplies both halves:

  - the **error taxonomy** the fence/transfer machinery raises
    (``TransferError`` and its subclasses) — callers recover by TYPE:
    transient errors are retried, stalls abort the step within the
    configured deadline, write-back errors poison the step (the host
    copy is incomplete, no fallback can reconstruct it), and
    per-request faults are contained to their owning request;
  - the **``FaultPolicy``** injection hook threaded through
    ``TransferEngine`` / ``HostKVStore`` / the serving engine: seeded,
    thread-safe, and able to express injected delays, slow-link
    throttling, transient and persistent I/O failures, hard
    per-request failures, kernel-launch failures, and a
    dead-store-thread mode (an op that hangs until released).

The recovery semantics that consume these types live in
``core/runtime.py`` (retries, fence timeouts, degradation ladder) and
``serving/api.py`` (per-request isolation); docs/robustness.md is the
narrative reference.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, FrozenSet, Optional

__all__ = [
    "DiskFullError", "DiskReadError", "FaultPolicy",
    "KernelLaunchError", "RequestFaultError", "TransferError",
    "TransferStallError", "TransientTransferError", "WriteBackError",
]


class TransferError(RuntimeError):
    """Base of every typed offload-pipeline failure."""


class TransientTransferError(TransferError):
    """A retryable I/O failure (contended link, spurious copy error).
    The transfer engine retries these with exponential backoff; one
    that survives every retry escalates to its caller."""


class TransferStallError(TransferError):
    """A fence or fetch exceeded its deadline (``fence_timeout_s``):
    the store/copy pipeline is stalled or dead.  Raised by the fence
    watchdog instead of hanging; never retried and never degraded —
    the step aborts and the error reaches the caller."""


class WriteBackError(TransferError):
    """A host write-back failed after retries: the host copy of the KV
    cache / activations is now incomplete, so NO fallback (recompute
    included) can reconstruct the lost state.  Fence waits wrap
    store-side errors in this type so the runtime knows degradation is
    unsound and aborts instead."""


class DiskReadError(TransientTransferError):
    """A disk-tier block read failed (bad sector, torn mmap page,
    injected ``disk_read_fail_rate``).  Subclasses
    ``TransientTransferError`` on purpose: the transfer engine retries
    it with the same backoff as any transient link failure, and one
    that survives every retry escalates through the SAME degradation
    ladder — the step falls back to the l = p full-recompute endpoint
    (activations are pinned in the host tier, so no disk read is on
    the fallback path) instead of hanging or aborting."""


class DiskFullError(TransferError):
    """The disk tier ran out of configured capacity during a demotion.
    Benign by construction: the block simply STAYS in host DRAM (the
    demotion is skipped and counted in ``TieredStoreStats.
    demote_failures``) — correctness never depends on a demotion
    happening, so this error never aborts a step.  Raised to callers
    only by explicit disk-tier writes, never from the decode path."""


class RequestFaultError(TransferError):
    """A hard failure attributable to ONE request (its admission
    write-back, restore, or tagged transfer).  The serving engine
    contains it: that request finishes with ``finish_reason="error"``
    and the rest of the batch continues token-identically."""

    def __init__(self, uid: int, op: str = "io"):
        super().__init__(f"injected hard fault for request uid={uid} "
                         f"({op})")
        self.uid = uid
        self.op = op


class KernelLaunchError(RuntimeError):
    """A Pallas kernel failed to trace/compile/launch.  The runtime
    degrades the step to the jnp oracle path (logged once,
    ``StepStats.kernel_path`` reflects it); tokens are identical either
    way."""


@dataclasses.dataclass
class FaultPolicy:
    """Deterministic, seeded fault injection for the offload pipeline.

    Threaded into ``TransferEngine`` (every fetch/store/restore op
    calls ``on_op``), ``OffloadDecodeRuntime`` (``on_kernel_launch``
    before each Pallas step) and the serving engine (``on_admit`` per
    admitted request).  All decisions derive from ``random.Random(
    seed)`` plus per-kind op counters, so a given policy replays the
    same fault sequence every run.  Fields are mutable on purpose:
    tests flip rates mid-scenario (e.g. poison write-backs, then heal
    the link and assert the engine recovered).

    Op kinds: ``"fetch"`` (per-layer KV/activation fetch), ``"store"``
    (decode write-back, chunk write-back, slot fills), ``"restore"``
    (prefix-cache restore), ``"disk_read"`` (tiered-store block
    page-in; injected failures surface as ``DiskReadError``) and
    ``"disk_write"`` (tiered-store demotion; failures skip the
    demotion, the block stays in DRAM).

    dead_store_after: the (n+1)-th store op HANGS (holding the store
    pool's worker) until ``release()`` — the fence watchdog must
    convert that into a ``TransferStallError`` within the configured
    timeout.  ``TransferEngine.close()`` releases the hang so shutdown
    never deadlocks.
    """

    seed: int = 0
    # -- injected latency -------------------------------------------------
    fetch_delay_s: float = 0.0       # added to every fetch op
    store_delay_s: float = 0.0       # added to every store op
    link_bytes_per_s: Optional[float] = None   # slow-link throttle:
    #                                  sleep nbytes/rate per transfer
    # -- transient failures (seeded probability per op) -------------------
    fetch_fail_rate: float = 0.0
    store_fail_rate: float = 0.0
    restore_fail_rate: float = 0.0
    disk_read_fail_rate: float = 0.0   # tiered store: mmap block reads
    disk_write_fail_rate: float = 0.0  # tiered store: demotion writes
    # -- deterministic transient failures: fail the FIRST n ops per kind
    fail_first: Dict[str, int] = dataclasses.field(default_factory=dict)
    # -- hard per-request failures ----------------------------------------
    hard_fail_uids: FrozenSet[int] = frozenset()        # at admission
    hard_fail_store_uids: FrozenSet[int] = frozenset()  # at tagged I/O
    # -- dead store thread: store op #(n+1) hangs until release() ---------
    dead_store_after: Optional[int] = None
    # -- kernel launches: fail the first n launches -----------------------
    kernel_fail_launches: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._rng = random.Random(self.seed)
        self.ops: Dict[str, int] = {}          # ops seen per kind
        self.injected: Dict[str, int] = {}     # faults raised per kind
        self._fail_first_left = dict(self.fail_first)
        self._released = threading.Event()

    # ------------------------------------------------------------- hooks

    def _rate_for(self, kind: str) -> float:
        return {"fetch": self.fetch_fail_rate,
                "store": self.store_fail_rate,
                "restore": self.restore_fail_rate,
                "disk_read": self.disk_read_fail_rate,
                "disk_write": self.disk_write_fail_rate}.get(kind, 0.0)

    def _delay_for(self, kind: str) -> float:
        return (self.store_delay_s if kind == "store"
                else self.fetch_delay_s)

    def _record(self, kind: str) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1

    def on_op(self, kind: str, uid: Optional[int] = None) -> None:
        """Called at the start of every injectable transfer op.  May
        sleep (injected delay), raise ``RequestFaultError`` (hard
        per-request), raise ``TransientTransferError`` (transient), or
        hang until ``release()`` (dead-store mode)."""
        with self._lock:
            n = self.ops.get(kind, 0)
            self.ops[kind] = n + 1
            if uid is not None and uid in self.hard_fail_store_uids:
                self._record(kind)
                raise RequestFaultError(uid, kind)
            hang = (kind == "store"
                    and self.dead_store_after is not None
                    and n >= self.dead_store_after)
            transient = False
            if not hang:
                left = self._fail_first_left.get(kind, 0)
                if left > 0:
                    self._fail_first_left[kind] = left - 1
                    transient = True
                elif (self._rate_for(kind) > 0.0
                      and self._rng.random() < self._rate_for(kind)):
                    transient = True
            if transient or hang:
                self._record(kind)
        if hang:
            # dead store thread: hold this pool worker until the
            # engine is closed (release()).  The fence watchdog turns
            # the resulting stall into TransferStallError.
            self._released.wait()
            return
        if transient:
            if kind == "disk_read":
                raise DiskReadError("injected disk block read failure")
            raise TransientTransferError(
                f"injected transient {kind} failure")
        d = self._delay_for(kind)
        if d > 0.0:
            time.sleep(d)

    def throttle(self, nbytes: int) -> None:
        """Slow-link emulation: charge ``nbytes`` against the injected
        link bandwidth (called by the transfer engine after a copy)."""
        if self.link_bytes_per_s:
            time.sleep(nbytes / float(self.link_bytes_per_s))

    def on_admit(self, uid: int) -> None:
        """Per-request admission hook (every backend, including
        resident ones with no transfer ops): a uid in
        ``hard_fail_uids`` fails hard, containable to that request."""
        if uid in self.hard_fail_uids:
            with self._lock:
                self._record("admit")
            raise RequestFaultError(uid, "admit")

    def on_kernel_launch(self) -> None:
        """Called before each Pallas-path layer step; fails the first
        ``kernel_fail_launches`` launches."""
        with self._lock:
            if self.kernel_fail_launches > 0:
                self.kernel_fail_launches -= 1
                self._record("kernel")
                raise KernelLaunchError("injected kernel launch failure")

    # ----------------------------------------------------------- control

    def release(self) -> None:
        """Un-hang any dead-store threads (idempotent; called by
        ``TransferEngine.close()`` so shutdown never deadlocks)."""
        self._released.set()

    def reset(self) -> None:
        """Restart the deterministic schedule (counters, RNG,
        fail-first budgets, the dead-store release latch)."""
        with self._lock:
            self._rng = random.Random(self.seed)
            self.ops = {}
            self.injected = {}
            self._fail_first_left = dict(self.fail_first)
            self._released = threading.Event()
