"""KVPR runtime module (paper §3.3): an executable host-offload decode
engine with asynchronous streams and double buffering.

The KV cache (and attention-input activations) live in HOST memory
(numpy, emulating CPU DRAM / `pinned_host`). Each decode step streams, per
layer, either
  - the full KV cache                       (baseline / FlexGen mode), or
  - activations[0:l] + KV[l:s']             (KVPR mode, solver-chosen l)
into device arrays while the previous layer computes — a copy-thread pool
emulates the CUDA-stream / DMA engine. On this CPU container "the link" is
memcpy (jax.device_put), whose bandwidth the profiler measures; on TPU the
identical structure maps to host-DMA into HBM with XLA async copies.

Six overlapped flows of paper Alg. 1 and their mapping here:
  load_weight            -> params resident (latency mode) or per-layer put
  load_activation_recompute / load_cache / load_activation
                         -> prefetch_layer() futures (double buffer)
  compute                -> jitted per-layer step
  store_activation / store_cache -> host_store.append() on the pool
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor, Future
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareProfile, Workload
from repro.core.solver import SplitDecision, optimal_split
from repro.core import kvquant as KQ
from repro.core import recompute as RC
from repro.models import layers as L

Array = jax.Array


class HostKVStore:
    """Host-memory (numpy) per-layer KV + activation storage, preallocated
    ("pinned") to max_len so stores are slice writes, not reallocations.

    compress="int4" keeps the KV cache group-wise 4-bit quantized in host
    memory (paper §4.4 / beyond-paper executable path): appends quantize
    once, fetches stream packed codes + scales (≈⅛ of the f32 bytes);
    activations stay exact — the KVPR-recomputed prefix loses nothing.
    """

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 dtype=np.float32, compress: Optional[str] = None,
                 group: int = 32):
        Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                         cfg.d_model)
        self.compress = compress
        self.group = group
        if compress == "int4":
            ng = dh // group
            self.kq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
            self.vq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
        else:
            self.k = np.zeros((Lh, batch, max_len, KV, dh), dtype)
            self.v = np.zeros((Lh, batch, max_len, KV, dh), dtype)
        self.act = np.zeros((Lh, batch, max_len, h), dtype)
        self.len = 0
        self.lock = threading.Lock()

    def _put_kv(self, layer, sl, k: np.ndarray, v: np.ndarray):
        if self.compress == "int4":
            for buf, x in ((self.kq, k), (self.vq, v)):
                q = KQ.quantize_np(x, self.group)
                buf.packed[layer, :, sl] = q.packed
                buf.scale[layer, :, sl] = q.scale
                buf.zero[layer, :, sl] = q.zero
        else:
            self.k[layer, :, sl] = k
            self.v[layer, :, sl] = v

    def append(self, layer: int, k: np.ndarray, v: np.ndarray,
               act: np.ndarray, pos: int):
        self._put_kv(layer, slice(pos, pos + k.shape[1]), k, v)
        self.act[layer, :, pos:pos + act.shape[1]] = act

    def bulk_fill(self, ks, vs, acts, s: int):
        """Fill from prefill outputs: (L, b, s, KV, dh) / (L, b, s, h)."""
        if self.compress == "int4":
            for li in range(ks.shape[0]):
                self._put_kv(li, slice(0, s), ks[li], vs[li])
        else:
            self.k[:, :, :s] = ks
            self.v[:, :, :s] = vs
        self.act[:, :, :s] = acts
        self.len = s


@dataclasses.dataclass
class StepStats:
    t_total: float
    t_wait_transfer: float      # GPU idle waiting on host data
    t_compute: float
    bytes_transferred: int
    split_l: int


class OffloadDecodeRuntime:
    """Decode loop for dense-family models with host-offloaded KV cache.

    mode: "flexgen" (full KV streamed) | "kvpr" (partial recompute).
    The per-layer compute is a single jitted function; transfers for layer
    i+1 are issued while layer i computes (double buffering).
    """

    def __init__(self, cfg: ModelConfig, params, hw: HardwareProfile,
                 mode: str = "kvpr", schedule: str = "row",
                 align: int = 1, n_copy_threads: int = 2,
                 compress: Optional[str] = None, group: int = 32,
                 offload_weights: bool = False,
                 fine_grained: bool = True):
        self.cfg = cfg
        self.params = params
        self.hw = hw
        self.mode = mode
        self.schedule = schedule
        self.align = align
        self.compress = compress
        self.group = group
        # Weight offloading (paper's throughput mode, §3.2/§3.3): layer
        # weights live in host memory and stream per layer. fine_grained
        # (Fig. 5b) issues the W_K/W_V copy FIRST so KV recomputation can
        # begin before W_Q/W_O/FFN arrive; coarse (Fig. 5a) copies the
        # whole layer in one piece.
        self.offload_weights = offload_weights
        self.fine_grained = fine_grained
        if offload_weights:
            n_layers = jax.tree.leaves(params["layers"])[0].shape[0]
            self._host_layers = [
                jax.tree.map(lambda a, i=i: np.asarray(a[i]),
                             params["layers"])
                for i in range(n_layers)]
        self.pool = ThreadPoolExecutor(max_workers=n_copy_threads)
        self._layer_fn = jax.jit(self._layer_step,
                                 static_argnames=("split_l", "s_str"))
        self._bytes = 0

    # ------------------------------------------------------- weight loads

    _KV_KEYS = ("wk", "wv")

    def _fetch_weights_kv(self, layer: int):
        """Stage 1 (fine-grained priority): W_K and W_V only."""
        hl = self._host_layers[layer]
        out = {k: jax.device_put(hl["attn"][k]) for k in self._KV_KEYS}
        return out, sum(a.nbytes for a in out.values())

    def _fetch_weights_rest(self, layer: int):
        """Stage 2: everything except W_K/W_V."""
        hl = self._host_layers[layer]
        rest = {"attn": {k: v for k, v in hl["attn"].items()
                         if k not in self._KV_KEYS},
                **{k: v for k, v in hl.items() if k != "attn"}}
        out = jax.tree.map(jax.device_put, rest)
        return out, sum(a.nbytes for a in jax.tree.leaves(out))

    def _assemble_layer(self, wkv, rest):
        lp = dict(rest)
        lp["attn"] = dict(rest["attn"], **wkv)
        return lp

    # ---------------------------------------------------------- layer step

    def _layer_step(self, x, lp, h_res, k_str, v_str, pos, valid_streamed,
                    split_l: int, s_str: int):
        cfg = self.cfg
        b = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wq"])
        k_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wk"])
        v_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wv"])
        if cfg.pos_embedding == "rope":
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new = L.apply_rope(k_new, positions, cfg.rope_theta)
        segments = []
        if split_l > 0:
            k_rec, v_rec = RC.recompute_kv(h_res, lp["attn"]["wk"],
                                           lp["attn"]["wv"], cfg)
            segments.append((k_rec, v_rec, None))
        if s_str > 0:
            if self.compress == "int4":
                # streamed segment arrives packed; dequantize on device
                # (on TPU this fuses into the attention kernel — see
                # kernels/kv_dequant_attention.py)
                k_str = KQ.dequantize_jnp(*k_str, group=self.group)
                v_str = KQ.dequantize_jnp(*v_str, group=self.group)
            segments.append((k_str, v_str, valid_streamed))
        segments.append((k_new, v_new, None))
        out = RC.merged_decode_attention(q, segments, pos)
        out = out.reshape(b, 1, cfg.num_heads * cfg.dh).astype(x.dtype)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, k_new, v_new, h

    # ----------------------------------------------------------- transfers

    def _fetch_layer(self, store: HostKVStore, layer: int, s_cur: int,
                     split: SplitDecision, s_str: int):
        """Copy host slices to device (the 'PCIe' transfer)."""
        l = split.l
        h_res = jax.device_put(store.act[layer, :, :max(l, 1)])
        sl = slice(l, l + s_str) if s_str else slice(0, 1)
        if self.compress == "int4":
            k_str = tuple(
                jax.device_put(np.ascontiguousarray(b[layer, :, sl]))
                for b in store.kq)
            v_str = tuple(
                jax.device_put(np.ascontiguousarray(b[layer, :, sl]))
                for b in store.vq)
            kv_bytes = sum(a.nbytes for a in k_str + v_str)
        else:
            k_str = jax.device_put(
                np.ascontiguousarray(store.k[layer, :, sl]))
            v_str = jax.device_put(
                np.ascontiguousarray(store.v[layer, :, sl]))
            kv_bytes = k_str.nbytes + v_str.nbytes
        nbytes = (h_res.nbytes if l else 0) + (kv_bytes if s_str else 0)
        return h_res, k_str, v_str, nbytes

    def _split_for(self, s_cur: int) -> SplitDecision:
        cfg = self.cfg
        wl = Workload(batch=self.batch, seq_len=s_cur, d_model=cfg.d_model,
                      kv_dim=cfg.num_kv_heads * cfg.dh, dtype_bytes=4)
        if self.mode == "flexgen":
            return SplitDecision(0, 0, 0, 0, 0, self.schedule, s_cur)
        return optimal_split(wl, self.hw, schedule=self.schedule,
                             align=self.align)

    # -------------------------------------------------------------- decode

    def decode(self, store: HostKVStore, first_token: np.ndarray,
               gen_len: int, pad_to: Optional[int] = None
               ) -> Tuple[np.ndarray, List[StepStats]]:
        """Generate `gen_len` tokens greedily. Returns (tokens, stats)."""
        cfg = self.cfg
        params = self.params
        self.batch = first_token.shape[0]
        token = jnp.asarray(first_token)
        stats: List[StepStats] = []
        out_tokens = []

        for g in range(gen_len):
            s_cur = store.len
            split = self._split_for(s_cur)
            # static streamed length, padded for jit-cache friendliness
            s_str_exact = s_cur - split.l
            s_str = s_str_exact if pad_to is None else \
                min(-(-s_str_exact // pad_to) * pad_to,
                    store.k.shape[2] - split.l)
            t0 = time.perf_counter()
            pos = jnp.asarray(s_cur, jnp.int32)
            positions = jnp.full((self.batch, 1), s_cur, jnp.int32)
            x = L.embed(token, params["embed"], cfg, positions[0])

            t_wait = 0.0
            nbytes_total = 0

            def submit_weights(layer):
                """fine-grained: W_K/W_V first (Fig. 5b); coarse: one
                combined copy (Fig. 5a)."""
                if self.fine_grained:
                    return (self.pool.submit(self._fetch_weights_kv,
                                             layer),
                            self.pool.submit(self._fetch_weights_rest,
                                             layer))
                both = self.pool.submit(
                    lambda l: (self._fetch_weights_kv(l),
                               self._fetch_weights_rest(l)), layer)
                return both, None

            # prefetch layer 0 (weights first when offloaded — they gate
            # recomputation; then the KV/activation stream)
            w_fut = submit_weights(0) if self.offload_weights else None
            fut = self.pool.submit(self._fetch_layer, store, 0, s_cur,
                                   split, s_str)
            new_kv = []
            for li in range(cfg.num_layers):
                tw0 = time.perf_counter()
                if self.offload_weights:
                    if self.fine_grained:
                        (wkv, nb_kv) = w_fut[0].result()
                        (rest, nb_r) = w_fut[1].result()
                    else:
                        (wkv, nb_kv), (rest, nb_r) = w_fut[0].result()
                    lp = self._assemble_layer(wkv, rest)
                    nbytes_total += nb_kv + nb_r
                else:
                    lp = jax.tree.map(lambda a: a[li], params["layers"])
                h_res, k_str, v_str, nb = fut.result()
                t_wait += time.perf_counter() - tw0
                nbytes_total += nb
                if li + 1 < cfg.num_layers:
                    if self.offload_weights:
                        w_fut = submit_weights(li + 1)
                    fut = self.pool.submit(self._fetch_layer, store, li + 1,
                                           s_cur, split, s_str)
                x, k_new, v_new, h_new = self._layer_fn(
                    x, lp, h_res, k_str, v_str, pos,
                    jnp.asarray(s_str_exact, jnp.int32),
                    split_l=split.l, s_str=s_str)
                new_kv.append((li, k_new, v_new, h_new))

            x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
            logits = L.unembed(x, params["embed"], cfg)
            token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            token.block_until_ready()

            # store new KV + activations back to host (async), then the
            # paper's Alg. 1 `synchronize()`: the next step's fetches must
            # not race with this step's stores.
            store_futs = [
                self.pool.submit(store.append, li, np.asarray(k_new),
                                 np.asarray(v_new), np.asarray(h_new),
                                 s_cur)
                for (li, k_new, v_new, h_new) in new_kv]
            for f in store_futs:
                f.result()
            store.len = s_cur + 1
            out_tokens.append(np.asarray(token))

            dt = time.perf_counter() - t0
            stats.append(StepStats(dt, t_wait, dt - t_wait, nbytes_total,
                                   split.l))
        return np.concatenate(out_tokens, axis=1), stats
