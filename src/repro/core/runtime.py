"""KVPR runtime module (paper §3.3): the *execution* half of the
profiler → scheduler → runtime loop, as three composable stages:

  - ``HostKVStore``     host-memory KV + activation storage, slot-aware:
                        every batch slot carries its own sequence length,
                        so iteration-level batching can admit a request
                        mid-decode by spilling its prefill into a free
                        slot (``fill_slot``) while other slots keep
                        decoding at their own (ragged) positions.  Also
                        owns the per-layer write-back fence ring: step
                        N's host store of layer li gates only step N+1's
                        *fetch* of layer li, so write-back overlaps the
                        next step's embed and early layers instead of
                        serializing at an end-of-step barrier.
  - ``TransferEngine``  the copy-thread pool emulating the CUDA-stream /
                        DMA engine: per-layer KV/activation fetches
                        (uniform fast path or vectorized ragged gather)
                        and the fine-grained W_K/W_V-first weight
                        stream.  All fetches stage through persistent
                        double-buffered host buffers — the steady-state
                        decode loop performs zero numpy allocations.
  - ``ComputeStep``     the jitted per-layer device compute (recompute +
                        merged segment attention + FFN) and the embed /
                        unembed ends of a decode step.

``OffloadDecodeRuntime`` composes the stages and *executes* an
``ExecutionPlan`` from ``core/scheduler.py`` — it contains no solver
calls of its own and chooses no shapes of its own: per-step/per-slot
``SplitDecision``s AND the bucket-padded static shapes (``l_pad``,
``s_pad``) come from the plan's ``step_geometry`` (paper §3.2), which
amortizes the solves and bounds the XLA trace cache at O(#buckets)
entries.  ``step()`` advances every active slot by one token and is the
single decode hot path shared by static batching (``decode()`` loop),
the serving engine, and the continuous-batching engine.

The KV cache (and attention-input activations) live in HOST memory
(numpy, emulating CPU DRAM / `pinned_host`). Each decode step streams,
per layer, either
  - the full KV cache                       (baseline / FlexGen mode), or
  - activations[0:l] + KV[l:s']             (KVPR mode, plan-chosen l)
into device arrays while the previous layer computes. On this CPU
container "the link" is memcpy (jax.device_put), whose bandwidth the
profiler measures; on TPU the identical structure maps to host-DMA into
HBM with XLA async copies.

Six overlapped flows of paper Alg. 1 and their mapping here:
  load_weight            -> params resident (latency mode) or per-layer put
  load_activation_recompute / load_cache / load_activation
                         -> TransferEngine.fetch_layer futures
  compute                -> ComputeStep.layer (jitted)
  store_activation / store_cache -> per-layer fenced append on the
                                    dedicated store pool

Exactness invariant for the padded buffers: every position beyond a
slot's valid length is masked out of attention (scores replaced before
the softmax, so padded V rows receive exactly zero weight).  Stale
staging content is therefore never *read into* the result — padding can
carry any finite garbage without changing a single token.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareProfile
from repro.core.faults import (FaultPolicy, TransferStallError,
                               TransientTransferError, WriteBackError)
from repro.core.scheduler import ExecutionPlan, Scheduler
from repro.core import kvquant as KQ
from repro.core import recompute as RC
from repro.kernels import ops as kops
from repro.models import layers as L

Array = jax.Array


# ``HostKVStore`` moved to ``core/kvstore/host.py`` as the top rung of
# the tiered storage hierarchy; re-exported here so historical
# ``from repro.core.runtime import HostKVStore`` imports keep working.
from repro.core.kvstore import HostKVStore  # noqa: F401  (re-export)


class TransferEngine:
    """The copy-thread pool emulating the DMA / CUDA-stream engine:
    issues host→device copies for KV, activations, and (optionally)
    streamed layer weights, and counts the bytes it moves.

    Host write-back runs on a separate single-thread pool so a queued
    store can never sit behind (or starve) the latency-critical fetch
    stream — and a fetch blocked on a store fence always has a running
    store to wait on (no pool self-deadlock).

    Fetches stage through *persistent* host buffers, double-buffered by
    layer parity: buffer (kind, parity, shape) is allocated once per
    distinct plan bucket shape and reused across layers and steps, so
    the steady-state decode loop performs zero numpy allocations
    (``staging_allocs`` counts the one-time allocations; a regression
    test asserts it stops growing after warmup).  ``jax.device_put``
    copies out of the staging buffer before returning, so reuse two
    fetches later (same parity) is safe.
    """

    _KV_KEYS = ("wk", "wv")

    def __init__(self, n_copy_threads: int = 2, host_layers=None,
                 fine_grained: bool = True, *,
                 faults: Optional[FaultPolicy] = None,
                 retries: int = 2, backoff_s: float = 0.01):
        self.pool = ThreadPoolExecutor(max_workers=n_copy_threads)
        self.store_pool = ThreadPoolExecutor(max_workers=1)
        self._host_layers = host_layers
        self.fine_grained = fine_grained
        self.faults = faults
        self.retries = max(0, int(retries))
        self.backoff_s = float(backoff_s)
        self._staging: Dict[tuple, np.ndarray] = {}
        self.staging_allocs = 0
        self._t_fence = 0.0
        self._t_fence_lock = threading.Lock()
        self._retry_count = 0
        self._retry_lock = threading.Lock()
        self._closed = False
        self._close_lock = threading.Lock()
        # per-shard stream pool (mesh decode): shard slice copies run
        # here, NOT on the main copy pool — a fetch task fanning out on
        # its own pool could starve-deadlock against the next layer's
        # prefetch.  Created lazily on the first sharded fetch; the
        # unsharded path never pays for it.
        self._shard_pool: Optional[ThreadPoolExecutor] = None
        self._shard_pool_n = 0
        self._shard_bytes: Optional[List[int]] = None
        self._shard_lock = threading.Lock()

    def submit(self, fn, *args):
        return self.pool.submit(fn, *args)

    def submit_store(self, fn, *args):
        return self.store_pool.submit(fn, *args)

    # ------------------------------------------------------- faulty I/O
    # Every injectable transfer op goes through run_io: the FaultPolicy
    # hook fires first (so injected faults hit before any bytes move),
    # then transient failures — injected OR real (OSError from a copy)
    # — retry with exponential backoff up to `retries` times.  Stalls,
    # write-back poisons, and per-request hard faults are NOT retryable
    # and escalate immediately.

    def run_io(self, kind: str, fn, *args, uid: Optional[int] = None,
               **kwargs):
        """Run one transfer op synchronously with fault injection and
        bounded transient-failure retries (``kind`` is the fault-policy
        op kind: "fetch" | "store" | "restore")."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.on_op(kind, uid=uid)
                return fn(*args, **kwargs)
            except (TransientTransferError, OSError):
                attempt += 1
                if attempt > self.retries:
                    raise
                with self._retry_lock:
                    self._retry_count += 1
                time.sleep(self.backoff_s * (2 ** (attempt - 1)))

    def submit_io(self, kind: str, fn, *args, uid: Optional[int] = None,
                  **kwargs):
        """`submit`, through the fault/retry layer."""
        return self.pool.submit(functools.partial(
            self.run_io, kind, fn, *args, uid=uid, **kwargs))

    def submit_store_io(self, kind: str, fn, *args,
                        uid: Optional[int] = None, **kwargs):
        """`submit_store`, through the fault/retry layer."""
        return self.store_pool.submit(functools.partial(
            self.run_io, kind, fn, *args, uid=uid, **kwargs))

    def drain_retries(self) -> int:
        """Transient-failure retries performed since the last drain
        (feeds ``StepStats.retries``)."""
        with self._retry_lock:
            n, self._retry_count = self._retry_count, 0
        return n

    def close(self) -> None:
        """Shut down the copy and store pools (joins the worker
        threads; queued work finishes first).  Idempotent and safe
        under concurrency (flag + lock), and releases any fault-injected
        dead-store hang first so shutdown never deadlocks on a worker
        the policy itself parked."""
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        if self.faults is not None:
            self.faults.release()
        self.pool.shutdown(wait=True)
        self.store_pool.shutdown(wait=True)
        if self._shard_pool is not None:
            self._shard_pool.shutdown(wait=True)

    def drain_t_fence(self) -> float:
        """Seconds fetch workers spent blocked on write-back fences
        since the last drain.  Fence waits cover the *previous* layer's
        device compute (the store task blocks on its outputs), so this
        portion of a step's t_wait is really overlapped compute, not
        link stall — StepStats reports it separately as t_fence."""
        with self._t_fence_lock:
            t, self._t_fence = self._t_fence, 0.0
        return t

    # ------------------------------------------------------- shard streams
    # Tensor-parallel decode (docs/scaling.md): each model-axis shard
    # owns a KV head-slice and streams it over its own 1/shards share of
    # the link.  Emulated here as `shards` concurrent slice copies into
    # disjoint head-slice VIEWS of the one staging buffer — the merged
    # buffer the device receives is byte-identical to the single-stream
    # copy (per-KV-head slices are pure data movement), which is what
    # keeps sharded decode token-identical by construction.

    def _shard_exec(self, shards: int) -> ThreadPoolExecutor:
        """Dedicated pool for shard slice copies, sized to the widest
        mesh seen.  Separate from the fetch pool so a fetch task that
        fans out can never deadlock against queued fetches."""
        with self._shard_lock:
            if self._shard_pool is None or self._shard_pool_n < shards:
                old = self._shard_pool
                self._shard_pool = ThreadPoolExecutor(max_workers=shards)
                self._shard_pool_n = shards
                if old is not None:
                    old.shutdown(wait=True)
            return self._shard_pool

    def _note_shard_bytes(self, shards: int, kv_bytes: int) -> None:
        """Accumulate the per-shard streamed-KV link bytes of one fetch
        (each shard's slice is an even 1/shards of the window)."""
        with self._shard_lock:
            if self._shard_bytes is None or \
                    len(self._shard_bytes) != shards:
                self._shard_bytes = [0] * shards
            per = kv_bytes // shards
            for si in range(shards):
                self._shard_bytes[si] += per

    def drain_shard_bytes(self) -> Optional[Tuple[int, ...]]:
        """Per-shard streamed-KV bytes since the last drain (None when
        no sharded fetch ran) — feeds ``StepStats.shard_kv_bytes``."""
        with self._shard_lock:
            sb, self._shard_bytes = self._shard_bytes, None
        return None if sb is None else tuple(sb)

    @staticmethod
    def _can_shard(shards: int, kv_heads: int) -> bool:
        return shards > 1 and kv_heads % shards == 0

    def _shard_copies(self, shards: int, kv_heads: int, copy_one):
        """Run ``copy_one(h0, h1)`` for each shard's head range on the
        shard pool, concurrently, and join.  ``copy_one`` must write
        only its own head-slice view."""
        per = kv_heads // shards
        pool = self._shard_exec(shards)
        futs = [pool.submit(copy_one, si * per, (si + 1) * per)
                for si in range(shards)]
        for f in futs:
            f.result()

    # ------------------------------------------------------------ staging

    def _stage(self, kind: str, parity: int, shape: tuple,
               dtype) -> np.ndarray:
        """Persistent staging buffer for (kind, parity, shape).  Shapes
        are plan-bucketed, so the dict stays O(#buckets) and steady-state
        lookups allocate nothing."""
        key = (kind, parity, shape, np.dtype(dtype).str)
        buf = self._staging.get(key)
        if buf is None:
            buf = np.zeros(shape, dtype)
            self._staging[key] = buf
            self.staging_allocs += 1
        return buf

    # ---------------------------------------------------------- KV fetch

    def fetch_layer(self, store: HostKVStore, layer: int,
                    ls: np.ndarray, s_strs: np.ndarray,
                    l_pad: int, s_pad: int, stage_ns: str = "",
                    shards: int = 1):
        """Copy host slices to device (the 'PCIe' transfer).

        ls / s_strs are per-slot recompute lengths and streamed lengths;
        l_pad / s_pad are the plan's bucket-padded static shapes.
        Uniform batches take the whole-batch slice path; ragged batches
        gather each slot's own [l_i, l_i + s_pad) window with one
        batched strided take.  Both paths write into persistent staging
        (positions beyond a slot's valid length carry stale-but-finite
        bytes that attention masks to exactly zero weight).

        Waits the layer's write-back fence first: the previous step's
        store of this layer must land before its bytes are re-read.
        Also waits the fence of the layer that last consumed this
        parity's staging buffers: on CPU, jax.device_put zero-copies
        aligned numpy buffers, so the device arrays handed to the
        jitted layer may ALIAS the staging memory — that layer's
        write-back fence resolves only after its outputs materialized,
        i.e. after its (aliased) inputs were fully read, which makes
        the overwrite safe.  When device_put copies instead (other
        backends), the extra wait is a cheap no-op.

        stage_ns namespaces the staging buffers: a degradation-ladder
        fallback fetch passes its own namespace so it can never share
        staging memory with a timed-out primary fetch that may still be
        writing the default-namespace buffers from a pool thread.

        shards > 1 splits the streamed-KV copy into per-KV-head-slice
        streams (one per model-axis shard, concurrent on the shard
        pool) writing disjoint views of the SAME staging buffer — the
        merged bytes are identical to the single-stream copy, so
        sharding the transfer never changes a token.  Requires the
        store's KV-head count to divide by ``shards`` (EngineConfig
        validates this); per-shard streamed bytes accumulate for
        ``StepStats.shard_kv_bytes``.
        """
        t0 = time.perf_counter()
        store.wait_fence(layer)
        if layer >= 2:
            prev = layer - 2             # same parity, same step
        else:
            # wrap: the previous step's LAST same-parity layer (L-1 or
            # L-2 depending on whether L is even — NOT always L-2)
            n = store.num_layers
            prev = n - 1 if (n - 1) & 1 == (layer & 1) else max(n - 2, 0)
        store.wait_fence(prev)
        with self._t_fence_lock:
            self._t_fence += time.perf_counter() - t0
        # Tiered store: promote this layer's demoted share of the fetch
        # windows disk→host before staging reads them.  Running here —
        # inside the per-layer fetch task on the copy pool — overlaps
        # the disk read with the previous layer's compute exactly like
        # the PCIe stream; a failed block read raises DiskReadError
        # (transient), riding the same retry → degradation ladder.
        page_in = getattr(store, "page_in", None)
        if page_in is not None:
            page_in(layer, ls, s_strs)
        parity = layer & 1
        b = store.batch
        # activations: every slot's window starts at 0, so uniform and
        # ragged share one whole-batch copy of the padded prefix
        h_np = self._stage(stage_ns + "h", parity,
                           (b, max(l_pad, 1)) + store.act.shape[3:],
                           store.act.dtype)
        h_np[:] = store.act[layer, :, :max(l_pad, 1)]

        uniform = bool((ls == ls[0]).all())
        if uniform:
            k_np, v_np = self._slice_uniform(store, layer, int(ls[0]),
                                             s_pad, parity, stage_ns,
                                             shards)
        else:
            k_np, v_np = self._gather_ragged(store, layer, ls, s_pad,
                                             parity, stage_ns, shards)
        h_res = jax.device_put(h_np)
        if store.compress == "int4":
            k_str = tuple(jax.device_put(a) for a in k_np)
            v_str = tuple(jax.device_put(a) for a in v_np)
            kv_bytes = sum(a.nbytes for a in k_str + v_str)
        else:
            k_str = jax.device_put(k_np)
            v_str = jax.device_put(v_np)
            kv_bytes = k_str.nbytes + v_str.nbytes
        if shards > 1 and s_pad:
            self._note_shard_bytes(shards, kv_bytes)
        nbytes = (h_res.nbytes if l_pad else 0) + (kv_bytes if s_pad else 0)
        if self.faults is not None:
            self.faults.throttle(nbytes)
        return h_res, k_str, v_str, nbytes

    def _kv_bufs(self, store: HostKVStore):
        if store.compress == "int4":
            return (("kp", "ks", "kz"), tuple(store.kq),
                    ("vp", "vs", "vz"), tuple(store.vq))
        return (("k",), (store.k,), ("v",), (store.v,))

    def _slice_uniform(self, store, layer, l, s_pad, parity,
                       stage_ns="", shards: int = 1):
        """Whole-batch window [l, l + s_pad) copied into staging; with
        shards > 1 each KV buffer's copy fans out into per-head-slice
        shard streams (the int4 triple slices on the same KV-head axis,
        so packed/scale/zero shard identically)."""
        sl = slice(l, l + s_pad) if s_pad else slice(0, 1)
        k_names, k_srcs, v_names, v_srcs = self._kv_bufs(store)

        def stage_copy(names, srcs):
            outs = []
            for name, src in zip(names, srcs):
                win = src[layer, :, sl]
                out = self._stage(stage_ns + name, parity, win.shape,
                                  src.dtype)
                if s_pad and self._can_shard(shards, win.shape[2]):
                    def copy_one(h0, h1, out=out, win=win):
                        out[:, :, h0:h1] = win[:, :, h0:h1]
                    self._shard_copies(shards, win.shape[2], copy_one)
                else:
                    out[:] = win
                outs.append(out)
            return outs

        k_np = stage_copy(k_names, k_srcs)
        v_np = stage_copy(v_names, v_srcs)
        if store.compress == "int4":
            return tuple(k_np), tuple(v_np)
        return k_np[0], v_np[0]

    def _gather_ragged(self, store, layer, ls, s_pad, parity,
                       stage_ns="", shards: int = 1):
        """Vectorized ragged gather: one batched strided take per buffer
        (no per-slot Python loop, no allocation).  Slot i's window is
        [l_i, l_i + s_pad), clamped to the preallocated max_len; rows
        beyond the slot's valid streamed length are masked in attention.
        With shards > 1 the take splits into per-shard column-group
        takes (each KV head-slice flattens to a contiguous column range
        of the (KV, ...) tail), concurrent on the shard pool.
        """
        b, max_len = store.batch, store.max_len
        w = max(s_pad, 1)
        if s_pad:
            idx = np.minimum(ls[:, None] + np.arange(s_pad), max_len - 1)
            flat_idx = (np.arange(b)[:, None] * max_len + idx).ravel()
        k_names, k_srcs, v_names, v_srcs = self._kv_bufs(store)

        def take(names, srcs):
            outs = []
            for name, src in zip(names, srcs):
                tail = src.shape[3:]
                out = self._stage(stage_ns + name, parity, (b, w) + tail,
                                  src.dtype)
                if s_pad:
                    flat_src = src[layer].reshape(b * max_len, -1)
                    flat_out = out.reshape(b * s_pad, -1)
                    kv_heads = tail[0] if tail else 1
                    if self._can_shard(shards, kv_heads):
                        cols = flat_src.shape[1] // kv_heads

                        def take_one(h0, h1, fs=flat_src, fo=flat_out,
                                     c=cols):
                            np.take(fs[:, h0 * c:h1 * c], flat_idx,
                                    axis=0, out=fo[:, h0 * c:h1 * c])
                        self._shard_copies(shards, kv_heads, take_one)
                    else:
                        np.take(flat_src, flat_idx, axis=0,
                                out=flat_out)
                outs.append(out)
            return outs

        k_np = take(k_names, k_srcs)
        v_np = take(v_names, v_srcs)
        if store.compress == "int4":
            return tuple(k_np), tuple(v_np)
        return k_np[0], v_np[0]

    # ------------------------------------------------------ weight fetch
    # Weight offloading (paper's throughput mode, §3.2/§3.3): layer
    # weights live in host memory and stream per layer. fine_grained
    # (Fig. 5b) issues the W_K/W_V copy FIRST so KV recomputation can
    # begin before W_Q/W_O/FFN arrive; coarse (Fig. 5a) copies the
    # whole layer in one piece.

    def fetch_weights_kv(self, layer: int):
        """Stage 1 (fine-grained priority): W_K and W_V only."""
        hl = self._host_layers[layer]
        out = {k: jax.device_put(hl["attn"][k]) for k in self._KV_KEYS}
        return out, sum(a.nbytes for a in out.values())

    def fetch_weights_rest(self, layer: int):
        """Stage 2: everything except W_K/W_V."""
        hl = self._host_layers[layer]
        rest = {"attn": {k: v for k, v in hl["attn"].items()
                         if k not in self._KV_KEYS},
                **{k: v for k, v in hl.items() if k != "attn"}}
        out = jax.tree.map(jax.device_put, rest)
        return out, sum(a.nbytes for a in jax.tree.leaves(out))

    @staticmethod
    def assemble_layer(wkv, rest):
        lp = dict(rest)
        lp["attn"] = dict(rest["attn"], **wkv)
        return lp

    def submit_weights(self, layer: int):
        """fine-grained: W_K/W_V first (Fig. 5b); coarse: one combined
        copy (Fig. 5a)."""
        if self.fine_grained:
            return (self.pool.submit(self.fetch_weights_kv, layer),
                    self.pool.submit(self.fetch_weights_rest, layer))
        both = self.pool.submit(
            lambda l: (self.fetch_weights_kv(l),
                       self.fetch_weights_rest(l)), layer)
        return both, None

    def weights_result(self, w_fut):
        if self.fine_grained:
            (wkv, nb_kv) = w_fut[0].result()
            (rest, nb_r) = w_fut[1].result()
        else:
            (wkv, nb_kv), (rest, nb_r) = w_fut[0].result()
        return self.assemble_layer(wkv, rest), nb_kv + nb_r


class ComputeStep:
    """Jitted device compute for one offload decode step: per-layer
    recompute + merged segment attention + FFN, plus the embed/unembed
    ends.  Per-slot positions and valid lengths make the same compiled
    function serve uniform static batches and ragged continuous slots —
    the runtime always passes (b,) valid vectors, so one trace per
    (l_pad, s_pad) bucket pair covers both.

    ``kernels`` selects the attention implementation: "off" keeps the
    pure-jnp oracle path; any resolved kernel mode (see
    ``kernels.ops.kernel_mode``) routes the three KVPR segments through
    the Pallas suite — fused recompute+attend for the recomputed
    prefix, flash decode (with in-kernel dequant under int4) for the
    streamed segment, flash decode for the new token — merged exactly
    via ``combine_segments``."""

    def __init__(self, cfg: ModelConfig, compress: Optional[str] = None,
                 group: int = 32, kernels="off", shards: int = 1):
        self.cfg = cfg
        self.compress = compress
        self.group = group
        self.shards = int(shards)
        self.kernel_mode = kops.kernel_mode(kernels)
        self.layer = jax.jit(self._layer_step,
                             static_argnames=("l_pad", "s_pad"))

    @property
    def kernel_path(self) -> bool:
        return self.kernel_mode != "off"

    def traces(self) -> int:
        """Number of compiled variants of the per-layer step (-1 when
        the running jax version exposes no cache-size hook)."""
        try:
            return int(self.layer._cache_size())
        except Exception:
            return -1

    def embed(self, params, token: Array, positions: Array) -> Array:
        return L.embed(token, params["embed"], self.cfg, positions)

    def finalize(self, params, x: Array) -> Array:
        x = L.apply_norm(x, params["final_norm"], self.cfg.rms_eps)
        return L.unembed(x, params["embed"], self.cfg)

    def _layer_step(self, x, lp, h_res, k_str, v_str, positions,
                    l_valid, s_valid, l_pad: int, s_pad: int):
        """positions: (b, 1) per-slot decode positions; l_valid: None
        (h_res exact) or (b,) per-slot recompute lengths; s_valid:
        scalar or (b,) streamed valid lengths."""
        cfg = self.cfg
        b = x.shape[0]
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wq"])
        k_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wk"])
        v_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wv"])
        if cfg.pos_embedding == "rope":
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new = L.apply_rope(k_new, positions, cfg.rope_theta)
        if self.kernel_mode != "off":
            out = self._kernel_attention(q, lp, h_res, k_str, v_str,
                                         k_new, v_new, l_valid, s_valid,
                                         l_pad, s_pad)
        else:
            segments = []
            if l_pad > 0:
                k_rec, v_rec = RC.recompute_kv(h_res, lp["attn"]["wk"],
                                               lp["attn"]["wv"], cfg)
                segments.append((k_rec, v_rec, l_valid))
            if s_pad > 0:
                if self.compress == "int4":
                    # kernels off: the packed streamed KV is dequantized
                    # here as a SEPARATE jnp pass before attention (this
                    # is the oracle path — with kernels on the packed
                    # triple goes to the fused dequant-attend kernel
                    # untouched; see _kernel_attention)
                    k_str = KQ.dequantize_jnp(*k_str, group=self.group)
                    v_str = KQ.dequantize_jnp(*v_str, group=self.group)
                segments.append((k_str, v_str, s_valid))
            segments.append((k_new, v_new, None))
            out = RC.merged_decode_attention(q, segments,
                                             positions[:, 0])
        out = out.reshape(b, 1, cfg.num_heads * cfg.dh).astype(x.dtype)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, k_new, v_new, h

    def _kernel_attention(self, q, lp, h_res, k_str, v_str, k_new,
                          v_new, l_valid, s_valid, l_pad: int,
                          s_pad: int):
        """Pallas decode hot path: build the tagged KVPR segment list
        and dispatch through kernels.ops.  The recomputed prefix runs
        the fused recompute+attend kernel (its K/V tiles never leave
        VMEM); an int4 streamed segment's (packed, scale, zero) triple
        is passed through UNTOUCHED — only packed bytes cross HBM→VMEM
        and dequant happens inside the attention kernel."""
        cfg = self.cfg
        segments = []
        if l_pad > 0:
            segments.append(("recompute", h_res, lp["attn"]["wk"],
                             lp["attn"]["wv"], l_valid, 0,
                             cfg.rope_theta,
                             cfg.pos_embedding == "rope"))
        if s_pad > 0:
            if self.compress == "int4":
                segments.append(("int4", k_str, v_str, s_valid,
                                 self.group))
            else:
                segments.append(("fp", k_str, v_str, s_valid))
        segments.append(("fp", k_new, v_new, None))
        return kops.segmented_decode_attention(q, segments,
                                               mode=self.kernel_mode,
                                               head_shards=self.shards)


@dataclasses.dataclass
class StepStats:
    t_total: float
    t_wait_transfer: float      # GPU idle waiting on host data
    t_compute: float            # dt - t_wait: device compute + dispatch
    bytes_transferred: int
    split_l: int                             # max over slots
    split_ls: Optional[Tuple[int, ...]] = None   # per-slot (ragged steps)
    t_store: float = 0.0        # host write-back drained in this step's
                                # window (overlapped, NOT part of t_total
                                # critical path)
    t_fence: float = 0.0        # portion of t_wait_transfer that fetch
                                # workers spent on write-back fences —
                                # mostly overlapped device compute, so
                                # t_compute underestimates device-busy
                                # by up to this much
    retraces: int = 0           # new XLA traces of the layer step
    l_pad: int = 0              # static shapes the step ran with
    s_pad: int = 0
    kernel_path: bool = False   # attention ran the Pallas suite (vs
                                # the jnp oracle path)
    retries: int = 0            # transient transfer/store retries the
                                # fault layer performed in this step's
                                # window
    fetch_fallbacks: int = 0    # layers that degraded to the full-
                                # recompute (l = p) fetch path after a
                                # failed/stalled KV fetch
    shards: int = 1             # model-axis mesh size the step ran with
    shard_kv_bytes: Optional[Tuple[int, ...]] = None
                                # per-shard streamed-KV link bytes
                                # (None on the unsharded path)


class OffloadDecodeRuntime:
    """Plan-executing decode runtime for dense-family models with a
    host-offloaded KV cache.

    mode: "flexgen" (full KV streamed) | "kvpr" (partial recompute).
    Splits AND pad geometry come from the scheduler's ExecutionPlan —
    never solved or chosen here.  ``step()`` advances every active slot
    one token (slots may sit at ragged positions); ``decode()`` is the
    static-batch loop on top.

    kernels: the Pallas dispatch knob (see ``kernels.ops.kernel_mode``)
    — "auto" (default) compiles the kernel suite natively on TPU and
    keeps the jnp oracle path elsewhere; True forces the kernels
    (interpret mode off-TPU); False/"off" forces the jnp path.
    """

    def __init__(self, cfg: ModelConfig, params,
                 hw: Optional[HardwareProfile] = None, *,
                 scheduler: Optional[Scheduler] = None,
                 mode: str = "kvpr", schedule: str = "row",
                 align: int = 1, n_copy_threads: int = 2,
                 compress: Optional[str] = None, group: int = 32,
                 offload_weights: bool = False,
                 fine_grained: bool = True, kernels="auto",
                 faults: Optional[FaultPolicy] = None,
                 io_retries: int = 2, io_backoff_s: float = 0.01,
                 fence_timeout_s: Optional[float] = None,
                 shards: int = 1):
        self.cfg = cfg
        self.params = params
        self.scheduler = scheduler or Scheduler(hw)
        self.mode = mode
        self.schedule = schedule
        self.align = align
        self.compress = compress
        self.group = group
        self.shards = max(1, int(shards))
        if self.shards > 1 and cfg.num_kv_heads % self.shards:
            raise ValueError(
                f"model-axis mesh size {self.shards} does not divide "
                f"num_kv_heads={cfg.num_kv_heads}")
        self.offload_weights = offload_weights
        self.faults = faults
        self.fence_timeout_s = fence_timeout_s
        host_layers = None
        if offload_weights:
            n_layers = jax.tree.leaves(params["layers"])[0].shape[0]
            host_layers = [
                jax.tree.map(lambda a, i=i: np.asarray(a[i]),
                             params["layers"])
                for i in range(n_layers)]
        self.xfer = TransferEngine(n_copy_threads, host_layers,
                                   fine_grained, faults=faults,
                                   retries=io_retries,
                                   backoff_s=io_backoff_s)
        self.compute = ComputeStep(cfg, compress=compress, group=group,
                                   kernels=kernels, shards=shards)
        self._t_store = 0.0
        self._t_store_lock = threading.Lock()
        # degradation-ladder state: sticky jnp-oracle fallback after a
        # kernel launch failure, and one-shot warnings per rung
        self._oracle_step: Optional[ComputeStep] = None
        self._kernel_fallback = False
        self._warned_kernel = False
        self._warned_fetch_fb = False
        self._fetch_fallbacks = 0

    # ---------------------------------------------------------- lifecycle

    def close(self) -> None:
        """Release the transfer engine's thread pools (idempotent)."""
        self.xfer.close()

    def __enter__(self) -> "OffloadDecodeRuntime":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------ planning

    def plan_for(self, batch: int,
                 store: Optional[HostKVStore] = None) -> ExecutionPlan:
        """The runtime's schedule, from the scheduler's plan cache.

        When ``store`` is a tiered store running the ``tier_split``
        policy, the plan is keyed on the hardware ladder extended with
        the store's disk rung (and its disk element width) so the
        fourth plan kind can price the disk crossing — a separate
        cache entry from the single-link plans, which stay untouched."""
        hw = None
        dbe = None
        if (store is not None
                and getattr(store, "tier_policy", None) == "tier_split"):
            hw = store.hw_ladder(self.scheduler.hw)
            dbe = store.disk_bytes_per_el
        return self.scheduler.plan_for(
            self.cfg, batch, mode=self.mode, schedule=self.schedule,
            align=self.align, compress=self.compress, dtype_bytes=4,
            group=self.group, hw=hw, disk_bytes_per_el=dbe,
            shards=self.shards)

    # ----------------------------------------------------------- plumbing

    def _oracle(self) -> ComputeStep:
        """The jnp-oracle ComputeStep the kernel path degrades to
        (lazily built: the fault-free engine never pays for it)."""
        if self._oracle_step is None:
            self._oracle_step = ComputeStep(
                self.cfg, compress=self.compress, group=self.group,
                kernels="off")
        return self._oracle_step

    def _store_layer(self, store: HostKVStore, li: int, k_new, v_new,
                     h_new, pos) -> None:
        """Write-back task (store pool): block on the device values
        *here* — off the critical path — then append to host memory."""
        t0 = time.perf_counter()
        store.append(li, np.asarray(k_new), np.asarray(v_new),
                     np.asarray(h_new), pos)
        with self._t_store_lock:
            self._t_store += time.perf_counter() - t0

    def _drain_t_store(self) -> float:
        with self._t_store_lock:
            t, self._t_store = self._t_store, 0.0
        return t

    # ---------------------------------------------------------------- step

    def step(self, store: HostKVStore, token,
             plan: Optional[ExecutionPlan] = None, *,
             active: Optional[np.ndarray] = None
             ) -> Tuple[Array, StepStats]:
        """One decode step for every slot; returns (logits, stats).

        Slots advance at their own positions (``store.seq_lens``); the
        plan supplies one SplitDecision per distinct (bucketed) length
        plus the step's static pad geometry.  ``active`` masks which
        slots store their new token and advance — inactive slots (empty,
        awaiting admission) compute garbage that is fully masked out of
        attention and never written back.

        The returned logits are NOT blocked on: callers sample on-device
        and pull a single small token array per step, so device compute
        overlaps the host-side loop.  Host write-back of the new token
        is fenced per layer — this step's store of layer li gates only
        the next step's fetch of layer li.
        """
        cfg = self.cfg
        params = self.params
        b = int(np.shape(token)[0])
        plan = plan if plan is not None else self.plan_for(b, store)
        # tiered store: run the dual LRU+TTL eviction sweep once per
        # step (cheap when nothing is over budget or idle), then plan
        # this step's splits against the post-sweep disk residency
        sweep = getattr(store, "sweep", None)
        if sweep is not None:
            sweep()
        seq_lens = np.asarray(store.seq_lens, np.int64).copy()
        if active is None:
            active = np.ones(b, bool)
        disk_fn = getattr(store, "disk_tokens", None)
        if (disk_fn is not None
                and getattr(store, "tier_policy", None) == "tier_split"):
            # fourth plan kind: per-slot splits solved over BOTH links
            # (disk→host at the rung's width + host→device), so demoted
            # prefixes lean toward recomputation exactly when the disk
            # crossing would dominate the stream
            geom = plan.step_geometry(seq_lens, max_len=store.max_len,
                                      disk_tokens=disk_fn())
        else:
            geom = plan.step_geometry(seq_lens, max_len=store.max_len)
        ls, s_strs = geom.ls, geom.s_strs
        l_pad, s_pad = geom.l_pad, geom.s_pad

        t0 = time.perf_counter()
        traces0 = self.compute.traces()
        positions = jnp.asarray(seq_lens[:, None], jnp.int32)
        x = self.compute.embed(params, jnp.asarray(token), positions)
        # always (b,) valid vectors: uniform and ragged steps share the
        # same compiled variant per (l_pad, s_pad) bucket
        l_valid = jnp.asarray(ls, jnp.int32)
        s_valid = jnp.asarray(s_strs, jnp.int32)
        if geom.uniform and active.all():
            store_pos = int(seq_lens[0])
        else:
            store_pos = np.where(active, seq_lens, -1)

        comp = self._oracle() if self._kernel_fallback else self.compute
        fb = None             # lazy fallback geometry (built on first
        #                       failed fetch of the step, reused after)
        fb_count0 = self._fetch_fallbacks
        t_wait = 0.0
        nbytes_total = 0
        # prefetch layer 0 (weights first when offloaded — they gate
        # recomputation; then the KV/activation stream)
        w_fut = (self.xfer.submit_weights(0) if self.offload_weights
                 else None)
        fut = self.xfer.submit_io("fetch", self.xfer.fetch_layer, store,
                                  0, ls, s_strs, l_pad, s_pad,
                                  shards=self.shards)
        for li in range(cfg.num_layers):
            tw0 = time.perf_counter()
            if self.offload_weights:
                lp, nb_w = self.xfer.weights_result(w_fut)
                nbytes_total += nb_w
            else:
                lp = jax.tree.map(lambda a: a[li], params["layers"])
            cur_lp, cur_sp = l_pad, s_pad
            cur_lv, cur_sv = l_valid, s_valid
            try:
                h_res, k_str, v_str, nb = fut.result(
                    self.fence_timeout_s)
            except (TransferStallError, WriteBackError):
                # the store pipeline is stalled or the host copy is
                # already incomplete — no recompute can fix that; abort
                # the step and let the serving layer contain/escalate
                raise
            except (FuturesTimeout, TransientTransferError,
                    OSError) as e:
                # degradation ladder: the streamed-KV fetch is gone
                # (retries exhausted or deadline missed) — recompute
                # the WHOLE prefix from activations instead (the
                # paper's split at the l = p endpoint), fetched
                # synchronously in a private staging namespace so the
                # abandoned fetch can't scribble on our buffers
                if fb is None:
                    g = plan.fallback_geometry(seq_lens,
                                               max_len=store.max_len)
                    fb = (g, jnp.asarray(g.ls, jnp.int32),
                          jnp.asarray(g.s_strs, jnp.int32))
                if not self._warned_fetch_fb:
                    self._warned_fetch_fb = True
                    warnings.warn(
                        f"KV fetch failed ({type(e).__name__}); "
                        "degrading to full recomputation from "
                        "activations (split l = p)")
                g, fb_lv, fb_sv = fb
                h_res, k_str, v_str, nb = self.xfer.fetch_layer(
                    store, li, g.ls, g.s_strs, g.l_pad, g.s_pad,
                    stage_ns="fb:", shards=self.shards)
                cur_lp, cur_sp = g.l_pad, g.s_pad
                cur_lv, cur_sv = fb_lv, fb_sv
                self._fetch_fallbacks += 1
            t_wait += time.perf_counter() - tw0
            nbytes_total += nb
            if li + 1 < cfg.num_layers:
                if self.offload_weights:
                    w_fut = self.xfer.submit_weights(li + 1)
                fut = self.xfer.submit_io(
                    "fetch", self.xfer.fetch_layer, store, li + 1, ls,
                    s_strs, l_pad, s_pad, shards=self.shards)
            try:
                if comp.kernel_path and self.faults is not None:
                    self.faults.on_kernel_launch()
                x, k_new, v_new, h_new = comp.layer(
                    x, lp, h_res, k_str, v_str, positions, cur_lv,
                    cur_sv, l_pad=cur_lp, s_pad=cur_sp)
            except Exception as e:
                if not comp.kernel_path:
                    raise
                # degradation ladder: kernel launch failed — fall back
                # to the jnp oracle path, sticky for the runtime's
                # lifetime (relaunching a failed kernel every step
                # would re-pay tracing just to fail again)
                if not self._warned_kernel:
                    self._warned_kernel = True
                    warnings.warn(
                        f"Pallas kernel launch failed "
                        f"({type(e).__name__}: {e}); falling back to "
                        "the jnp oracle path")
                self._kernel_fallback = True
                comp = self._oracle()
                x, k_new, v_new, h_new = comp.layer(
                    x, lp, h_res, k_str, v_str, positions, cur_lv,
                    cur_sv, l_pad=cur_lp, s_pad=cur_sp)
            # paper Alg. 1 store_cache/store_activation, fence-grained:
            # submit the write-back NOW; only the NEXT step's fetch of
            # this layer waits on it, so stores overlap the tail of this
            # step and the head of the next
            store.set_fence(li, self.xfer.submit_store_io(
                "store", self._store_layer, store, li, k_new, v_new,
                h_new, store_pos))

        logits = self.compute.finalize(params, x)
        store.seq_lens[active] += 1

        dt = time.perf_counter() - t0
        traces1 = self.compute.traces()
        stats = StepStats(
            dt, t_wait, dt - t_wait, nbytes_total, int(ls.max()),
            None if geom.uniform else tuple(int(l) for l in ls),
            t_store=self._drain_t_store(),
            t_fence=self.xfer.drain_t_fence(),
            retraces=max(0, traces1 - traces0) if traces0 >= 0 else 0,
            l_pad=l_pad, s_pad=s_pad,
            kernel_path=comp.kernel_path,
            retries=self.xfer.drain_retries(),
            fetch_fallbacks=self._fetch_fallbacks - fb_count0,
            shards=self.shards,
            shard_kv_bytes=self.xfer.drain_shard_bytes())
        return logits, stats

    # -------------------------------------------------------------- decode

    def decode(self, store: HostKVStore, first_token: np.ndarray,
               gen_len: int, sample_fn=None, key=None, *,
               on_token=None) -> Tuple[np.ndarray, List[StepStats]]:
        """Generate `gen_len` tokens for a uniform batch.

        sample_fn(logits (b, V), key) -> (b,) picks the next token
        (greedy argmax when None).  Step i's key is derived as
        ``fold_in(key, i)`` — a counter-derived stream, so a caller that
        needs to continue the stream later advances a counter instead of
        mirroring per-step splits.  Sampling runs on-device; the only
        per-step host transfer is the (b,) token array itself.

        on_token(step, tokens (b,) np.int32, stats) is the streaming
        hook: called once per generated token block, after it landed on
        host; returning a truthy value stops decoding early (e.g. every
        request hit EOS).  Returns (tokens, stats).
        """
        token = jnp.asarray(first_token)
        plan = self.plan_for(int(token.shape[0]), store)
        stats: List[StepStats] = []
        out_tokens = []
        for i in range(gen_len):
            logits, st = self.step(store, token, plan)
            if sample_fn is None:
                token = jnp.argmax(logits[:, -1:], axis=-1).astype(
                    jnp.int32)
            else:
                sub = None
                if key is not None:
                    sub = jax.random.fold_in(key, i)
                token = sample_fn(logits[:, -1], sub)[:, None]
            out_tokens.append(np.asarray(token))
            stats.append(st)
            if on_token is not None and on_token(
                    i, out_tokens[-1][:, 0], st):
                break
        # leave the store consistent for the caller (and surface any
        # write-back error): drain the final step's fences
        t0 = time.perf_counter()
        store.sync()
        if stats:
            stats[-1].t_store += self._drain_t_store()
            stats[-1].t_total += time.perf_counter() - t0
        return np.concatenate(out_tokens, axis=1), stats


def prefill_with_activations(model, params, tokens: Array,
                             prompt_lens=None, prefix=None, pads=None):
    """Dense-family prefill that also returns per-layer attention-input
    activations (the host-resident tensors KVPR recomputes from).

    Returns (last_logits (b, 1, V), ks, vs, hs) — the caller samples the
    first token (so the engine's configured sampler applies) and spills
    ks/vs/hs into a HostKVStore slot.

    prompt_lens: optional (b,) TRUE per-row prompt lengths of a
    LEFT-padded ragged batch.  Row i's first real token gets RoPE /
    embedding position 0 and its left-padding is masked out of every
    attention with exactly zero weight, so each row's ks/vs/hs columns
    [s - len_i, s) equal a solo prefill of that prompt.

    prefix: optional ``(k_pre, v_pre, p)`` — device KV for the first
    ``p`` GLOBAL columns of the (padded) prompt, already materialized
    (restored from a shared-prefix cache via ``restore_prefix_kv``, or
    accumulated by ``ChunkedPrefill``).  ``tokens`` are then only the
    next columns (p .. p+s-1); every query attends over
    [prefix | causal block] and the returned ks/vs/hs cover those
    columns only.

    pads: optional (b,) per-row LEFT-pad widths in GLOBAL columns —
    the chunked-prefill form of ``prompt_lens`` (which it is mutually
    exclusive with): pad keys get exactly zero weight and positions are
    shifted per row, composing with ``prefix`` so a chunk of a ragged
    batch stays exact.
    """
    cfg = model.cfg
    b, s = tokens.shape
    p0 = 0
    if prefix is not None:
        if prompt_lens is not None:
            raise ValueError("prefix and prompt_lens are mutually "
                             "exclusive (pass pads for chunked ragged "
                             "prefill)")
        k_pre, v_pre, p0 = prefix
    if prompt_lens is not None:
        pads = (s - jnp.asarray(prompt_lens)).astype(jnp.int32)
    elif pads is not None:
        pads = jnp.asarray(pads, jnp.int32)
    kv_start = pads
    if pads is not None:
        positions = jnp.maximum(
            jnp.arange(s)[None, :] + p0 - pads[:, None], 0)
    else:
        positions = jnp.broadcast_to(jnp.arange(s) + p0, (b, s))
    x = L.embed(tokens, params["embed"], cfg, positions)

    def body(x, inp):
        if prefix is not None:
            lp, kp, vp = inp
        else:
            lp = inp
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
        if prefix is not None:
            out = L.chunked_causal_attend(
                q, jnp.concatenate([kp.astype(k.dtype), k], axis=1),
                jnp.concatenate([vp.astype(v.dtype), v], axis=1),
                q_offset=p0, kv_start=kv_start)
        else:
            out = L.chunked_causal_attend(q, k, v, kv_start=kv_start)
        out = out.reshape(b, s, cfg.num_heads * cfg.dh)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, (k, v, h)

    xs = ((params["layers"], k_pre, v_pre) if prefix is not None
          else params["layers"])
    x, (ks, vs, hs) = jax.lax.scan(body, x, xs)
    x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    return logits, ks, vs, hs


# --------------------------------------------------------- chunked prefill
# Streamed prefill (the last unpipelined stage of the offload path):
# instead of one monolithic prefill followed by one monolithic
# bulk_fill, the prompt is processed in scheduler-chosen chunks, and
# each finished chunk's KV + activations go to host THROUGH the
# TransferEngine's store pool while the next chunk computes — the same
# transfer/compute overlap the decode hot path gets from its per-layer
# fences, applied at chunk grain to prefill write-back.


def chunk_width(chunk: int, remaining: int, q_block: int = 512) -> int:
    """The one place the chunk-shape contract lives: clamp a chunk
    width to the remaining prompt and to a shape
    ``chunked_causal_attend`` accepts (<= q_block, or a multiple of
    it).  Both the offload driver (``ChunkedPrefill``) and the
    resident engine path use it.  Widths are always GRID widths — the
    configured chunk or the final partial one, never a budget-truncated
    sliver — so the XLA trace set stays O(n / chunk) per prompt
    length."""
    w = min(chunk, remaining)
    if w > q_block:
        w = (w // q_block) * q_block
    return max(w, 1)


def _chunk_prefill_fn(model):
    """Per-model jitted chunk step (cached ON the model so traces are
    shared across ChunkedPrefill instances, i.e. across admissions):
    one XLA executable per (chunk width, prefix length, pads?) shape
    triple — a warm engine re-admitting same-length prompts compiles
    nothing."""
    fn = getattr(model, "_chunked_prefill_jit", None)
    if fn is None:
        def step(params, tokens, k_pre, v_pre, p0, pads):
            prefix = (k_pre, v_pre, p0) if k_pre is not None else None
            return prefill_with_activations(model, params, tokens,
                                            prefix=prefix, pads=pads)
        fn = jax.jit(step, static_argnames=("p0",))
        model._chunked_prefill_jit = fn
    return fn


class ChunkedPrefill:
    """Resumable chunked prefill of one (possibly ragged, LEFT-padded)
    prompt batch, with optional streamed host write-back.

    Each ``step()`` prefills the next chunk — its queries attend over
    the device-accumulated prefix KV plus their own causal block via
    ``prefill_with_activations(prefix=..., pads=...)`` — and, when a
    ``store`` is given, submits the finished chunk's host write-back on
    the TransferEngine's store pool (device→host conversion happens on
    that pool, off the critical path) behind a chunk fence.  The driver
    itself never blocks on a store: only ``finish()`` drains the
    fences, so the lone un-overlapped write-back is the final chunk's.

    ``step(budget)`` runs the next GRID-width chunk only when the
    budget covers it (and nothing otherwise) — budgets gate progress,
    they never shrink chunk shapes, so a budget-driven caller compiles
    the same O(n / chunk) trace set as an unbudgeted one.  That is what
    lets a continuous-batching engine interleave prompt chunks with
    decode steps under a per-step token budget.  Token-identity: the
    chunk decomposition changes execution order only — the last
    chunk's logits equal a monolithic prefill's last-position logits
    exactly.
    """

    def __init__(self, model, params, tokens, chunk: int, *,
                 prompt_lens=None, store: Optional[HostKVStore] = None,
                 xfer: Optional[TransferEngine] = None,
                 slot: Optional[int] = None, q_block: int = 512,
                 uid: Optional[int] = None):
        self.model, self.params = model, params
        self.uid = uid
        self.tokens = jnp.asarray(tokens)
        self.b, self.n = self.tokens.shape
        self.chunk = max(1, int(chunk))
        self.q_block = q_block
        if (store is None) != (xfer is None):
            raise ValueError("store and xfer must be given together")
        self.store, self.xfer, self.slot = store, xfer, slot
        self.pads = None
        if prompt_lens is not None:
            lens = np.asarray(prompt_lens, np.int64)
            if not (lens == self.n).all():
                self.pads = (self.n - lens).astype(np.int32)
        self.pos = 0
        self.logits: Optional[Array] = None
        self.k_pre: Optional[Array] = None     # device (L, b, pos, KV, dh)
        self.v_pre: Optional[Array] = None
        self.chunks_run = 0
        self._fn = _chunk_prefill_fn(model)

    @property
    def done(self) -> bool:
        return self.pos >= self.n

    @property
    def remaining(self) -> int:
        return self.n - self.pos

    @property
    def next_width(self) -> int:
        """The next grid chunk width (full chunk, or the final partial
        one)."""
        return chunk_width(self.chunk, self.remaining, self.q_block)

    def step(self, budget: Optional[int] = None) -> int:
        """Prefill the next grid-width chunk — only if ``budget``
        covers it — submit its write-back, and return the tokens
        consumed (0 when done or under-budget)."""
        w = self.next_width
        if self.done or (budget is not None and budget < w):
            return 0
        chunk_toks = self.tokens[:, self.pos:self.pos + w]
        pads = None if self.pads is None else jnp.asarray(self.pads)
        lg, ks, vs, hs = self._fn(self.params, chunk_toks, self.k_pre,
                                  self.v_pre, self.pos, pads)
        self.logits = lg
        self.k_pre = (ks if self.k_pre is None
                      else jnp.concatenate([self.k_pre, ks], axis=2))
        self.v_pre = (vs if self.v_pre is None
                      else jnp.concatenate([self.v_pre, vs], axis=2))
        if self.store is not None:
            # uid-tagged, through the fault/retry layer: a hard fault
            # on THIS request's chunk write-back surfaces (typed, with
            # the owning uid) at this slot's wait_chunks, never at
            # another request's fence
            self.store.push_chunk_fence(
                self.xfer.submit_store_io(
                    "store", self._store_chunk, ks, vs, hs, self.pos,
                    uid=self.uid), slot=self.slot)
        self.pos += w
        self.chunks_run += 1
        return w

    def _store_chunk(self, ks, vs, hs, start: int) -> None:
        """Write-back task (store pool): block on the device values
        here — off the critical path — then copy into host memory."""
        ks, vs, hs = np.asarray(ks), np.asarray(vs), np.asarray(hs)
        if self.slot is not None:
            self.store.fill_chunk_slot(self.slot, ks, vs, hs, start)
        else:
            self.store.fill_chunk(ks, vs, hs, start, pads=self.pads)

    def finish(self) -> Array:
        """Drive any remaining chunks, drain THIS prefill's chunk
        fences, and return the last-position logits (b, 1, V)."""
        while not self.done:
            self.step()
        if self.store is not None:
            self.store.wait_chunks(self.slot)
        return self.logits


# ---------------------------------------------------------------- restore
# Shared-prefix restore (admission-time KVPR): materialize device KV for
# a cached prefix by the scheduler's split — stream KV[l:p] over the
# emulated link while the device recomputes KV[0:l] from the (smaller)
# cached activations.  This is the paper's decode-time transfer-vs-
# recompute decision applied once, at admission, to a prompt prefix
# another request already paid to prefill.


@dataclasses.dataclass
class RestoreStats:
    """One prefix restore: how the matched tokens were materialized."""
    matched: int                 # tokens restored from the prefix cache
    recomputed: int              # l — recomputed on device from acts
    streamed: int                # matched - l — KV streamed on the link
    bytes_streamed: int          # link bytes (KV[l:p] + acts[0:l])
    t_restore: float             # wall seconds for the whole restore


def _recompute_prefix_kv(hs, wk, wv, theta, rope: bool):
    """All-layer KV recompute from stacked activations: hs (L, b, l, h),
    wk/wv (L, h, KV, dh) -> k/v (L, b, l, KV, dh), roped at [0, l)."""
    k = jnp.einsum("Lblh,Lhnd->Lblnd", hs, wk)
    v = jnp.einsum("Lblh,Lhnd->Lblnd", hs, wv)
    if rope:
        k = L.apply_rope(k, jnp.arange(hs.shape[2]), theta)
    return k, v


_recompute_prefix_kv = jax.jit(_recompute_prefix_kv,
                               static_argnames=("rope",))


def restore_prefix_kv(cfg: ModelConfig, params, entry_ks, entry_vs,
                      entry_hs, p: int, split_l: int,
                      xfer: TransferEngine,
                      uid: Optional[int] = None
                      ) -> Tuple[Array, Array, RestoreStats]:
    """Materialize device KV for the first ``p`` tokens of a cached
    prefix entry, split at ``split_l`` (the scheduler's restore-split
    decision, paper Eq. 11 at admission time).

    entry_ks/vs: host (L, 1, >=p, KV, dh); entry_hs: host (L, 1, >=p, h).
    The streamed tail KV[l:p) goes through the TransferEngine's copy
    pool (counted link bytes, overlapped), while activations[0:l) are
    put on device and KV[0:l) recomputed there — the same GEMM+RoPE the
    decode-path ComputeStep runs, batched over all layers.
    Returns (k_dev, v_dev) each (L, 1, p, KV, dh) plus RestoreStats.
    """
    t0 = time.perf_counter()
    l = max(0, min(int(split_l), int(p)))
    nbytes = 0
    fut = None
    if l < p:
        k_tail = np.ascontiguousarray(entry_ks[:, :, l:p])
        v_tail = np.ascontiguousarray(entry_vs[:, :, l:p])
        nbytes += k_tail.nbytes + v_tail.nbytes
        fut = xfer.submit_io(
            "restore",
            lambda a, b: (jax.device_put(a), jax.device_put(b)),
            k_tail, v_tail, uid=uid)
    parts_k, parts_v = [], []
    if l > 0:
        hs_dev = jax.device_put(np.ascontiguousarray(entry_hs[:, :, :l]))
        nbytes += int(hs_dev.nbytes)
        wk = params["layers"]["attn"]["wk"]
        wv = params["layers"]["attn"]["wv"]
        k_rec, v_rec = _recompute_prefix_kv(
            hs_dev, wk, wv, cfg.rope_theta,
            rope=cfg.pos_embedding == "rope")
        parts_k.append(k_rec)
        parts_v.append(v_rec)
    if fut is not None:
        k_str, v_str = fut.result()
        parts_k.append(k_str)
        parts_v.append(v_str)
    k_dev = parts_k[0] if len(parts_k) == 1 else jnp.concatenate(
        parts_k, axis=2)
    v_dev = parts_v[0] if len(parts_v) == 1 else jnp.concatenate(
        parts_v, axis=2)
    stats = RestoreStats(matched=int(p), recomputed=l,
                         streamed=int(p) - l, bytes_streamed=int(nbytes),
                         t_restore=time.perf_counter() - t0)
    return k_dev, v_dev, stats
