"""KVPR runtime module (paper §3.3): the *execution* half of the
profiler → scheduler → runtime loop, as three composable stages:

  - ``HostKVStore``     host-memory KV + activation storage, slot-aware:
                        every batch slot carries its own sequence length,
                        so iteration-level batching can admit a request
                        mid-decode by spilling its prefill into a free
                        slot (``fill_slot``) while other slots keep
                        decoding at their own (ragged) positions.
  - ``TransferEngine``  the copy-thread pool emulating the CUDA-stream /
                        DMA engine: per-layer KV/activation fetches
                        (uniform fast path or ragged padded gather) and
                        the fine-grained W_K/W_V-first weight stream.
  - ``ComputeStep``     the jitted per-layer device compute (recompute +
                        merged segment attention + FFN) and the embed /
                        unembed ends of a decode step.

``OffloadDecodeRuntime`` composes the stages and *executes* an
``ExecutionPlan`` from ``core/scheduler.py`` — it contains no solver
calls of its own: per-step/per-slot ``SplitDecision``s come from the
plan (paper §3.2), which amortizes and caches the solves.  ``step()``
advances every active slot by one token and is the single decode hot
path shared by static batching (``decode()`` loop), the serving engine,
and the continuous-batching engine.

The KV cache (and attention-input activations) live in HOST memory
(numpy, emulating CPU DRAM / `pinned_host`). Each decode step streams,
per layer, either
  - the full KV cache                       (baseline / FlexGen mode), or
  - activations[0:l] + KV[l:s']             (KVPR mode, plan-chosen l)
into device arrays while the previous layer computes. On this CPU
container "the link" is memcpy (jax.device_put), whose bandwidth the
profiler measures; on TPU the identical structure maps to host-DMA into
HBM with XLA async copies.

Six overlapped flows of paper Alg. 1 and their mapping here:
  load_weight            -> params resident (latency mode) or per-layer put
  load_activation_recompute / load_cache / load_activation
                         -> TransferEngine.fetch_layer futures
  compute                -> ComputeStep.layer (jitted)
  store_activation / store_cache -> host_store.append() on the pool
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareProfile
from repro.core.scheduler import ExecutionPlan, Scheduler
from repro.core import kvquant as KQ
from repro.core import recompute as RC
from repro.models import layers as L

Array = jax.Array


class HostKVStore:
    """Host-memory (numpy) per-layer KV + activation storage, preallocated
    ("pinned") to max_len so stores are slice writes, not reallocations.

    Slot-aware: ``seq_lens[i]`` is slot i's own cached length, so slots
    can hold sequences at different decode positions (continuous
    batching).  ``fill_slot`` spills a b=1 prefill into one slot;
    ``clear_slot`` frees it for the next admission.  The legacy ``len``
    property views the store as a uniform batch (max length; assigning
    sets every slot) for the static-batching path.

    compress="int4" keeps the KV cache group-wise 4-bit quantized in host
    memory (paper §4.4 / beyond-paper executable path): appends quantize
    once, fetches stream packed codes + scales (≈⅛ of the f32 bytes);
    activations stay exact — the KVPR-recomputed prefix loses nothing.
    """

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 dtype=np.float32, compress: Optional[str] = None,
                 group: int = 32):
        Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                         cfg.d_model)
        self.compress = compress
        self.group = group
        self.batch = batch
        self.max_len = max_len
        if compress == "int4":
            ng = dh // group
            self.kq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
            self.vq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
        else:
            self.k = np.zeros((Lh, batch, max_len, KV, dh), dtype)
            self.v = np.zeros((Lh, batch, max_len, KV, dh), dtype)
        self.act = np.zeros((Lh, batch, max_len, h), dtype)
        self.seq_lens = np.zeros((batch,), np.int64)
        self.lock = threading.Lock()

    # `len` views the store as a uniform batch (static-batching path).
    @property
    def len(self) -> int:
        return int(self.seq_lens.max())

    @len.setter
    def len(self, value: int) -> None:
        self.seq_lens[:] = value

    # ------------------------------------------------------------- writes

    def _put_kv(self, layer, sl, k: np.ndarray, v: np.ndarray):
        if self.compress == "int4":
            for buf, x in ((self.kq, k), (self.vq, v)):
                q = KQ.quantize_np(x, self.group)
                buf.packed[layer, :, sl] = q.packed
                buf.scale[layer, :, sl] = q.scale
                buf.zero[layer, :, sl] = q.zero
        else:
            self.k[layer, :, sl] = k
            self.v[layer, :, sl] = v

    def _put_kv_slot(self, layer, slot, sl, k: np.ndarray, v: np.ndarray):
        if self.compress == "int4":
            for buf, x in ((self.kq, k), (self.vq, v)):
                q = KQ.quantize_np(x, self.group)
                buf.packed[layer, slot, sl] = q.packed
                buf.scale[layer, slot, sl] = q.scale
                buf.zero[layer, slot, sl] = q.zero
        else:
            self.k[layer, slot, sl] = k
            self.v[layer, slot, sl] = v

    def append(self, layer: int, k: np.ndarray, v: np.ndarray,
               act: np.ndarray, pos) -> None:
        """Store one new token per slot.  ``pos`` is an int (uniform
        batch: every slot writes the same position) or a (b,) vector of
        per-slot positions; a negative entry skips that slot."""
        if np.ndim(pos) == 0:
            self._put_kv(layer, slice(pos, pos + k.shape[1]), k, v)
            self.act[layer, :, pos:pos + act.shape[1]] = act
            return
        for i, p in enumerate(np.asarray(pos)):
            if p < 0:
                continue
            self._put_kv_slot(layer, i, slice(p, p + k.shape[1]),
                              k[i], v[i])
            self.act[layer, i, p:p + act.shape[1]] = act[i]

    def bulk_fill(self, ks, vs, acts, s: int) -> None:
        """Fill from prefill outputs: (L, b, s, KV, dh) / (L, b, s, h)."""
        if self.compress == "int4":
            for li in range(ks.shape[0]):
                self._put_kv(li, slice(0, s), ks[li], vs[li])
        else:
            self.k[:, :, :s] = ks
            self.v[:, :, :s] = vs
        self.act[:, :, :s] = acts
        self.seq_lens[:] = s

    def fill_slot(self, slot: int, ks, vs, acts, s: int) -> None:
        """Spill a b=1 prefill — (L, 1, s, KV, dh) / (L, 1, s, h) — into
        one slot (iteration-level admission)."""
        for li in range(ks.shape[0]):
            self._put_kv_slot(li, slot, slice(0, s), ks[li, 0], vs[li, 0])
        self.act[:, slot, :s] = acts[:, 0]
        self.seq_lens[slot] = s

    def clear_slot(self, slot: int) -> None:
        """Free a slot for the next admission (data may stay stale: every
        fetch copies/masks only the valid prefix)."""
        self.seq_lens[slot] = 0


class TransferEngine:
    """The copy-thread pool emulating the DMA / CUDA-stream engine:
    issues host→device copies for KV, activations, and (optionally)
    streamed layer weights, and counts the bytes it moves."""

    _KV_KEYS = ("wk", "wv")

    def __init__(self, n_copy_threads: int = 2, host_layers=None,
                 fine_grained: bool = True):
        self.pool = ThreadPoolExecutor(max_workers=n_copy_threads)
        self._host_layers = host_layers
        self.fine_grained = fine_grained

    def submit(self, fn, *args):
        return self.pool.submit(fn, *args)

    # ---------------------------------------------------------- KV fetch

    def fetch_layer(self, store: HostKVStore, layer: int,
                    ls: np.ndarray, s_strs: np.ndarray,
                    l_pad: int, s_pad: int):
        """Copy host slices to device (the 'PCIe' transfer).

        ls / s_strs are per-slot recompute lengths and streamed lengths.
        Uniform batches take the fast whole-batch slice path; ragged
        batches gather each slot's own [l_i, l_i + s_i) window into a
        zero-padded (b, s_pad, ...) buffer before the device_put.
        """
        uniform = bool((ls == ls[0]).all() and (s_strs == s_strs[0]).all())
        if uniform:
            h_np, k_np, v_np = self._slice_uniform(store, layer,
                                                   int(ls[0]), l_pad, s_pad)
        else:
            h_np, k_np, v_np = self._gather_ragged(store, layer, ls,
                                                   s_strs, l_pad, s_pad)
        h_res = jax.device_put(h_np)
        if store.compress == "int4":
            k_str = tuple(jax.device_put(a) for a in k_np)
            v_str = tuple(jax.device_put(a) for a in v_np)
            kv_bytes = sum(a.nbytes for a in k_str + v_str)
        else:
            k_str = jax.device_put(k_np)
            v_str = jax.device_put(v_np)
            kv_bytes = k_str.nbytes + v_str.nbytes
        nbytes = (h_res.nbytes if l_pad else 0) + (kv_bytes if s_pad else 0)
        return h_res, k_str, v_str, nbytes

    def _slice_uniform(self, store, layer, l, l_pad, s_pad):
        h_np = store.act[layer, :, :max(l_pad, 1)]
        sl = slice(l, l + s_pad) if s_pad else slice(0, 1)
        if store.compress == "int4":
            k_np = tuple(np.ascontiguousarray(b[layer, :, sl])
                         for b in store.kq)
            v_np = tuple(np.ascontiguousarray(b[layer, :, sl])
                         for b in store.vq)
        else:
            k_np = np.ascontiguousarray(store.k[layer, :, sl])
            v_np = np.ascontiguousarray(store.v[layer, :, sl])
        return h_np, k_np, v_np

    def _gather_ragged(self, store, layer, ls, s_strs, l_pad, s_pad):
        b = store.batch
        h_np = np.zeros((b, max(l_pad, 1)) + store.act.shape[3:],
                        store.act.dtype)
        for i in range(b):
            li = int(ls[i])
            if li:
                h_np[i, :li] = store.act[layer, i, :li]

        def gather(bufs):
            outs = []
            for buf in bufs:
                out = np.zeros((b, max(s_pad, 1)) + buf.shape[3:],
                               buf.dtype)
                for i in range(b):
                    li, si = int(ls[i]), int(s_strs[i])
                    if si:
                        out[i, :si] = buf[layer, i, li:li + si]
                outs.append(out)
            return outs

        if store.compress == "int4":
            k_np = tuple(gather(store.kq))
            v_np = tuple(gather(store.vq))
        else:
            (k_np,) = gather([store.k])
            (v_np,) = gather([store.v])
        return h_np, k_np, v_np

    # ------------------------------------------------------ weight fetch
    # Weight offloading (paper's throughput mode, §3.2/§3.3): layer
    # weights live in host memory and stream per layer. fine_grained
    # (Fig. 5b) issues the W_K/W_V copy FIRST so KV recomputation can
    # begin before W_Q/W_O/FFN arrive; coarse (Fig. 5a) copies the
    # whole layer in one piece.

    def fetch_weights_kv(self, layer: int):
        """Stage 1 (fine-grained priority): W_K and W_V only."""
        hl = self._host_layers[layer]
        out = {k: jax.device_put(hl["attn"][k]) for k in self._KV_KEYS}
        return out, sum(a.nbytes for a in out.values())

    def fetch_weights_rest(self, layer: int):
        """Stage 2: everything except W_K/W_V."""
        hl = self._host_layers[layer]
        rest = {"attn": {k: v for k, v in hl["attn"].items()
                         if k not in self._KV_KEYS},
                **{k: v for k, v in hl.items() if k != "attn"}}
        out = jax.tree.map(jax.device_put, rest)
        return out, sum(a.nbytes for a in jax.tree.leaves(out))

    @staticmethod
    def assemble_layer(wkv, rest):
        lp = dict(rest)
        lp["attn"] = dict(rest["attn"], **wkv)
        return lp

    def submit_weights(self, layer: int):
        """fine-grained: W_K/W_V first (Fig. 5b); coarse: one combined
        copy (Fig. 5a)."""
        if self.fine_grained:
            return (self.pool.submit(self.fetch_weights_kv, layer),
                    self.pool.submit(self.fetch_weights_rest, layer))
        both = self.pool.submit(
            lambda l: (self.fetch_weights_kv(l),
                       self.fetch_weights_rest(l)), layer)
        return both, None

    def weights_result(self, w_fut):
        if self.fine_grained:
            (wkv, nb_kv) = w_fut[0].result()
            (rest, nb_r) = w_fut[1].result()
        else:
            (wkv, nb_kv), (rest, nb_r) = w_fut[0].result()
        return self.assemble_layer(wkv, rest), nb_kv + nb_r


class ComputeStep:
    """Jitted device compute for one offload decode step: per-layer
    recompute + merged segment attention + FFN, plus the embed/unembed
    ends.  Per-slot positions and valid lengths make the same compiled
    function serve uniform static batches and ragged continuous slots."""

    def __init__(self, cfg: ModelConfig, compress: Optional[str] = None,
                 group: int = 32):
        self.cfg = cfg
        self.compress = compress
        self.group = group
        self.layer = jax.jit(self._layer_step,
                             static_argnames=("l_pad", "s_pad"))

    def embed(self, params, token: Array, positions: Array) -> Array:
        return L.embed(token, params["embed"], self.cfg, positions)

    def finalize(self, params, x: Array) -> Array:
        x = L.apply_norm(x, params["final_norm"], self.cfg.rms_eps)
        return L.unembed(x, params["embed"], self.cfg)

    def _layer_step(self, x, lp, h_res, k_str, v_str, positions,
                    l_valid, s_valid, l_pad: int, s_pad: int):
        """positions: (b, 1) per-slot decode positions; l_valid: None
        (uniform, h_res exact) or (b,) per-slot recompute lengths;
        s_valid: scalar or (b,) streamed valid lengths."""
        cfg = self.cfg
        b = x.shape[0]
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wq"])
        k_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wk"])
        v_new = jnp.einsum("bsh,hnd->bsnd", h, lp["attn"]["wv"])
        if cfg.pos_embedding == "rope":
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new = L.apply_rope(k_new, positions, cfg.rope_theta)
        segments = []
        if l_pad > 0:
            k_rec, v_rec = RC.recompute_kv(h_res, lp["attn"]["wk"],
                                           lp["attn"]["wv"], cfg)
            segments.append((k_rec, v_rec, l_valid))
        if s_pad > 0:
            if self.compress == "int4":
                # streamed segment arrives packed; dequantize on device
                # (on TPU this fuses into the attention kernel — see
                # kernels/kv_dequant_attention.py)
                k_str = KQ.dequantize_jnp(*k_str, group=self.group)
                v_str = KQ.dequantize_jnp(*v_str, group=self.group)
            segments.append((k_str, v_str, s_valid))
        segments.append((k_new, v_new, None))
        out = RC.merged_decode_attention(q, segments, positions[:, 0])
        out = out.reshape(b, 1, cfg.num_heads * cfg.dh).astype(x.dtype)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, k_new, v_new, h


@dataclasses.dataclass
class StepStats:
    t_total: float
    t_wait_transfer: float      # GPU idle waiting on host data
    t_compute: float
    bytes_transferred: int
    split_l: int                             # max over slots
    split_ls: Optional[Tuple[int, ...]] = None   # per-slot (ragged steps)


class OffloadDecodeRuntime:
    """Plan-executing decode runtime for dense-family models with a
    host-offloaded KV cache.

    mode: "flexgen" (full KV streamed) | "kvpr" (partial recompute).
    Splits come from the scheduler's ExecutionPlan — never solved here.
    ``step()`` advances every active slot one token (slots may sit at
    ragged positions); ``decode()`` is the static-batch loop on top.
    """

    def __init__(self, cfg: ModelConfig, params,
                 hw: Optional[HardwareProfile] = None, *,
                 scheduler: Optional[Scheduler] = None,
                 mode: str = "kvpr", schedule: str = "row",
                 align: int = 1, n_copy_threads: int = 2,
                 compress: Optional[str] = None, group: int = 32,
                 offload_weights: bool = False,
                 fine_grained: bool = True):
        self.cfg = cfg
        self.params = params
        self.scheduler = scheduler or Scheduler(hw)
        self.mode = mode
        self.schedule = schedule
        self.align = align
        self.compress = compress
        self.offload_weights = offload_weights
        host_layers = None
        if offload_weights:
            n_layers = jax.tree.leaves(params["layers"])[0].shape[0]
            host_layers = [
                jax.tree.map(lambda a, i=i: np.asarray(a[i]),
                             params["layers"])
                for i in range(n_layers)]
        self.xfer = TransferEngine(n_copy_threads, host_layers,
                                   fine_grained)
        self.compute = ComputeStep(cfg, compress=compress, group=group)

    # ------------------------------------------------------------ planning

    def plan_for(self, batch: int) -> ExecutionPlan:
        """The runtime's schedule, from the scheduler's plan cache."""
        return self.scheduler.plan_for(
            self.cfg, batch, mode=self.mode, schedule=self.schedule,
            align=self.align, compress=self.compress, dtype_bytes=4)

    # ---------------------------------------------------------------- step

    def step(self, store: HostKVStore, token,
             plan: Optional[ExecutionPlan] = None, *,
             active: Optional[np.ndarray] = None,
             pad_to: Optional[int] = None) -> Tuple[Array, StepStats]:
        """One decode step for every slot; returns (logits, stats).

        Slots advance at their own positions (``store.seq_lens``); the
        plan supplies one SplitDecision per distinct (bucketed) length.
        ``active`` masks which slots store their new token and advance —
        inactive slots (empty, awaiting admission) compute garbage that
        is fully masked out of attention and never written back.
        """
        cfg = self.cfg
        params = self.params
        b = int(np.shape(token)[0])
        plan = plan if plan is not None else self.plan_for(b)
        seq_lens = np.asarray(store.seq_lens, np.int64).copy()
        if active is None:
            active = np.ones(b, bool)
        uniform = bool((seq_lens == seq_lens[0]).all())
        if uniform:
            split = plan.split_for(int(seq_lens[0]))
            ls = np.full(b, split.l, np.int64)
        else:
            ls = np.array([d.l for d in plan.splits_for_slots(seq_lens)],
                          np.int64)
        s_strs = seq_lens - ls
        l_pad = int(ls.max())
        s_exact = int(s_strs.max())
        if pad_to is None:
            s_pad = s_exact
        else:
            s_pad = min(-(-s_exact // pad_to) * pad_to,
                        store.max_len - int(ls.min()))

        t0 = time.perf_counter()
        positions = jnp.asarray(seq_lens[:, None], jnp.int32)
        x = self.compute.embed(params, jnp.asarray(token), positions)
        l_valid = None if uniform else jnp.asarray(ls, jnp.int32)
        s_valid = (jnp.asarray(s_exact, jnp.int32) if uniform
                   else jnp.asarray(s_strs, jnp.int32))

        t_wait = 0.0
        nbytes_total = 0
        # prefetch layer 0 (weights first when offloaded — they gate
        # recomputation; then the KV/activation stream)
        w_fut = (self.xfer.submit_weights(0) if self.offload_weights
                 else None)
        fut = self.xfer.submit(self.xfer.fetch_layer, store, 0, ls,
                               s_strs, l_pad, s_pad)
        new_kv = []
        for li in range(cfg.num_layers):
            tw0 = time.perf_counter()
            if self.offload_weights:
                lp, nb_w = self.xfer.weights_result(w_fut)
                nbytes_total += nb_w
            else:
                lp = jax.tree.map(lambda a: a[li], params["layers"])
            h_res, k_str, v_str, nb = fut.result()
            t_wait += time.perf_counter() - tw0
            nbytes_total += nb
            if li + 1 < cfg.num_layers:
                if self.offload_weights:
                    w_fut = self.xfer.submit_weights(li + 1)
                fut = self.xfer.submit(self.xfer.fetch_layer, store,
                                       li + 1, ls, s_strs, l_pad, s_pad)
            x, k_new, v_new, h_new = self.compute.layer(
                x, lp, h_res, k_str, v_str, positions, l_valid, s_valid,
                l_pad=l_pad, s_pad=s_pad)
            new_kv.append((li, k_new, v_new, h_new))

        logits = self.compute.finalize(params, x)
        logits.block_until_ready()

        # store new KV + activations back to host (async), then the
        # paper's Alg. 1 `synchronize()`: the next step's fetches must
        # not race with this step's stores.
        if uniform and active.all():
            store_pos = int(seq_lens[0])
        else:
            store_pos = np.where(active, seq_lens, -1)
        store_futs = [
            self.xfer.submit(store.append, li, np.asarray(k_new),
                             np.asarray(v_new), np.asarray(h_new),
                             store_pos)
            for (li, k_new, v_new, h_new) in new_kv]
        for f in store_futs:
            f.result()
        store.seq_lens[active] += 1

        dt = time.perf_counter() - t0
        stats = StepStats(dt, t_wait, dt - t_wait, nbytes_total, l_pad,
                          None if uniform else tuple(int(l) for l in ls))
        return logits, stats

    # -------------------------------------------------------------- decode

    def decode(self, store: HostKVStore, first_token: np.ndarray,
               gen_len: int, pad_to: Optional[int] = None,
               sample_fn=None, key=None
               ) -> Tuple[np.ndarray, List[StepStats]]:
        """Generate `gen_len` tokens for a uniform batch.

        sample_fn(logits (b, V), key) -> (b,) picks the next token
        (greedy argmax when None).  `key` is split EXACTLY once per
        generated token — engines mirror that consumption to keep their
        own PRNG stream in sync with the resident path, so any change
        here must keep the one-split-per-token contract.
        Returns (tokens, stats).
        """
        token = jnp.asarray(first_token)
        plan = self.plan_for(int(token.shape[0]))
        stats: List[StepStats] = []
        out_tokens = []
        for _ in range(gen_len):
            logits, st = self.step(store, token, plan, pad_to=pad_to)
            if sample_fn is None:
                token = jnp.argmax(logits[:, -1:], axis=-1).astype(
                    jnp.int32)
            else:
                sub = None
                if key is not None:
                    key, sub = jax.random.split(key)
                token = sample_fn(logits[:, -1], sub)[:, None]
            out_tokens.append(np.asarray(token))
            stats.append(st)
        return np.concatenate(out_tokens, axis=1), stats


def prefill_with_activations(model, params, tokens: Array):
    """Dense-family prefill that also returns per-layer attention-input
    activations (the host-resident tensors KVPR recomputes from).

    Returns (last_logits (b, 1, V), ks, vs, hs) — the caller samples the
    first token (so the engine's configured sampler applies) and spills
    ks/vs/hs into a HostKVStore slot.
    """
    cfg = model.cfg
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(tokens, params["embed"], cfg, jnp.arange(s))

    def body(x, lp):
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
        out = L.chunked_causal_attend(q, k, v)
        out = out.reshape(b, s, cfg.num_heads * cfg.dh)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, (k, v, h)

    x, (ks, vs, hs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    return logits, ks, vs, hs
