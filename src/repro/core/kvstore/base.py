"""Block-granular tier interface for the tiered KV store.

A *tier* stores fixed-width token blocks of per-layer K/V data for
(slot, block) coordinates.  The host DRAM tier (``host.HostKVStore``)
is the always-present top rung and keeps its historical slice-write
API; lower rungs (``disk.MmapDiskTier``) implement this narrower
block interface, which is all demotion/promotion needs:

  - demotion writes ONE block across every layer at once (the store
    pool already runs it off the hot path),
  - promotion (page-in) reads one layer's span of blocks at a time,
    inside the per-layer fetch task, so disk reads overlap the
    previous layer's compute exactly like the PCIe stream does.

Capacity is explicit at every rung: a tier that cannot take a block
raises a typed error (``StoreCapacityError`` for host-tier fills,
``DiskFullError`` for demotions) instead of silently growing.
"""
from __future__ import annotations

import abc
from typing import Optional

import numpy as np

from repro.core.faults import TransferError

__all__ = ["KVBlockTier", "StoreCapacityError"]


class StoreCapacityError(TransferError):
    """A fill would exceed the store tier's configured token capacity.
    Raised by ``bulk_fill`` / ``fill_slot`` (and block writes) instead
    of silently writing past the accounted budget: the caller — the
    admission path — must shrink, shed, or demote before retrying."""


class KVBlockTier(abc.ABC):
    """One rung below host DRAM in the KV storage ladder."""

    #: tokens per block (set by implementations)
    block_tokens: int

    @abc.abstractmethod
    def write_block(self, slot: int, block: int, k: np.ndarray,
                    v: np.ndarray) -> None:
        """Store one (slot, block): ``k``/``v`` are
        (num_layers, block_tokens, KV, dh) float arrays.  Raises
        ``DiskFullError`` when the tier is at capacity."""

    @abc.abstractmethod
    def read_block_layer(self, layer: int, slot: int, block: int,
                         out_k: np.ndarray, out_v: np.ndarray) -> None:
        """Read one layer of one block into ``out_k``/``out_v``
        ((block_tokens, KV, dh) views of the host arrays).  Raises
        ``DiskReadError`` on a failed read."""

    @abc.abstractmethod
    def free_block(self, slot: int, block: int) -> None:
        """Release one block's capacity (no-op when absent)."""

    @abc.abstractmethod
    def free_slot(self, slot: int) -> None:
        """Release every block of a slot."""

    @property
    @abc.abstractmethod
    def bytes_used(self) -> int:
        """Bytes currently accounted to resident blocks."""

    @property
    @abc.abstractmethod
    def capacity_bytes(self) -> Optional[int]:
        """Configured byte capacity (None = unbounded)."""

    def close(self) -> None:      # pragma: no cover - trivial default
        """Release backing resources (files, maps).  Idempotent."""
