"""The pinned-host DRAM tier: ``HostKVStore``, moved verbatim from
``core/runtime.py`` (which re-exports it for compatibility) and
extended with explicit capacity accounting.

Semantics are unchanged from the monolithic store: preallocated numpy
("pinned") K/V + activation arrays, per-slot sequence lengths, the
per-layer write-back fence ring and per-slot chunk-fence buckets.  New
here:

  - ``capacity_tokens``: an optional accounted token budget below the
    physical ``max_len`` allocation.  ``bulk_fill`` / ``fill_slot``
    REJECT an over-capacity fill with a typed ``StoreCapacityError``
    instead of an opaque numpy broadcast error (or, worse, silently
    landing in a bigger-than-budgeted allocation);
  - ``tier_bytes()``: per-tier byte/token accounting, extended by the
    tiered subclass with its disk rung.
"""
from __future__ import annotations

import threading
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import kvquant as KQ
from repro.core.faults import TransferStallError, WriteBackError
from repro.core.kvstore.base import StoreCapacityError

__all__ = ["HostKVStore"]


class HostKVStore:
    """Host-memory (numpy) per-layer KV + activation storage, preallocated
    ("pinned") to max_len so stores are slice writes, not reallocations.

    Slot-aware: ``seq_lens[i]`` is slot i's own cached length, so slots
    can hold sequences at different decode positions (continuous
    batching).  ``fill_slot`` spills a b=1 prefill into one slot;
    ``clear_slot`` frees it for the next admission.  The legacy ``len``
    property views the store as a uniform batch (max length; assigning
    sets every slot) for the static-batching path.

    Write-back fences: ``set_fence(li, fut)`` records the in-flight host
    store of layer li's new token; ``wait_fence(li)`` (called by the
    transfer engine before reading layer li) and ``sync()`` (called
    before bulk writes) are the only synchronization points — there is
    no global end-of-step barrier.

    compress="int4" keeps the KV cache group-wise 4-bit quantized in host
    memory (paper §4.4 / beyond-paper executable path): appends quantize
    once, fetches stream packed codes + scales (≈⅛ of the f32 bytes);
    activations stay exact — the KVPR-recomputed prefix loses nothing.

    ``capacity_tokens`` (optional) is the accounted DRAM token budget:
    a ``bulk_fill`` / ``fill_slot`` that would push the summed per-slot
    lengths past it raises ``StoreCapacityError`` — typed, so admission
    can shed or (in the tiered subclass) demote instead of guessing at
    a numpy broadcast error.
    """

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 dtype=np.float32, compress: Optional[str] = None,
                 group: int = 32,
                 fence_timeout_s: Optional[float] = None,
                 capacity_tokens: Optional[int] = None):
        Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                         cfg.d_model)
        self.compress = compress
        self.group = group
        self.batch = batch
        self.max_len = max_len
        self.capacity_tokens = (None if capacity_tokens is None
                                else int(capacity_tokens))
        if compress == "int4":
            ng = dh // group
            self.kq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
            self.vq = KQ.QuantizedKV(
                np.zeros((Lh, batch, max_len, KV, dh // 2), np.uint8),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32),
                np.zeros((Lh, batch, max_len, KV, ng), np.float32))
        else:
            self.k = np.zeros((Lh, batch, max_len, KV, dh), dtype)
            self.v = np.zeros((Lh, batch, max_len, KV, dh), dtype)
        self.act = np.zeros((Lh, batch, max_len, h), dtype)
        self.seq_lens = np.zeros((batch,), np.int64)
        self.lock = threading.Lock()
        self.num_layers = Lh
        self.fence_timeout_s = fence_timeout_s
        self._fences: List[Optional[object]] = [None] * Lh
        # chunk fences bucketed per slot (None = whole-batch fills), so
        # one slot's admission never waits another's in-flight chunks
        self._chunk_fences: Dict[Optional[int], List[object]] = {}
        self._chunk_lock = threading.Lock()

    # ------------------------------------------------------ head slices
    # Tensor-parallel (mesh) decode: each model-axis shard owns a
    # KV-head slice of every slot.  The slices are VIEWS into the one
    # host allocation — per-shard transfer streams read disjoint head
    # ranges of the same bytes, so concatenating the slices is the
    # full array by construction (no shard-local copies to keep
    # coherent, and demotion/eviction stay token-granular and
    # shard-agnostic in the tiered subclass).

    @property
    def num_kv_heads(self) -> int:
        buf = self.kq.packed if self.compress == "int4" else self.k
        return int(buf.shape[3])

    def head_slice(self, shards: int, si: int) -> Dict[str, np.ndarray]:
        """Shard ``si``'s head-slice views of the K/V planes (keys
        match the transfer engine's staging names: "k"/"v", or the int4
        "kp"/"ks"/"kz"/"vp"/"vs"/"vz" triple — every plane carries the
        KV-head axis at position 3, so all slice identically).
        Activations are replicated across shards and are NOT included.
        """
        kv = self.num_kv_heads
        if shards < 1 or kv % shards:
            raise ValueError(f"{shards} shards do not divide "
                             f"{kv} KV heads")
        if not 0 <= si < shards:
            raise ValueError(f"shard index {si} out of range "
                             f"[0, {shards})")
        per = kv // shards
        sl = slice(si * per, (si + 1) * per)
        if self.compress == "int4":
            return {"kp": self.kq.packed[:, :, :, sl],
                    "ks": self.kq.scale[:, :, :, sl],
                    "kz": self.kq.zero[:, :, :, sl],
                    "vp": self.vq.packed[:, :, :, sl],
                    "vs": self.vq.scale[:, :, :, sl],
                    "vz": self.vq.zero[:, :, :, sl]}
        return {"k": self.k[:, :, :, sl], "v": self.v[:, :, :, sl]}

    # `len` views the store as a uniform batch (static-batching path).
    @property
    def len(self) -> int:
        return int(self.seq_lens.max())

    @len.setter
    def len(self, value: int) -> None:
        self.seq_lens[:] = value

    # ---------------------------------------------------------- capacity

    @property
    def kv_token_bytes(self) -> int:
        """Host bytes one cached token occupies (K + V at the stored
        width, plus the attention-input activation row)."""
        if self.compress == "int4":
            KV = self.kq.packed.shape[3]
            dh2, ng = self.kq.packed.shape[4], self.kq.scale.shape[4]
            kv_b = 2 * KV * (dh2 + 2 * 4 * ng)
        else:
            KV, dh = self.k.shape[3], self.k.shape[4]
            kv_b = 2 * KV * dh * self.k.itemsize
        return int(kv_b + self.act.shape[3] * self.act.itemsize)

    def tier_bytes(self) -> Dict[str, Dict[str, int]]:
        """Per-tier byte/token accounting.  The base store reports its
        single DRAM rung; ``TieredKVStore`` extends the dict with the
        disk rung."""
        if self.compress == "int4":
            alloc = sum(b.nbytes for b in self.kq) \
                + sum(b.nbytes for b in self.vq) + self.act.nbytes
        else:
            alloc = self.k.nbytes + self.v.nbytes + self.act.nbytes
        used_tokens = int(self.seq_lens.sum())
        return {"host": {
            "allocated_bytes": int(alloc),
            "used_tokens": used_tokens,
            "used_bytes": used_tokens * self.kv_token_bytes,
            "capacity_tokens": (-1 if self.capacity_tokens is None
                                else self.capacity_tokens),
        }}

    def _check_capacity(self, new_lens: np.ndarray, what: str) -> None:
        """Typed rejection of an over-capacity fill: per-slot length
        past the physical allocation, or summed tokens past the
        accounted ``capacity_tokens`` budget."""
        if int(new_lens.max(initial=0)) > self.max_len:
            raise StoreCapacityError(
                f"{what}: slot length {int(new_lens.max())} exceeds "
                f"store max_len {self.max_len}")
        if self.capacity_tokens is not None:
            total = int(new_lens.sum())
            if total > self.capacity_tokens:
                raise StoreCapacityError(
                    f"{what}: {total} tokens exceed the host tier's "
                    f"capacity_tokens budget {self.capacity_tokens}")

    # ------------------------------------------------------------- fences

    def set_fence(self, layer: int, fut) -> None:
        """Record layer li's in-flight write-back (a Future)."""
        self._fences[layer] = fut

    @staticmethod
    def _fence_result(fut, timeout: Optional[float], what: str):
        """Resolve one write-back future with bounded patience and a
        typed verdict: a deadline miss becomes ``TransferStallError``
        (the watchdog — the pipeline is stalled/dead, never hang); an
        error raised inside the store task becomes ``WriteBackError``
        (the host copy is now incomplete — recompute fallbacks are
        unsound, callers must abort/contain instead).  Already-typed
        errors (a stall seen through a second fence, a per-request
        fault on a tagged store) pass through unwrapped so callers can
        still dispatch on type."""
        try:
            return fut.result(timeout)
        except FuturesTimeout:
            raise TransferStallError(
                f"{what} write-back exceeded fence timeout "
                f"({timeout:.3g}s): store pipeline stalled") from None
        except (TransferStallError, WriteBackError):
            raise
        except Exception as e:
            from repro.core.faults import RequestFaultError
            if isinstance(e, (RequestFaultError, StoreCapacityError)):
                raise
            raise WriteBackError(
                f"{what} write-back failed: {type(e).__name__}: {e}"
            ) from e

    def wait_fence(self, layer: int) -> None:
        """Block until layer li's last write-back has landed (no-op when
        none is in flight).  Fetches call this so a step never reads a
        layer the previous step is still storing.  Bounded by
        ``fence_timeout_s`` (None = wait forever): a stalled store pool
        raises ``TransferStallError`` instead of deadlocking decode."""
        f = self._fences[layer]
        if f is not None:
            self._fence_result(f, self.fence_timeout_s,
                               f"layer {layer}")

    _ALL_SLOTS = object()        # wait_chunks sentinel: every bucket

    def push_chunk_fence(self, fut, slot: Optional[int] = None) -> None:
        """Record an in-flight prefill-chunk write-back (a Future),
        bucketed by the slot it targets (None = a whole-batch fill).
        Chunk fences are coarser than the per-layer decode fences: one
        covers a whole chunk's K/V/activations across every layer.  A
        slot being chunk-filled is never decoded (its ``seq_lens`` entry
        stays at its pre-admission value until the prompt completes), so
        only ``wait_chunks``/``sync`` — not the per-layer fetch path —
        synchronize on them."""
        with self._chunk_lock:
            self._chunk_fences.setdefault(slot, []).append(fut)

    def wait_chunks(self, slot=_ALL_SLOTS) -> None:
        """Drain in-flight chunk write-backs (surfacing any store
        error) — one slot's bucket, or every bucket by default.
        Admission calls this once for ITS slot, after the LAST chunk
        was submitted, so the only un-overlapped write-back is the
        final chunk's (exactly the pipeline-drain term the chunk_split
        cost model charges) and a concurrent admission's in-flight
        chunks are never waited on.

        The WHOLE bucket is drained even when a chunk errored (so no
        orphaned future survives to poison a later tenant of the slot);
        the first error is re-raised after the drain, typed by
        ``_fence_result``."""
        first_err: Optional[BaseException] = None
        while True:
            with self._chunk_lock:
                if slot is self._ALL_SLOTS:
                    bucket = next((b for b in self._chunk_fences.values()
                                   if b), None)
                else:
                    bucket = self._chunk_fences.get(slot)
                if not bucket:
                    break
                fut = bucket.pop()
            try:
                self._fence_result(fut, self.fence_timeout_s, "chunk")
            except Exception as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err

    def sync(self, strict: bool = True) -> List[BaseException]:
        """Drain EVERY in-flight write-back (bulk writes + end of decode
        call this; the steady-state decode loop never does).

        All fences and chunk buckets are drained even when some
        errored, and drained fence slots are cleared — after ``sync``
        the store carries no poisoned future that could resurface at an
        unrelated caller's next fence wait.  ``strict=True`` (default)
        re-raises the first error; ``strict=False`` is the
        exception-path/cleanup form — it swallows and returns the
        collected errors so a failing caller can still leave the engine
        reusable."""
        errs: List[BaseException] = []
        for li in range(len(self._fences)):
            try:
                self.wait_fence(li)
            except Exception as e:
                errs.append(e)
            self._fences[li] = None
        try:
            self.wait_chunks()
        except Exception as e:
            errs.append(e)
        if strict and errs:
            raise errs[0]
        return errs

    # ------------------------------------------------------------- writes

    def _put_kv(self, layer, sl, k: np.ndarray, v: np.ndarray):
        if self.compress == "int4":
            for buf, x in ((self.kq, k), (self.vq, v)):
                q = KQ.quantize_np(x, self.group)
                buf.packed[layer, :, sl] = q.packed
                buf.scale[layer, :, sl] = q.scale
                buf.zero[layer, :, sl] = q.zero
        else:
            self.k[layer, :, sl] = k
            self.v[layer, :, sl] = v

    def _put_kv_slot(self, layer, slot, sl, k: np.ndarray, v: np.ndarray):
        if self.compress == "int4":
            for buf, x in ((self.kq, k), (self.vq, v)):
                q = KQ.quantize_np(x, self.group)
                buf.packed[layer, slot, sl] = q.packed
                buf.scale[layer, slot, sl] = q.scale
                buf.zero[layer, slot, sl] = q.zero
        else:
            self.k[layer, slot, sl] = k
            self.v[layer, slot, sl] = v

    def append(self, layer: int, k: np.ndarray, v: np.ndarray,
               act: np.ndarray, pos) -> None:
        """Store one new token per slot.  ``pos`` is an int (uniform
        batch: every slot writes the same position) or a (b,) vector of
        per-slot positions; a negative entry skips that slot."""
        if np.ndim(pos) == 0:
            self._put_kv(layer, slice(pos, pos + k.shape[1]), k, v)
            self.act[layer, :, pos:pos + act.shape[1]] = act
            return
        for i, p in enumerate(np.asarray(pos)):
            if p < 0:
                continue
            self._put_kv_slot(layer, i, slice(p, p + k.shape[1]),
                              k[i], v[i])
            self.act[layer, i, p:p + act.shape[1]] = act[i]

    def bulk_fill(self, ks, vs, acts, s: int, seq_lens=None) -> None:
        """Fill from prefill outputs: (L, b, s, KV, dh) / (L, b, s, h).

        ``seq_lens`` (optional, (b,)) are the TRUE per-slot prompt
        lengths of a LEFT-padded ragged prefill: slot i's real tokens
        occupy columns [s - len_i, s) of ks/vs/acts and are shifted to
        host positions [0, len_i), so every slot's cached prefix is
        position-native (host index == RoPE position, matching the
        per-slot ragged decode convention) and ``self.seq_lens`` records
        true lengths instead of the padded batch length."""
        self.sync()
        if seq_lens is not None:
            lens = np.asarray(seq_lens, np.int64)
            if lens.shape != (self.batch,):
                raise ValueError(f"seq_lens shape {lens.shape} != "
                                 f"({self.batch},)")
            self._check_capacity(lens, "bulk_fill")
            if not (lens == s).all():
                for i, n in enumerate(lens):
                    n = int(n)
                    pad = s - n
                    for li in range(ks.shape[0]):
                        self._put_kv_slot(li, i, slice(0, n),
                                          ks[li, i, pad:s],
                                          vs[li, i, pad:s])
                    self.act[:, i, :n] = acts[:, i, pad:s]
                self.seq_lens[:] = lens
                return
        else:
            self._check_capacity(
                np.full((self.batch,), s, np.int64), "bulk_fill")
        if self.compress == "int4":
            for li in range(ks.shape[0]):
                self._put_kv(li, slice(0, s), ks[li], vs[li])
        else:
            self.k[:, :, :s] = ks
            self.v[:, :, :s] = vs
        self.act[:, :, :s] = acts
        self.seq_lens[:] = s

    def fill_slot(self, slot: int, ks, vs, acts, s: int) -> None:
        """Spill a b=1 prefill — (L, 1, s, KV, dh) / (L, 1, s, h) — into
        one slot (iteration-level admission).  Drains in-flight
        write-backs first: a pending append from the slot's previous
        tenant must not land on top of the new request's prefill."""
        self.sync()
        new_lens = self.seq_lens.copy()
        new_lens[slot] = s
        self._check_capacity(new_lens, f"fill_slot({slot})")
        for li in range(ks.shape[0]):
            self._put_kv_slot(li, slot, slice(0, s), ks[li, 0], vs[li, 0])
        self.act[:, slot, :s] = acts[:, 0]
        self.seq_lens[slot] = s

    def fill_chunk(self, ks, vs, acts, start: int, pads=None) -> None:
        """Write one prefill chunk — (L, b, c, KV, dh) / (L, b, c, h)
        covering global prompt columns [start, start + c) — into host
        memory.  ``pads`` (optional, (b,)) are the per-slot left-pad
        widths of a ragged batch: slot i's real columns
        [max(start, pad_i), start + c) land at position-native host
        indices [col - pad_i, ...); rows entirely inside a slot's pad
        are skipped.  Does NOT touch ``seq_lens`` — the prefill driver
        marks the slot length once the whole prompt has landed, so a
        partially-filled slot is never decoded."""
        c = ks.shape[2]
        if pads is None:
            if self.compress == "int4":
                for li in range(ks.shape[0]):
                    self._put_kv(li, slice(start, start + c),
                                 ks[li], vs[li])
            else:
                self.k[:, :, start:start + c] = ks
                self.v[:, :, start:start + c] = vs
            self.act[:, :, start:start + c] = acts
            return
        for i, pad in enumerate(np.asarray(pads)):
            lo = max(start, int(pad))          # first real global column
            if lo >= start + c:
                continue
            off = lo - start
            dst = slice(lo - int(pad), start + c - int(pad))
            for li in range(ks.shape[0]):
                self._put_kv_slot(li, i, dst, ks[li, i, off:],
                                  vs[li, i, off:])
            self.act[:, i, dst] = acts[:, i, off:]

    def fill_chunk_slot(self, slot: int, ks, vs, acts, start: int
                        ) -> None:
        """Write a b=1 prefill chunk — (L, 1, c, ...) at positions
        [start, start + c) — into one slot (iteration-level chunked
        admission).  Like ``fill_chunk``, never touches ``seq_lens``."""
        c = ks.shape[2]
        sl = slice(start, start + c)
        for li in range(ks.shape[0]):
            self._put_kv_slot(li, slot, sl, ks[li, 0], vs[li, 0])
        self.act[:, slot, sl] = acts[:, 0]

    def clear_slot(self, slot: int) -> None:
        """Free a slot for the next admission (data may stay stale: every
        fetch copies/masks only the valid prefix)."""
        self.seq_lens[slot] = 0

    def close(self) -> None:
        """Release backing resources.  The DRAM tier has none (numpy
        arrays free with the object); the tiered subclass closes its
        disk rung here.  Idempotent."""
