"""``TieredKVStore``: the pinned-host DRAM tier backed by an mmap disk
rung, with hierarchy-aware residency the scheduler can plan against.

Residency invariant — **the demoted region of every slot is a PREFIX**
``[0, disk_end_i)`` of its cached tokens, in whole ``block_tokens``
blocks.  Demotion pushes the prefix boundary up (coldest tokens first:
the front of the sequence is exactly what the transfer-vs-recompute
split prefers to recompute anyway); a fetch window ``[l, s)`` pages in
the suffix of that prefix and shrinks it back to ``floor_block(l)`` —
still a prefix.  ``disk_tokens()`` therefore compresses the whole
residency map into one integer per slot, which is what the fourth plan
kind (``ExecutionPlan.tier_split_for``) consumes.

Why torn reads are impossible by construction: the tier machinery
NEVER invalidates host bytes.  Demotion copies a block to disk and
moves the accounting boundary; page-in copies the block back over the
same host bytes (bit-identical under the lossless ``raw`` layout).
Decode always reads valid values no matter how the boundary races with
a concurrent fetch — the mmap read + bandwidth throttle model the
COST of the page-in, the correctness never depends on its timing.
Activations are deliberately never demoted: the l = p full-recompute
fallback (the PR 7 degradation ladder) reads only activations, so a
failing disk never blocks the escape hatch.

Eviction is dual LRU + TTL: capacity pressure demotes the least-
recently-touched slot's next front block (``host_capacity_tokens`` is
the accounted DRAM budget); ``sweep()`` — called once per decode step
by the runtime — additionally demotes every full block of slots idle
longer than ``ttl_s``.  A demotion that fails (``DiskFullError``,
injected ``disk_write`` faults) is benign: the block stays in DRAM and
``demote_failures`` counts it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import kvquant as KQ
from repro.core.cost_model import HardwareProfile, TierLink
from repro.core.faults import FaultPolicy, TransferError
from repro.core.kvstore.disk import MmapDiskTier
from repro.core.kvstore.host import HostKVStore

__all__ = ["KVTiersConfig", "TieredKVStore", "TieredStoreStats"]


@dataclasses.dataclass(frozen=True)
class KVTiersConfig:
    """Knobs for the tiered KV store (``EngineConfig(kv_tiers=...)``).

    ``host_capacity_tokens`` is the accounted DRAM budget: tokens past
    it are demoted (coldest slot first) to the disk rung — unlike the
    bare ``HostKVStore``'s ``capacity_tokens``, which REJECTS.  The
    ``policy`` picks the scheduler integration: ``"tier_split"`` (the
    fourth plan kind — splits are solved over both links) or
    ``"demand"`` (the naive demand-paging baseline: plans stay
    disk-blind and every demoted token is paged in on use; this is the
    baseline ``bench_tiered.py`` beats)."""
    host_capacity_tokens: Optional[int] = None
    block_tokens: int = 32
    ttl_s: Optional[float] = None
    compress_on_demote: bool = False
    disk_capacity_tokens: Optional[int] = None
    disk_dir: Optional[str] = None
    disk_read_bytes_per_s: Optional[float] = None
    disk_write_bytes_per_s: Optional[float] = None
    policy: str = "tier_split"

    def validate(self) -> None:
        if self.block_tokens <= 0:
            raise ValueError("kv_tiers.block_tokens must be positive")
        if self.policy not in ("tier_split", "demand"):
            raise ValueError(
                f"kv_tiers.policy must be 'tier_split' or 'demand', "
                f"got {self.policy!r}")
        if (self.host_capacity_tokens is not None
                and self.host_capacity_tokens < self.block_tokens):
            raise ValueError(
                "kv_tiers.host_capacity_tokens must cover at least one "
                "block")
        if self.ttl_s is not None and self.ttl_s <= 0:
            raise ValueError("kv_tiers.ttl_s must be positive")


@dataclasses.dataclass
class TieredStoreStats:
    """Counters the tiered store accumulates (snapshot via ``stats``)."""
    demotions: int = 0           # blocks pushed to disk (capacity)
    ttl_demotions: int = 0       # blocks pushed to disk (TTL sweep)
    demote_failures: int = 0     # demotions skipped (disk full/fault)
    promotions: int = 0          # layer-blocks paged back in
    disk_bytes_read: int = 0
    disk_bytes_written: int = 0
    demoted_tokens: int = 0      # current sum of disk prefixes
    host_tokens: int = 0         # current DRAM-resident tokens


class TieredKVStore(HostKVStore):
    """Host DRAM + mmap disk, presenting the exact ``HostKVStore``
    surface (same arrays, fences, fills) plus the tier machinery the
    runtime and scheduler hook into: ``disk_tokens()`` for the
    tier_split geometry, ``page_in()`` invoked inside each per-layer
    fetch task, ``sweep()`` once per decode step."""

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 tiers: Optional[KVTiersConfig] = None,
                 dtype=np.float32, compress: Optional[str] = None,
                 group: int = 32,
                 fence_timeout_s: Optional[float] = None,
                 faults: Optional[FaultPolicy] = None):
        tiers = tiers or KVTiersConfig()
        tiers.validate()
        super().__init__(cfg, batch, max_len, dtype=dtype,
                         compress=compress, group=group,
                         fence_timeout_s=fence_timeout_s)
        self.tiers = tiers
        self.tier_policy = tiers.policy
        bt = int(tiers.block_tokens)
        self.block_tokens = bt
        self.host_capacity_tokens = tiers.host_capacity_tokens
        # disk layout: lossless raw mirror by default; int4 pack when
        # compress-on-demote is asked for on an uncompressed host; a
        # verbatim triple mirror when the host is ALREADY int4 (no
        # second lossy step)
        if compress == "int4":
            layout = "mirror4"
        elif tiers.compress_on_demote:
            layout = "pack"
        else:
            layout = "raw"
        self.tier = MmapDiskTier(
            cfg, batch, max_len, bt, layout=layout, group=group,
            capacity_tokens=tiers.disk_capacity_tokens,
            directory=tiers.disk_dir,
            read_bytes_per_s=tiers.disk_read_bytes_per_s,
            write_bytes_per_s=tiers.disk_write_bytes_per_s,
            faults=faults)
        # tokens [0, _disk_end[i]) of slot i are accounted to disk
        # (block multiples; host bytes stay valid — see module doc)
        self._disk_end = np.zeros((batch,), np.int64)
        self._last_touch = np.zeros((batch,), np.float64)
        self._demote_lock = threading.Lock()
        self._tstats = TieredStoreStats()
        self._closed = False

    # --------------------------------------------------------- accounting

    def disk_tokens(self) -> np.ndarray:
        """Per-slot demoted-prefix lengths (the ``d`` the fourth plan
        kind consumes).  Snapshot copy — safe to hand to the planner
        while demotion runs on the store pool."""
        with self.lock:
            return self._disk_end.copy()

    @property
    def host_tokens(self) -> int:
        """Tokens currently accounted to DRAM."""
        with self.lock:
            return int(self.seq_lens.sum() - self._disk_end.sum())

    # ------------------------------------------------------ planner hooks

    @property
    def disk_bytes_per_el(self) -> float:
        """Disk bytes per stored KV element — what the tier_split cost
        model charges the disk crossing: 4.0 (f32) for the lossless raw
        layout; the int4 packed width (half a byte plus scale/zero
        amortized over the quantization group) for pack/mirror4."""
        if self.tier.layout == "raw":
            return 4.0
        return 0.5 + 8.0 / float(self.group)

    def hw_ladder(self, hw: HardwareProfile) -> HardwareProfile:
        """``hw`` extended with this store's disk rung, for plan keying.
        When the rung is unthrottled (no emulated bandwidth) it is
        priced at the host link's speed — the split then degenerates
        toward the single-link optimum, which is exactly right when the
        disk crossing is effectively free."""
        read_bw = self.tiers.disk_read_bytes_per_s or hw.v_com
        write_bw = self.tiers.disk_write_bytes_per_s or hw.v_com
        return hw.with_tiers(TierLink("disk", float(read_bw),
                                      float(write_bw)))

    def stats(self) -> TieredStoreStats:
        with self.lock:
            out = dataclasses.replace(self._tstats)
            out.demoted_tokens = int(self._disk_end.sum())
            out.host_tokens = int(self.seq_lens.sum()
                                  - self._disk_end.sum())
        out.disk_bytes_read = self.tier.bytes_read
        out.disk_bytes_written = self.tier.bytes_written
        return out

    def tier_bytes(self) -> Dict[str, Dict[str, int]]:
        out = super().tier_bytes()
        with self.lock:
            demoted = int(self._disk_end.sum())
            used = int(self.seq_lens.sum()) - demoted
        out["host"]["used_tokens"] = used
        out["host"]["used_bytes"] = used * self.kv_token_bytes
        out["host"]["capacity_tokens"] = (
            -1 if self.host_capacity_tokens is None
            else self.host_capacity_tokens)
        cap = self.tier.capacity_tokens
        out["disk"] = {
            "allocated_bytes": self.tier.bytes_used,
            "used_tokens": demoted,
            "used_bytes": self.tier.bytes_used,
            "capacity_tokens": -1 if cap is None else cap,
        }
        return out

    def _touch(self, slot: int) -> None:
        self._last_touch[slot] = time.monotonic()

    # ----------------------------------------------------------- demotion

    def _demotable(self, i: int) -> bool:
        """Slot i has a full block of real tokens past its disk prefix.

        The ``- 1`` is a one-token safety margin: all of a decode step's
        per-layer appends write the SAME position (``seq_lens[i] - 1``
        once the main thread has advanced), so at any instant the only
        host bytes that may still be mid-write belong to that newest
        token.  Never demoting a block that contains it means demotion
        only ever copies fully-landed bytes to disk."""
        return (self._disk_end[i] + self.block_tokens
                <= self.seq_lens[i] - 1)

    def _demote_front_block(self, i: int) -> bool:
        """Push slot i's front non-demoted block to disk.  Returns
        False (and counts ``demote_failures``) when the disk rung
        refuses — the block simply stays in DRAM.  Serialized under
        ``_demote_lock``; the boundary is re-checked before it is
        advanced so a concurrent page-in shrink is never overwritten."""
        bt = self.block_tokens
        with self._demote_lock:
            with self.lock:
                d = int(self._disk_end[i])
            jb = d // bt
            sl = slice(d, d + bt)
            try:
                if self.compress == "int4":
                    self.tier.write_block_q(
                        i, jb,
                        KQ.QuantizedKV(self.kq.packed[:, i, sl],
                                       self.kq.scale[:, i, sl],
                                       self.kq.zero[:, i, sl]),
                        KQ.QuantizedKV(self.vq.packed[:, i, sl],
                                       self.vq.scale[:, i, sl],
                                       self.vq.zero[:, i, sl]))
                else:
                    self.tier.write_block(i, jb, self.k[:, i, sl],
                                          self.v[:, i, sl])
            except (TransferError, OSError):
                with self.lock:
                    self._tstats.demote_failures += 1
                return False
            with self.lock:
                if int(self._disk_end[i]) != d:
                    # a page-in shrank the prefix while we wrote: the
                    # block's host bytes are authoritative again
                    self.tier.free_block(i, jb)
                    return False
                self._disk_end[i] = d + bt
                self._tstats.demotions += 1
        return True

    def enforce_capacity(self) -> int:
        """Demote least-recently-touched slots' front blocks until the
        DRAM-resident token count fits ``host_capacity_tokens``.
        Called after fills and from ``sweep()`` — always off the decode
        hot path (fills run on the store pool; sweep runs between
        steps).  Returns the number of blocks demoted."""
        cap = self.host_capacity_tokens
        if cap is None:
            return 0
        n = 0
        blocked = set()
        while True:
            with self.lock:
                resident = int(self.seq_lens.sum()
                               - self._disk_end.sum())
                if resident <= cap:
                    break
                order = np.argsort(self._last_touch, kind="stable")
                victim = next((int(i) for i in order
                               if i not in blocked
                               and self._demotable(int(i))), None)
            if victim is None:
                break
            if self._demote_front_block(victim):
                n += 1
            else:
                blocked.add(victim)    # disk refused: don't spin on it
        return n

    def sweep(self) -> int:
        """Dual-eviction sweep, called once per decode step by the
        runtime: demote every full block of slots idle past ``ttl_s``,
        then re-enforce the capacity budget.  Cheap when nothing is
        over budget or idle."""
        demoted = 0
        ttl = self.tiers.ttl_s
        if ttl is not None:
            now = time.monotonic()
            with self.lock:
                idle = [i for i in range(self.batch)
                        if self.seq_lens[i] > 0
                        and now - self._last_touch[i] > ttl
                        and self._demotable(i)]
            for i in idle:
                while True:
                    with self.lock:
                        more = self._demotable(i)
                    if not more or not self._demote_front_block(i):
                        break
                    demoted += 1
                    with self.lock:
                        self._tstats.ttl_demotions += 1
        return demoted + self.enforce_capacity()

    # ------------------------------------------------------------ page-in

    def page_in(self, layer: int, ls, s_strs) -> None:
        """Promote the demoted share of this layer's fetch windows back
        into the host arrays.  Runs INSIDE the per-layer fetch task on
        the copy pool, so the disk read overlaps the previous layer's
        compute exactly like the PCIe stream does; a failed block read
        raises ``DiskReadError`` (a ``TransientTransferError``), which
        rides the fetch path's existing retry → degradation ladder.

        Window: slot i's fetch streams host positions
        ``[ls[i], ls[i] + s_strs[i])``; the part below ``disk_end_i``
        must cross disk→host first.  Whole blocks are read (the block
        containing ``ls[i]`` included).  When the LAST layer's windows
        land, the slot's disk prefix shrinks to ``floor_block(ls[i])``
        and the freed blocks release their disk capacity."""
        bt = self.block_tokens
        ls = np.asarray(ls)
        s_strs = np.asarray(s_strs)
        final = layer == self.num_layers - 1
        for i in range(min(len(ls), self.batch)):
            n_str = int(s_strs[i])
            if n_str <= 0:
                continue
            with self.lock:
                d = int(self._disk_end[i])
            lo_tok = int(ls[i])
            hi_tok = min(lo_tok + n_str, d)
            if hi_tok <= lo_tok:
                continue
            lo_b, hi_b = lo_tok // bt, -(-hi_tok // bt)
            for jb in range(lo_b, hi_b):
                sl = slice(jb * bt, (jb + 1) * bt)
                if self.compress == "int4":
                    kq, vq = self.tier.read_block_layer_q(layer, i, jb)
                    for buf, q in ((self.kq, kq), (self.vq, vq)):
                        buf.packed[layer, i, sl] = q.packed
                        buf.scale[layer, i, sl] = q.scale
                        buf.zero[layer, i, sl] = q.zero
                else:
                    self.tier.read_block_layer(
                        layer, i, jb, self.k[layer, i, sl],
                        self.v[layer, i, sl])
                with self.lock:
                    self._tstats.promotions += 1
            if final:
                new_end = (lo_tok // bt) * bt
                with self.lock:
                    old_end = int(self._disk_end[i])
                    if new_end < old_end:
                        self._disk_end[i] = new_end
                        for jb in range(new_end // bt, old_end // bt):
                            self.tier.free_block(i, jb)

    # ----------------------------------------------- HostKVStore overrides

    def bulk_fill(self, ks, vs, acts, s, seq_lens=None) -> None:
        super().bulk_fill(ks, vs, acts, s, seq_lens=seq_lens)
        for i in range(self.batch):
            self._touch(i)
        with self.lock:
            self._disk_end[:] = 0
        for i in range(self.batch):
            self.tier.free_slot(i)
        self.enforce_capacity()

    def fill_slot(self, slot: int, ks, vs, acts, s: int) -> None:
        super().fill_slot(slot, ks, vs, acts, s)
        self._touch(slot)
        with self.lock:
            self._disk_end[slot] = 0
        self.tier.free_slot(slot)
        self.enforce_capacity()

    def append(self, layer, k, v, act, pos) -> None:
        super().append(layer, k, v, act, pos)
        if layer == self.num_layers - 1:
            if np.ndim(pos) == 0:
                for i in range(self.batch):
                    self._touch(i)
            else:
                for i, p in enumerate(np.asarray(pos)):
                    if p >= 0:
                        self._touch(i)
            self.enforce_capacity()

    def clear_slot(self, slot: int) -> None:
        super().clear_slot(slot)
        with self.lock:
            self._disk_end[slot] = 0
        self.tier.free_slot(slot)

    def close(self) -> None:
        """Release the disk rung's backing files.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.tier.close()
