"""The mmap disk tier: block-granular K/V storage in memory-mapped
files (``np.memmap`` — no new dependencies), with optional int4
compress-on-demote, explicit capacity, emulated bandwidth, and fault
hooks.

Layout: one backing file per array, shaped exactly like the host
arrays ((L, batch, max_len, ...)) so a block's bytes live at their
natural offset — no allocation map, and the files are sparse until
blocks are actually demoted.  Three layouts:

  - ``layout="raw"``      float32 K/V, mirrors an uncompressed host
                          store bit-exactly (the LOSSLESS default — the
                          identity matrix runs over this);
  - ``layout="pack"``     group-wise int4 on demotion (compress_on_
                          demote): quantize on write, dequantize on
                          page-in.  Lossy by design, like KVComp's
                          cold-block compression;
  - ``layout="mirror4"``  the host store is ALREADY int4: the demoted
                          triple (packed/scale/zero) is mirrored
                          verbatim — no second lossy step.

Fault surface: every block read passes ``FaultPolicy.on_op(
"disk_read")`` (injected failures raise ``DiskReadError``, a
``TransientTransferError`` — the transfer engine's retry/degradation
ladder handles it); every block write passes ``on_op("disk_write")``
and checks capacity (``DiskFullError`` — the caller keeps the block in
DRAM).  ``read_bytes_per_s`` / ``write_bytes_per_s`` emulate a slow
rung by sleeping per transfer, the same convention the TransferEngine
uses for the PCIe link.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from typing import Dict, Optional, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import kvquant as KQ
from repro.core.faults import (DiskFullError, DiskReadError,
                               FaultPolicy)
from repro.core.kvstore.base import KVBlockTier

__all__ = ["MmapDiskTier"]


class MmapDiskTier(KVBlockTier):
    """Memory-mapped block storage for demoted KV prefixes."""

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 block_tokens: int, layout: str = "raw",
                 group: int = 32,
                 capacity_tokens: Optional[int] = None,
                 directory: Optional[str] = None,
                 read_bytes_per_s: Optional[float] = None,
                 write_bytes_per_s: Optional[float] = None,
                 faults: Optional[FaultPolicy] = None):
        if layout not in ("raw", "pack", "mirror4"):
            raise ValueError(f"unknown disk layout {layout!r}")
        Lh, KV, dh = cfg.num_layers, cfg.num_kv_heads, cfg.dh
        self.block_tokens = int(block_tokens)
        self.layout = layout
        self.group = group
        self.capacity_tokens = (None if capacity_tokens is None
                                else int(capacity_tokens))
        self.read_bytes_per_s = read_bytes_per_s
        self.write_bytes_per_s = write_bytes_per_s
        self.faults = faults
        self._owns_dir = directory is None
        self.dir = (tempfile.mkdtemp(prefix="kvtier-")
                    if directory is None else str(directory))
        os.makedirs(self.dir, exist_ok=True)
        self._lock = threading.Lock()
        self._resident: Set[Tuple[int, int]] = set()   # (slot, block)
        self._closed = False
        self.reads = 0
        self.writes = 0
        self.bytes_read = 0
        self.bytes_written = 0

        bt = self.block_tokens
        if layout == "raw":
            self._k = self._map("k", (Lh, batch, max_len, KV, dh),
                                np.float32)
            self._v = self._map("v", (Lh, batch, max_len, KV, dh),
                                np.float32)
            self._block_bytes = 2 * Lh * bt * KV * dh * 4
        else:
            ng = dh // group
            self._maps: Dict[str, np.memmap] = {}
            for name in ("kp", "vp"):
                self._maps[name] = self._map(
                    name, (Lh, batch, max_len, KV, dh // 2), np.uint8)
            for name in ("ks", "kz", "vs", "vz"):
                self._maps[name] = self._map(
                    name, (Lh, batch, max_len, KV, ng), np.float32)
            self._block_bytes = 2 * Lh * bt * KV * (dh // 2 + 2 * 4 * ng)
        self._layer_block_bytes = self._block_bytes // Lh

    def _map(self, name: str, shape, dtype) -> np.memmap:
        return np.memmap(os.path.join(self.dir, f"{name}.bin"),
                         dtype=dtype, mode="w+", shape=shape)

    # --------------------------------------------------------- accounting

    @property
    def bytes_used(self) -> int:
        with self._lock:
            return len(self._resident) * self._block_bytes

    @property
    def capacity_bytes(self) -> Optional[int]:
        if self.capacity_tokens is None:
            return None
        return ((self.capacity_tokens // self.block_tokens)
                * self._block_bytes)

    @property
    def resident_blocks(self) -> int:
        with self._lock:
            return len(self._resident)

    def _throttle(self, nbytes: int, rate: Optional[float]) -> None:
        if rate:
            time.sleep(nbytes / float(rate))

    def _span(self, block: int) -> slice:
        lo = block * self.block_tokens
        return slice(lo, lo + self.block_tokens)

    # -------------------------------------------------------------- write

    def write_block(self, slot: int, block: int, k: np.ndarray,
                    v: np.ndarray) -> None:
        """Demote one (slot, block): ``k``/``v`` are (L, bt, KV, dh)
        float arrays (``layout="mirror4"`` uses ``write_block_q``)."""
        self._reserve(slot, block)
        if self.faults is not None:
            self.faults.on_op("disk_write")
        sl = self._span(block)
        if self.layout == "raw":
            self._k[:, slot, sl] = k
            self._v[:, slot, sl] = v
        else:                                  # pack: int4 on demote
            m = self._maps
            for pre, x in (("k", k), ("v", v)):
                q = KQ.quantize_np(x, self.group)
                m[pre + "p"][:, slot, sl] = q.packed
                m[pre + "s"][:, slot, sl] = q.scale
                m[pre + "z"][:, slot, sl] = q.zero
        self._commit_write()

    def write_block_q(self, slot: int, block: int, kq: KQ.QuantizedKV,
                      vq: KQ.QuantizedKV) -> None:
        """Demote one already-quantized block ((L, bt, ...) triples from
        an int4 host store) verbatim — no recompression."""
        self._reserve(slot, block)
        if self.faults is not None:
            self.faults.on_op("disk_write")
        sl = self._span(block)
        m = self._maps
        for pre, q in (("k", kq), ("v", vq)):
            m[pre + "p"][:, slot, sl] = q.packed
            m[pre + "s"][:, slot, sl] = q.scale
            m[pre + "z"][:, slot, sl] = q.zero
        self._commit_write()

    def _reserve(self, slot: int, block: int) -> None:
        with self._lock:
            if self._closed:
                raise DiskFullError("disk tier is closed")
            if (slot, block) in self._resident:
                return
            if (self.capacity_tokens is not None
                    and (len(self._resident) + 1) * self.block_tokens
                    > self.capacity_tokens):
                raise DiskFullError(
                    f"disk tier at capacity "
                    f"({self.capacity_tokens} tokens): cannot demote "
                    f"block (slot={slot}, block={block})")
            self._resident.add((slot, block))

    def _commit_write(self) -> None:
        with self._lock:
            self.writes += 1
            self.bytes_written += self._block_bytes
        self._throttle(self._block_bytes, self.write_bytes_per_s)

    # --------------------------------------------------------------- read

    def read_block_layer(self, layer: int, slot: int, block: int,
                         out_k: np.ndarray, out_v: np.ndarray) -> None:
        """Page one layer of one block into the host views
        ``out_k``/``out_v`` ((bt, KV, dh) float32)."""
        with self._lock:
            if (slot, block) not in self._resident:
                raise DiskReadError(
                    f"block (slot={slot}, block={block}) not resident "
                    f"on the disk tier")
        if self.faults is not None:
            self.faults.on_op("disk_read")
        sl = self._span(block)
        if self.layout == "raw":
            out_k[...] = self._k[layer, slot, sl]
            out_v[...] = self._v[layer, slot, sl]
        else:
            m = self._maps
            out_k[...] = KQ.dequantize_np(KQ.QuantizedKV(
                np.asarray(m["kp"][layer, slot, sl]),
                np.asarray(m["ks"][layer, slot, sl]),
                np.asarray(m["kz"][layer, slot, sl])), self.group)
            out_v[...] = KQ.dequantize_np(KQ.QuantizedKV(
                np.asarray(m["vp"][layer, slot, sl]),
                np.asarray(m["vs"][layer, slot, sl]),
                np.asarray(m["vz"][layer, slot, sl])), self.group)
        nbytes = self._layer_block_bytes
        with self._lock:
            self.reads += 1
            self.bytes_read += nbytes
        self._throttle(nbytes, self.read_bytes_per_s)

    def read_block_layer_q(self, layer: int, slot: int, block: int
                           ) -> Tuple[KQ.QuantizedKV, KQ.QuantizedKV]:
        """Page one layer of one mirrored int4 block back as the raw
        triple (for promotion into an int4 host store)."""
        with self._lock:
            if (slot, block) not in self._resident:
                raise DiskReadError(
                    f"block (slot={slot}, block={block}) not resident "
                    f"on the disk tier")
        if self.faults is not None:
            self.faults.on_op("disk_read")
        sl = self._span(block)
        m = self._maps
        kq = KQ.QuantizedKV(np.array(m["kp"][layer, slot, sl]),
                            np.array(m["ks"][layer, slot, sl]),
                            np.array(m["kz"][layer, slot, sl]))
        vq = KQ.QuantizedKV(np.array(m["vp"][layer, slot, sl]),
                            np.array(m["vs"][layer, slot, sl]),
                            np.array(m["vz"][layer, slot, sl]))
        nbytes = self._layer_block_bytes
        with self._lock:
            self.reads += 1
            self.bytes_read += nbytes
        self._throttle(nbytes, self.read_bytes_per_s)
        return kq, vq

    # --------------------------------------------------------------- free

    def free_block(self, slot: int, block: int) -> None:
        with self._lock:
            self._resident.discard((slot, block))

    def free_slot(self, slot: int) -> None:
        with self._lock:
            self._resident = {(s, b) for (s, b) in self._resident
                              if s != slot}

    def close(self) -> None:
        """Drop the maps and (when this tier created its tempdir)
        remove the backing files.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._resident.clear()
        if self.layout == "raw":
            self._k, self._v = None, None
        else:
            self._maps = {}
        if self._owns_dir:
            shutil.rmtree(self.dir, ignore_errors=True)
