"""Tiered KV storage hierarchy: pinned host DRAM (top rung, the
historical ``HostKVStore``) over a memory-mapped disk tier, with
block-granular demotion/promotion and typed capacity errors.

``core/runtime.py`` re-exports ``HostKVStore`` so existing imports
keep working; new code should import from here.
"""
from repro.core.kvstore.base import KVBlockTier, StoreCapacityError
from repro.core.kvstore.disk import MmapDiskTier
from repro.core.kvstore.host import HostKVStore
from repro.core.kvstore.tiered import (KVTiersConfig, TieredKVStore,
                                       TieredStoreStats)

__all__ = [
    "HostKVStore",
    "KVBlockTier",
    "KVTiersConfig",
    "MmapDiskTier",
    "StoreCapacityError",
    "TieredKVStore",
    "TieredStoreStats",
]
