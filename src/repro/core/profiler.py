"""Profiler module (paper §3.1): measures link bandwidth and matmul
throughput for the workload's shapes, producing a HardwareProfile.

On the CPU-only validation runtime we measure real host memcpy bandwidth
(numpy copy through a preallocated "pinned" buffer — the same double-copy
a pageable->pinned->device path would take) and real matmul throughput at
the recompute GEMM shapes. On TPU this module would time device_put into
HBM and a jit'd GEMM; the interfaces are identical.
"""
from __future__ import annotations

import threading
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import HardwareProfile


def measure_link_bandwidth(nbytes: int = 1 << 26, iters: int = 3) -> float:
    """Host->device transfer bytes/s. On CPU backend this is memcpy-bound,
    which is exactly the role PCIe plays on the paper's system."""
    src = np.ones(nbytes // 4, np.float32)
    # warmup
    jax.device_put(src).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.device_put(src).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return nbytes / dt


def measure_gemm_flops(m: int = 2048, k: int = 2048, n: int = 2048,
                       iters: int = 3, dtype=jnp.float32) -> float:
    """Matmul FLOP/s at recompute-like shapes."""
    a = jnp.ones((m, k), dtype)
    b = jnp.ones((k, n), dtype)
    f = jax.jit(lambda a, b: a @ b)
    f(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(a, b).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return 2 * m * k * n / dt


def measure_dispatch_overhead(iters: int = 20) -> float:
    """Fixed per-dispatch latency (s) of one already-compiled jitted
    call on a tiny array: the launch cost the chunked-prefill planner
    charges once per chunk (small chunks pay it n/c times)."""
    f = jax.jit(lambda a: a + 1.0)
    x = jnp.zeros((8,), jnp.float32)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(x).block_until_ready()
    return (time.perf_counter() - t0) / iters


_PROFILE_CACHE: dict = {}
_PROFILE_LOCK = threading.Lock()
# Schedulers whose HardwareProfile came from profile_system(), keyed by
# the profile NAME they adopted: a later force=True re-measure of that
# name pushes the fresh profile into them (and only them — re-measuring
# another name must not clobber their profile).  WeakSets so a
# registered scheduler's lifetime is unchanged.
_LIVE_SCHEDULERS: dict = {}


def register_scheduler(sched, name: str = "measured") -> None:
    """Register a live Scheduler that adopted the measured profile
    ``name``, so a later ``profile_system(name, force=True)``
    re-measure notifies it (``invalidate(hw=new_profile)``) instead of
    leaving it holding a stale profile and stale plans."""
    with _PROFILE_LOCK:
        _LIVE_SCHEDULERS.setdefault(name, weakref.WeakSet()).add(sched)


def profile_system(name: str = "measured",
                   force: bool = False) -> HardwareProfile:
    """Measure (once) and return the system profile.

    The measurement is memoized per `name` and guarded by a process
    lock: engines profile from multiple threads under continuous
    batching, and every caller must observe the SAME profile object —
    identical profiles make their plan-cache keys identical.

    Pass force=True to re-measure: the fresh profile replaces the
    cached one AND is pushed into every live Scheduler registered via
    ``register_scheduler`` (``invalidate(hw=...)``), so cached plans
    keyed by the stale profile are dropped automatically.
    """
    with _PROFILE_LOCK:
        if not force and name in _PROFILE_CACHE:
            return _PROFILE_CACHE[name]
        link = measure_link_bandwidth()
        flops = measure_gemm_flops()
        disp = measure_dispatch_overhead()
        prof = HardwareProfile(name=name, link_bandwidth=link,
                               gpu_flops=flops, hbm_bandwidth=link * 4,
                               gemm_efficiency=1.0,
                               dispatch_overhead=disp)
        _PROFILE_CACHE[name] = prof
        scheds = (list(_LIVE_SCHEDULERS.get(name, ())) if force else [])
    for s in scheds:
        s.invalidate(hw=prof)
    return prof
