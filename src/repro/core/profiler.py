"""Profiler module (paper §3.1): measures link bandwidth and matmul
throughput for the workload's shapes, producing a HardwareProfile.

On the CPU-only validation runtime we measure real host memcpy bandwidth
(numpy copy through a preallocated "pinned" buffer — the same double-copy
a pageable->pinned->device path would take) and real matmul throughput at
the recompute GEMM shapes. On TPU this module would time device_put into
HBM and a jit'd GEMM; the interfaces are identical.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import HardwareProfile


def measure_link_bandwidth(nbytes: int = 1 << 26, iters: int = 3) -> float:
    """Host->device transfer bytes/s. On CPU backend this is memcpy-bound,
    which is exactly the role PCIe plays on the paper's system."""
    src = np.ones(nbytes // 4, np.float32)
    # warmup
    jax.device_put(src).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.device_put(src).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return nbytes / dt


def measure_gemm_flops(m: int = 2048, k: int = 2048, n: int = 2048,
                       iters: int = 3, dtype=jnp.float32) -> float:
    """Matmul FLOP/s at recompute-like shapes."""
    a = jnp.ones((m, k), dtype)
    b = jnp.ones((k, n), dtype)
    f = jax.jit(lambda a, b: a @ b)
    f(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(a, b).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return 2 * m * k * n / dt


_PROFILE_CACHE: dict = {}


def profile_system(name: str = "measured",
                   force: bool = False) -> HardwareProfile:
    """Measure (once) and return the system profile.

    The measurement is memoized per `name`: the profiler runs once per
    process and every scheduler/engine constructed afterwards reuses the
    same profile — which also makes their plan-cache keys identical.
    Pass force=True to re-measure (callers should then
    `Scheduler.invalidate(hw=...)` so stale plans are dropped).
    """
    if not force and name in _PROFILE_CACHE:
        return _PROFILE_CACHE[name]
    link = measure_link_bandwidth()
    flops = measure_gemm_flops()
    prof = HardwareProfile(name=name, link_bandwidth=link, gpu_flops=flops,
                           hbm_bandwidth=link * 4, gemm_efficiency=1.0)
    _PROFILE_CACHE[name] = prof
    return prof
