"""Pallas TPU kernel: fused KV recomputation (paper Eq. 7, the KVPR
decode hot-spot).

Computes K = X @ W_K and V = X @ W_V in ONE pass over the X tiles: each
X block is loaded from HBM into VMEM once and feeds both MXU GEMMs,
halving activation bandwidth vs two separate matmuls. Accumulation is
f32 in VMEM scratch; block sizes are MXU-aligned (128) where shapes
allow. Grid: (batch, l-blocks, n-blocks, k-blocks), k innermost
(sequential accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(x_ref, wk_ref, wv_ref, k_ref, v_ref, acc_k, acc_v, *,
            nk: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_k[...] = jnp.zeros_like(acc_k)
        acc_v[...] = jnp.zeros_like(acc_v)

    x = x_ref[0]                                   # (BL, BK)
    acc_k[...] += jnp.dot(x, wk_ref[...],
                          preferred_element_type=jnp.float32)
    acc_v[...] += jnp.dot(x, wv_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == nk - 1)
    def _flush():
        k_ref[0] = acc_k[...].astype(k_ref.dtype)
        v_ref[0] = acc_v[...].astype(v_ref.dtype)


def _block(dim: int, pref: int) -> int:
    if dim % pref == 0:
        return pref
    # largest divisor of dim that is <= pref (shapes in tests are small)
    for c in range(min(pref, dim), 0, -1):
        if dim % c == 0:
            return c
    return dim


@functools.partial(jax.jit, static_argnames=("interpret", "bl", "bn", "bk"))
def kv_recompute_pallas(x: Array, wk: Array, wv: Array,
                        interpret: bool = False,
                        bl: int = 128, bn: int = 128, bk: int = 512):
    """x: (b, l, h); wk/wv: (h, N) with N = kv_heads * head_dim.
    Returns (k, v): (b, l, N) in x.dtype."""
    b, l, h = x.shape
    n = wk.shape[1]
    BL, BN, BK = _block(l, bl), _block(n, bn), _block(h, bk)
    nk = h // BK
    grid = (b, l // BL, n // BN, nk)

    out_shape = [jax.ShapeDtypeStruct((b, l, n), x.dtype)] * 2
    kern = functools.partial(_kernel, nk=nk)
    k, v = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BL, BK), lambda bi, i, j, kk: (bi, i, kk)),
            pl.BlockSpec((BK, BN), lambda bi, i, j, kk: (kk, j)),
            pl.BlockSpec((BK, BN), lambda bi, i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, BL, BN), lambda bi, i, j, kk: (bi, i, j)),
            pl.BlockSpec((1, BL, BN), lambda bi, i, j, kk: (bi, i, j)),
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((BL, BN), jnp.float32),
                        pltpu.VMEM((BL, BN), jnp.float32)],
        interpret=interpret,
    )(x, wk, wv)
    return k, v
