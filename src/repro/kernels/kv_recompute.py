"""Pallas TPU kernels: fused KV recomputation (paper Eq. 7, the KVPR
decode hot-spot).

``kv_recompute_pallas`` computes K = X @ W_K and V = X @ W_V in ONE
pass over the X tiles: each X block is loaded from HBM into VMEM once
and feeds both MXU GEMMs, halving activation bandwidth vs two separate
matmuls. Accumulation is f32 in VMEM scratch; block sizes are
MXU-aligned (128) where shapes allow. Grid: (batch, l-blocks, n-blocks,
k-blocks), k innermost (sequential accumulation).

``recompute_attend_segment`` goes one step further: each recomputed
(chunk, KV-head) tile feeds STRAIGHT into online-softmax attention
accumulation — RoPE applied in-kernel from per-slot position offsets —
so the recomputed prefix KV never round-trips through HBM at all. It
returns the same per-segment (out, m, l) triple as
``decode_attention.flash_decode_segment``, making the fused segment
exactly combinable with streamed/new-token segments.
``kv_recompute_pallas`` stays as the standalone fallback for callers
that need the materialized K/V (e.g. prefix restore).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(x_ref, wk_ref, wv_ref, k_ref, v_ref, acc_k, acc_v, *,
            nk: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_k[...] = jnp.zeros_like(acc_k)
        acc_v[...] = jnp.zeros_like(acc_v)

    x = x_ref[0]                                   # (BL, BK)
    acc_k[...] += jnp.dot(x, wk_ref[...],
                          preferred_element_type=jnp.float32)
    acc_v[...] += jnp.dot(x, wv_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == nk - 1)
    def _flush():
        k_ref[0] = acc_k[...].astype(k_ref.dtype)
        v_ref[0] = acc_v[...].astype(v_ref.dtype)


def _block(dim: int, pref: int) -> int:
    if dim % pref == 0:
        return pref
    # largest divisor of dim that is <= pref (shapes in tests are small)
    for c in range(min(pref, dim), 0, -1):
        if dim % c == 0:
            return c
    return dim


@functools.partial(jax.jit, static_argnames=("interpret", "bl", "bn", "bk"))
def kv_recompute_pallas(x: Array, wk: Array, wv: Array,
                        interpret: bool = False,
                        bl: int = 128, bn: int = 128, bk: int = 512):
    """x: (b, l, h); wk/wv: (h, N) with N = kv_heads * head_dim.
    Returns (k, v): (b, l, N) in x.dtype."""
    b, l, h = x.shape
    n = wk.shape[1]
    BL, BN, BK = _block(l, bl), _block(n, bn), _block(h, bk)
    nk = h // BK
    grid = (b, l // BL, n // BN, nk)

    out_shape = [jax.ShapeDtypeStruct((b, l, n), x.dtype)] * 2
    kern = functools.partial(_kernel, nk=nk)
    k, v = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BL, BK), lambda bi, i, j, kk: (bi, i, kk)),
            pl.BlockSpec((BK, BN), lambda bi, i, j, kk: (kk, j)),
            pl.BlockSpec((BK, BN), lambda bi, i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, BL, BN), lambda bi, i, j, kk: (bi, i, j)),
            pl.BlockSpec((1, BL, BN), lambda bi, i, j, kk: (bi, i, j)),
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((BL, BN), jnp.float32),
                        pltpu.VMEM((BL, BN), jnp.float32)],
        interpret=interpret,
    )(x, wk, wv)
    return k, v


# ------------------------------------------------ fused recompute+attend

NEG_INF = -1e30


def _fused_kernel(valid_ref, off_ref, q_ref, x_ref, wk_ref, wv_ref,
                  freqs_ref, out_ref, m_ref, l_ref, acc, m_s, l_s, *,
                  nchunks: int, chunk: int, rope: bool):
    bi = pl.program_id(0)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0]                                # (g, dh)
    x = x_ref[0]                                   # (C, h)
    dh = q.shape[-1]
    # paper Eq. 7, one X load for both GEMMs — the recomputed tile
    # lives only in VMEM from here on
    k = jnp.dot(x, wk_ref[:, 0], preferred_element_type=jnp.float32)
    v = jnp.dot(x, wv_ref[:, 0], preferred_element_type=jnp.float32)

    # positions within the segment (the mask index) and their absolute
    # RoPE positions (segment offset is per slot)
    idx = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    if rope:
        ang = (off_ref[bi] + idx).astype(jnp.float32) * freqs_ref[...]
        sin, cos = jnp.sin(ang), jnp.cos(ang)      # (C, dh/2)
        k1, k2 = k[:, :dh // 2], k[:, dh // 2:]
        k = jnp.concatenate([k1 * cos - k2 * sin,
                             k2 * cos + k1 * sin], axis=-1)

    valid = valid_ref[bi]
    s = jnp.dot(q.astype(jnp.float32), k.T,
                preferred_element_type=jnp.float32)      # (g, C)
    s = s / jnp.sqrt(jnp.float32(dh))
    s = jnp.where(idx.reshape(1, chunk) < valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jnp.dot(
        e, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ci == nchunks - 1)
    def _flush():
        out_ref[0, 0] = (acc[...] /
                         jnp.maximum(l_s[...], 1e-30)).astype(out_ref.dtype)
        m_ref[0, 0] = m_s[...]
        l_ref[0, 0] = l_s[...]


@functools.partial(jax.jit, static_argnames=("theta", "rope",
                                             "interpret", "chunk"))
def recompute_attend_segment(q: Array, x: Array, wk: Array, wv: Array,
                             valid_len: Array, pos_offset=0,
                             theta: float = 10000.0, rope: bool = True,
                             interpret: bool = False, chunk: int = 128):
    """Fused KVPR recompute+attend over the recomputed-prefix segment.

    q: (b, KV, g, dh) roped queries; x: (b, Lp, h) attention-input
    activations for segment positions [0, Lp); wk/wv: (h, KV, dh);
    valid_len: () or (b,) — rows >= a slot's length are masked;
    pos_offset: () or (b,) absolute position of segment row 0 (RoPE).

    Returns (out, m, l) with the flash_decode_segment contract; the
    recomputed K/V tiles never leave VMEM.
    """
    b, KV, g, dh = q.shape
    Lp, h = x.shape[1], x.shape[2]
    C = _block(Lp, chunk)
    nchunks = Lp // C
    from repro.kernels.decode_attention import valid_vec
    valid = valid_vec(valid_len, b)
    off = valid_vec(pos_offset, b)
    # matches models.layers.rope_freqs (half-split convention)
    freqs = (1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32)
                              / dh))).reshape(1, dh // 2)

    kern = functools.partial(_fused_kernel, nchunks=nchunks, chunk=C,
                             rope=rope)
    out, m, l = pl.pallas_call(
        kern,
        grid=(b, KV, nchunks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, C, h), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((h, 1, dh), lambda bi, hi, ci: (0, hi, 0)),
            pl.BlockSpec((h, 1, dh), lambda bi, hi, ci: (0, hi, 0)),
            pl.BlockSpec((1, dh // 2), lambda bi, hi, ci: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KV, g, dh), q.dtype),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid, off, q, x, wk, wv, freqs)
    return out, m, l
