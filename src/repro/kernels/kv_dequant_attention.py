"""Pallas TPU kernel: flash-decode attention over an int4-quantized KV
segment, dequantizing inside the kernel (beyond-paper extension; the
paper's §4.4 applies 4-bit compression before the PCIe transfer but
dequantizes as a separate pass).

Fusing dequant into the attention kernel means the packed KV (¼ the
bf16 bytes) is what crosses HBM->VMEM; the f32 dequantized values live
only in VMEM/VREGs. For host-offload decode this compounds with KVPR:
the streamed segment is quantized on the host (core/kvquant), while the
KVPR-recomputed prefix stays exact bf16 — recompute quality is free.

Quantization layout (see core/kvquant.py):
  packed  (..., S, dh//2) uint8 — two 4-bit codes per byte, code i at
          byte i//2 (low nibble = even i, high nibble = odd i)
  scale   (..., S, dh//G) f32 — per contiguous group of G along dh
  zero    (..., S, dh//G) f32 — dequant: x = code * scale + zero

Grid and online-softmax state mirror decode_attention.flash_decode_segment
so segments of mixed precision combine exactly via combine_segments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import valid_vec

Array = jax.Array
NEG_INF = -1e30


def _dequant_block(packed, scale, zero, dh: int, group: int):
    """packed (C, dh//2) uint8, scale/zero (C, dh//G) -> (C, dh) f32."""
    C = packed.shape[0]
    low = (packed & 0xF).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)
    # interleave low/high -> (C, dh): codes[2j] = low[j], codes[2j+1] = high[j]
    codes = jnp.stack([low, high], axis=-1).reshape(C, dh)
    s = jnp.repeat(scale, group, axis=-1)
    z = jnp.repeat(zero, group, axis=-1)
    return codes * s + z


def _kernel(valid_ref, q_ref, kp_ref, ks_ref, kz_ref, vp_ref, vs_ref,
            vz_ref, out_ref, m_ref, l_ref,
            acc, m_s, l_s, *, nchunks: int, chunk: int, dh: int,
            group: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0]                                   # (g, dh)
    k = _dequant_block(kp_ref[0, 0], ks_ref[0, 0], kz_ref[0, 0],
                       dh, group)                     # (C, dh) f32
    v = _dequant_block(vp_ref[0, 0], vs_ref[0, 0], vz_ref[0, 0],
                       dh, group)
    valid = valid_ref[pl.program_id(0)]            # this slot's length

    s = jnp.dot(q.astype(jnp.float32), k.T,
                preferred_element_type=jnp.float32)   # (g, C)
    s = s / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    posn = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(posn < valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)
    l_new = l_s[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jnp.dot(
        e, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new
    l_s[...] = l_new

    @pl.when(ci == nchunks - 1)
    def _flush():
        out_ref[0, 0] = (acc[...] /
                         jnp.maximum(l_s[...], 1e-30)).astype(out_ref.dtype)
        m_ref[0, 0] = m_s[...]
        l_ref[0, 0] = l_s[...]


def _chunk_of(s: int, pref: int) -> int:
    if s % pref == 0:
        return pref
    for c in range(min(pref, s), 0, -1):
        if s % c == 0:
            return c
    return s


@functools.partial(jax.jit,
                   static_argnames=("group", "interpret", "chunk"))
def flash_decode_segment_int4(q: Array,
                              k_packed: Array, k_scale: Array,
                              k_zero: Array,
                              v_packed: Array, v_scale: Array,
                              v_zero: Array,
                              valid_len: Array, group: int = 32,
                              interpret: bool = False, chunk: int = 512):
    """q: (b, KV, g, dh); *_packed: (b, KV, S, dh//2) uint8;
    *_scale/zero: (b, KV, S, dh//group) f32; valid_len: () or (b,)
    int32 (per-slot ragged lengths are masked in-kernel).

    Returns (out, m, l) — same contract as flash_decode_segment, so
    exact cross-segment combine works across precisions.
    """
    b, KV, g, dh = q.shape
    S = k_packed.shape[2]
    ng = dh // group
    C = _chunk_of(S, chunk)
    nchunks = S // C
    valid = valid_vec(valid_len, b)

    kern = functools.partial(_kernel, nchunks=nchunks, chunk=C, dh=dh,
                             group=group)
    kv_spec = pl.BlockSpec((1, 1, C, dh // 2),
                           lambda bi, hi, ci: (bi, hi, ci, 0))
    sc_spec = pl.BlockSpec((1, 1, C, ng),
                           lambda bi, hi, ci: (bi, hi, ci, 0))
    out, m, l = pl.pallas_call(
        kern,
        grid=(b, KV, nchunks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            kv_spec, sc_spec, sc_spec,
            kv_spec, sc_spec, sc_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KV, g, dh), q.dtype),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid, q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero)
    return out, m, l
