"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def kv_recompute_ref(x: Array, wk: Array, wv: Array):
    """x: (b, l, h); wk/wv: (h, N) -> (k, v): (b, l, N)."""
    k = jnp.einsum("blh,hn->bln", x.astype(jnp.float32),
                   wk.astype(jnp.float32))
    v = jnp.einsum("blh,hn->bln", x.astype(jnp.float32),
                   wv.astype(jnp.float32))
    return k.astype(x.dtype), v.astype(x.dtype)


def flash_decode_segment_ref(q: Array, k: Array, v: Array, valid_len):
    """q: (b,KV,g,dh); k/v: (b,KV,S,dh); valid_len: () or (b,).
    Returns (out, m, l) matching
    kernels.decode_attention.flash_decode_segment."""
    b, S = k.shape[0], k.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    valid = jnp.broadcast_to(jnp.asarray(valid_len), (b,))
    mask = jnp.arange(S)[None, :] < valid[:, None]          # (b, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksd->bkgd", e, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype), m, l


def merged_attention_ref(q: Array, segments):
    """Exact attention over concatenated segments [(k, v, valid|None)];
    ``valid`` may be () or (b,). q: (b, 1, H, dh); k/v: (b, S, KV, dh).
    Returns (b, 1, H, dh)."""
    b = q.shape[0]
    ks, vs, masks = [], [], []
    for (k, v, valid) in segments:
        S = k.shape[1]
        ks.append(k)
        vs.append(v)
        if valid is None:
            m = jnp.ones((b, S), bool)
        else:
            vv = jnp.broadcast_to(jnp.asarray(valid), (b,))
            m = jnp.arange(S)[None, :] < vv[:, None]
        masks.append(m)
    k = jnp.concatenate(ks, axis=1)
    v = jnp.concatenate(vs, axis=1)
    mask = jnp.concatenate(masks, axis=1)                   # (b, S_tot)
    _, _, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(b, KV, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(dh)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, 1, H, dh).astype(q.dtype)
