"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def kv_recompute_ref(x: Array, wk: Array, wv: Array):
    """x: (b, l, h); wk/wv: (h, N) -> (k, v): (b, l, N)."""
    k = jnp.einsum("blh,hn->bln", x.astype(jnp.float32),
                   wk.astype(jnp.float32))
    v = jnp.einsum("blh,hn->bln", x.astype(jnp.float32),
                   wv.astype(jnp.float32))
    return k.astype(x.dtype), v.astype(x.dtype)


def flash_decode_segment_ref(q: Array, k: Array, v: Array, valid_len):
    """q: (b,KV,g,dh); k/v: (b,KV,S,dh). Returns (out, m, l) matching
    kernels.decode_attention.flash_decode_segment."""
    S = k.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    mask = jnp.arange(S) < valid_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksd->bkgd", e, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype), m, l


def merged_attention_ref(q: Array, segments):
    """Exact attention over concatenated segments [(k, v, valid|None)].
    q: (b, 1, H, dh); k/v: (b, S, KV, dh). Returns (b, 1, H, dh)."""
    ks, vs, masks = [], [], []
    for (k, v, valid) in segments:
        S = k.shape[1]
        ks.append(k)
        vs.append(v)
        m = jnp.ones((S,), bool) if valid is None else \
            (jnp.arange(S) < valid)
        masks.append(m)
    k = jnp.concatenate(ks, axis=1)
    v = jnp.concatenate(vs, axis=1)
    mask = jnp.concatenate(masks)
    b, _, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(b, KV, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(dh)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, 1, H, dh).astype(q.dtype)
