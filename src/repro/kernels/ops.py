"""Jit'd public wrappers around the Pallas kernels, with automatic
interpret-mode on CPU (the container validates kernels in interpret=True;
on TPU the same calls compile natively)."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as DA
from repro.kernels import kv_recompute as KR

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def kv_recompute(x: Array, wk: Array, wv: Array) -> Tuple[Array, Array]:
    """x: (b, l, h); wk/wv: (h, KV, dh) -> k, v: (b, l, KV, dh)."""
    b, l, h = x.shape
    KV, dh = wk.shape[1], wk.shape[2]
    k, v = KR.kv_recompute_pallas(x, wk.reshape(h, KV * dh),
                                  wv.reshape(h, KV * dh),
                                  interpret=_interpret())
    return k.reshape(b, l, KV, dh), v.reshape(b, l, KV, dh)


def two_segment_decode_attention(q: Array, segments, pos: Array) -> Array:
    """KVPR merged attention via per-segment flash-decode + exact combine.

    q: (b, 1, H, dh); segments: [(k (b,S,KV,dh), v, valid|None), ...].
    """
    b, _, H, dh = q.shape
    KV = segments[0][0].shape[2]
    g = H // KV
    qg = q.reshape(b, KV, g, dh)
    parts = []
    for (k, v, valid) in segments:
        S = k.shape[1]
        kk = jnp.moveaxis(k, 2, 1)                 # (b, KV, S, dh)
        vv = jnp.moveaxis(v, 2, 1)
        vl = jnp.asarray(S if valid is None else valid, jnp.int32)
        parts.append(DA.flash_decode_segment(qg, kk, vv, vl,
                                             interpret=_interpret()))
    out = DA.combine_segments(parts)
    return out.reshape(b, 1, H, dh)
