"""Public dispatch layer over the Pallas kernel suite.

``kernel_mode`` resolves the ``EngineConfig.kernels`` knob to an
execution mode:

  "off"        jnp oracle path (no Pallas)
  "interpret"  Pallas kernels in interpret mode (CPU validation — the
               container runs TPU kernels through the interpreter)
  "pallas"     natively compiled Pallas (TPU)

``segmented_decode_attention`` is the decode hot path's entry point: it
takes the KVPR segment list in *tagged* form — fp KV, int4-packed KV,
or raw activations to recompute — drops zero-length segments statically
(the l=0 pure-stream split and the s=0 pure-recompute split), launches
the matching kernel per segment, and merges exactly via
``combine_segments``. The int4 segment's packed (packed, scale, zero)
triple is handed to ``flash_decode_segment_int4`` untouched — the
packed bytes are what cross HBM->VMEM; nothing is materialized at fp
precision outside the kernel. The recompute segment runs the fused
recompute+attend kernel, so the recomputed prefix KV never round-trips
through HBM.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.faults import KernelLaunchError
from repro.kernels import decode_attention as DA
from repro.kernels import kv_dequant_attention as DQA
from repro.kernels import kv_recompute as KR

Array = jax.Array

#: streamed fp segments at least this many chunks long use the
#: double-buffered DMA variant (a 1-chunk segment has nothing to
#: prefetch)
DB_MIN_CHUNKS = 2


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def kernel_mode(setting="auto") -> str:
    """Resolve a ``kernels`` knob value (bool | str) to an execution
    mode: "off" | "interpret" | "pallas".

    "auto" means real Pallas on TPU and the jnp path elsewhere (CPU
    runs stay on the oracle unless a caller opts into interpret mode);
    True/"on" means Pallas on TPU, interpret mode elsewhere (tests and
    CI parity lanes opt in this way).
    """
    if setting in (False, None, "off"):
        return "off"
    on_tpu = jax.default_backend() == "tpu"
    if setting == "auto":
        return "pallas" if on_tpu else "off"
    if setting in (True, "on"):
        return "pallas" if on_tpu else "interpret"
    if setting in ("interpret", "pallas"):
        return setting
    raise ValueError(
        f"kernels must be True/False/'auto'/'on'/'off'/'interpret'/"
        f"'pallas', got {setting!r}")


def kv_recompute(x: Array, wk: Array, wv: Array) -> Tuple[Array, Array]:
    """x: (b, l, h); wk/wv: (h, KV, dh) -> k, v: (b, l, KV, dh)."""
    b, l, h = x.shape
    KV, dh = wk.shape[1], wk.shape[2]
    k, v = KR.kv_recompute_pallas(x, wk.reshape(h, KV * dh),
                                  wv.reshape(h, KV * dh),
                                  interpret=_interpret())
    return k.reshape(b, l, KV, dh), v.reshape(b, l, KV, dh)


def _seg_len(seg) -> int:
    """Static length of a tagged segment (axis 1 of its data)."""
    tag = seg[0]
    if tag == "int4":
        return seg[1][0].shape[1]
    return seg[1].shape[1]


def _seg_kv_heads(seg) -> int:
    """KV-head count of a tagged segment."""
    tag = seg[0]
    if tag == "int4":
        return seg[1][0].shape[2]
    if tag == "fp":
        return seg[1].shape[2]
    return seg[2].shape[1]          # recompute: wk (h, KV, dh)


def _slice_seg_heads(seg, sl: slice):
    """One shard's KV-head slice of a tagged segment.  Every KV-bearing
    array carries the head axis at position 2 ((b, S, KV, ...) data) or
    1 (recompute's (h, KV, dh) projections); activations and the valid
    vector are head-agnostic and pass through whole."""
    tag = seg[0]
    if tag == "fp":
        return ("fp", seg[1][:, :, sl], seg[2][:, :, sl], seg[3])
    if tag == "int4":
        return (("int4", tuple(a[:, :, sl] for a in seg[1]),
                 tuple(a[:, :, sl] for a in seg[2]))
                + tuple(seg[3:]))
    if tag == "recompute":
        return (("recompute", seg[1], seg[2][:, sl], seg[3][:, sl])
                + tuple(seg[4:]))
    raise ValueError(f"unknown segment tag {tag!r}")


def segmented_decode_attention(q: Array, segments: List[tuple], *,
                               mode: str = "interpret",
                               chunk: int = 512,
                               head_shards: int = 1) -> Array:
    """KVPR merged attention over tagged segments via per-segment
    flash-decode + exact combine.

    q: (b, 1, H, dh) roped queries. Each segment is one of
      ("fp", k (b,S,KV,dh), v, valid)
      ("int4", (kp,ks,kz), (vp,vs,vz), valid)   # (b,S,KV,*), group=
      ("recompute", x (b,Lp,h), wk (h,KV,dh), wv, valid, pos_offset,
       theta, rope)
    where ``valid`` is None (all S rows), a scalar, or a (b,) vector.
    int4 segments take a trailing ``group`` element after ``valid``.
    Zero-length segments are dropped before launching any kernel.

    ``head_shards > 1`` is the mesh decode path: KV heads partition
    into that many contiguous slices and every segment kernel launches
    once per slice over its q-head group (each shard's VMEM working set
    and MXU occupancy match a 1/k-width device).  Flash decode reduces
    strictly within a KV head — no cross-head arithmetic anywhere in
    the per-segment kernels or the combine — so concatenating the
    per-shard outputs on the head axis is bit-identical to the single
    full-width launch.
    """
    if mode == "off":
        raise ValueError("segmented_decode_attention requires a kernel "
                         "mode; use core.recompute.merged_decode_"
                         "attention for the jnp path")
    if head_shards > 1:
        kv = max((_seg_kv_heads(s) for s in segments
                  if _seg_len(s) > 0), default=0)
        if kv % head_shards:
            raise ValueError(f"{head_shards} head shards do not divide "
                             f"{kv} KV heads")
        per = kv // head_shards
        gq = q.shape[2] // kv           # query heads per KV head
        outs = [segmented_decode_attention(
                    q[:, :, si * per * gq:(si + 1) * per * gq],
                    [_slice_seg_heads(s, slice(si * per,
                                               (si + 1) * per))
                     for s in segments],
                    mode=mode, chunk=chunk)
                for si in range(head_shards)]
        return jnp.concatenate(outs, axis=2)
    interpret = mode != "pallas"
    b, _, H, dh = q.shape
    segments = [s for s in segments if _seg_len(s) > 0]
    if not segments:
        raise ValueError("all segments empty")
    KV = (segments[0][1][0].shape[2] if segments[0][0] == "int4"
          else segments[0][1].shape[2] if segments[0][0] == "fp"
          else segments[0][2].shape[1])
    g = H // KV
    qg = q.reshape(b, KV, g, dh)

    parts = []
    for seg in segments:
        tag = seg[0]
        try:
            if tag == "fp":
                _, k, v, valid = seg
                S = k.shape[1]
                kk = jnp.moveaxis(k, 2, 1)         # (b, KV, S, dh)
                vv = jnp.moveaxis(v, 2, 1)
                vl = jnp.asarray(S if valid is None else valid,
                                 jnp.int32)
                fn = (DA.flash_decode_segment_db
                      if S >= DB_MIN_CHUNKS * chunk
                      else DA.flash_decode_segment)
                parts.append(fn(qg, kk, vv, vl, interpret=interpret,
                                chunk=chunk))
            elif tag == "int4":
                _, kq3, vq3, valid = seg[:4]
                group = seg[4] if len(seg) > 4 else 32
                S = kq3[0].shape[1]
                kq3 = tuple(jnp.moveaxis(a, 2, 1) for a in kq3)
                vq3 = tuple(jnp.moveaxis(a, 2, 1) for a in vq3)
                vl = jnp.asarray(S if valid is None else valid,
                                 jnp.int32)
                parts.append(DQA.flash_decode_segment_int4(
                    qg, *kq3, *vq3, vl, group=group,
                    interpret=interpret, chunk=chunk))
            elif tag == "recompute":
                _, x, wk, wv, valid, pos_offset, theta, rope = seg
                Lp = x.shape[1]
                vl = jnp.asarray(Lp if valid is None else valid,
                                 jnp.int32)
                parts.append(KR.recompute_attend_segment(
                    qg, x, wk, wv, vl, pos_offset, theta=float(theta),
                    rope=bool(rope), interpret=interpret,
                    chunk=min(chunk, 128)))
            else:
                raise ValueError(f"unknown segment tag {tag!r}")
        except (ValueError, TypeError):
            raise          # dispatch-contract bugs, not launch failures
        except Exception as e:
            # a Pallas trace/compile/launch failure surfaces here (the
            # dispatch runs at jit-trace time) — re-raise typed so the
            # runtime's degradation ladder can drop this step to the
            # jnp oracle path instead of killing the batch
            raise KernelLaunchError(
                f"{tag} segment kernel failed: "
                f"{type(e).__name__}: {e}") from e
    out = DA.combine_segments(parts)
    return out.reshape(b, 1, H, dh)


def two_segment_decode_attention(q: Array, segments, pos: Array,
                                 chunk: int = 512) -> Array:
    """KVPR merged attention over plain (k, v, valid) fp segments.

    q: (b, 1, H, dh); segments: [(k (b,S,KV,dh), v, valid|None), ...].
    Zero-length segments (the l=0 pure-stream split) are dropped before
    any kernel launches — matching merged_decode_attention's jnp path.
    """
    tagged = [("fp", k, v, valid) for (k, v, valid) in segments]
    return segmented_decode_attention(
        q, tagged, mode="interpret" if _interpret() else "pallas",
        chunk=chunk)
