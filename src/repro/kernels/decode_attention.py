"""Pallas TPU kernel: flash-decode single-token GQA attention over one KV
segment, returning partial-softmax statistics so multiple segments
(recomputed | streamed | new-token, per KVPR) — or seq-parallel shards —
can be combined exactly without materializing a merged cache.

Grid: (batch, kv_heads, kv_chunks); the chunk axis is innermost and
sequential on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch across chunk steps. Chunk positions >= the slot's valid length
are masked (the segment may be padded to a static length); ``valid_len``
may be a scalar (uniform batch) or a (b,) vector of per-slot lengths
(ragged continuous batching) — the kernel reads its slot's entry from
SMEM either way.

``flash_decode_segment_db`` is the double-buffered variant: grid
(batch, kv_heads) with K/V left in HBM/ANY memory and chunk tiles moved
by explicit async DMA into a 2-slot VMEM scratch, prefetching chunk
i+1's tiles while chunk i is in the MXU (the 3-stage copy/compute
pipeline). Same (out, m, l) contract, so the two variants interchange.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(valid_ref, q_ref, k_ref, v_ref,
            out_ref, m_ref, l_ref,
            acc, m_s, l_s, *, nchunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0]                                # (g, dh)
    k = k_ref[0, 0]                                # (C, dh)
    v = v_ref[0, 0]                                # (C, dh)
    valid = valid_ref[pl.program_id(0)]            # this slot's length

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (g, C)
    s = s / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    posn = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(posn < valid, s, NEG_INF)

    m_prev = m_s[...]                              # (g, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.exp(s - m_new)                         # (g, C)
    l_new = l_s[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jnp.dot(
        e, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_s[...] = m_new
    l_s[...] = l_new

    @pl.when(ci == nchunks - 1)
    def _flush():
        out_ref[0, 0] = (acc[...] /
                         jnp.maximum(l_s[...], 1e-30)).astype(out_ref.dtype)
        m_ref[0, 0] = m_s[...]
        l_ref[0, 0] = l_s[...]


def _chunk_of(s: int, pref: int) -> int:
    if s % pref == 0:
        return pref
    for c in range(min(pref, s), 0, -1):
        if s % c == 0:
            return c
    return s


def valid_vec(valid_len, b: int) -> Array:
    """Normalize a scalar-or-(b,) valid length to a (b,) int32 vector
    (the SMEM layout both kernel variants index per slot)."""
    v = jnp.asarray(valid_len, jnp.int32)
    return jnp.broadcast_to(v.reshape(-1) if v.ndim else v, (b,))


@functools.partial(jax.jit,
                   static_argnames=("interpret", "chunk"))
def flash_decode_segment(q: Array, k: Array, v: Array, valid_len: Array,
                         interpret: bool = False, chunk: int = 512):
    """q: (b, KV, g, dh); k/v: (b, KV, S, dh); valid_len: () or (b,)
    int32 — per-slot ragged lengths are masked in-kernel.

    Returns (out (b,KV,g,dh) — normalized within this segment,
             m (b,KV,g,1) row maxes, l (b,KV,g,1) softmax sums) so the
    caller can exactly combine several segments.
    """
    b, KV, g, dh = q.shape
    S = k.shape[2]
    C = _chunk_of(S, chunk)
    nchunks = S // C
    valid = valid_vec(valid_len, b)

    kern = functools.partial(_kernel, nchunks=nchunks, chunk=C)
    out, m, l = pl.pallas_call(
        kern,
        grid=(b, KV, nchunks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, C, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, C, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KV, g, dh), q.dtype),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid, q, k, v)
    return out, m, l


def _kernel_db(valid_ref, q_ref, k_hbm, v_hbm, out_ref, m_ref, l_ref,
               *, nchunks: int, chunk: int, g: int, dh: int):
    bi = pl.program_id(0)
    hi = pl.program_id(1)
    valid = valid_ref[bi]
    q = q_ref[0, 0]                                # (g, dh)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    def scoped(k_buf, v_buf, sem, acc, m_s, l_s):
        # k_buf/v_buf: (2, C, dh) VMEM double buffers; sem: (2, 2) DMA
        # semaphores (slot x {k, v})
        def copies(ci, slot):
            sl = pl.ds(ci * chunk, chunk)
            return (pltpu.make_async_copy(k_hbm.at[bi, hi, sl],
                                          k_buf.at[slot], sem.at[slot, 0]),
                    pltpu.make_async_copy(v_hbm.at[bi, hi, sl],
                                          v_buf.at[slot], sem.at[slot, 1]))

        for cp in copies(0, 0):
            cp.start()
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

        def body(ci, carry):
            slot = jax.lax.rem(ci, 2)

            @pl.when(ci + 1 < nchunks)
            def _prefetch():                       # overlap chunk i's MXU
                for cp in copies(ci + 1, 1 - slot):
                    cp.start()

            for cp in copies(ci, slot):
                cp.wait()
            k = k_buf[slot]                        # (C, dh)
            v = v_buf[slot]
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
            s = s * scale
            posn = ci * chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
            s = jnp.where(posn < valid, s, NEG_INF)
            m_prev = m_s[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            e = jnp.exp(s - m_new)
            l_s[...] = l_s[...] * alpha + jnp.sum(e, axis=-1,
                                                  keepdims=True)
            acc[...] = acc[...] * alpha + jnp.dot(
                e, v.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            m_s[...] = m_new
            return carry

        jax.lax.fori_loop(0, nchunks, body, 0)
        out_ref[0, 0] = (acc[...] /
                         jnp.maximum(l_s[...], 1e-30)).astype(out_ref.dtype)
        m_ref[0, 0] = m_s[...]
        l_ref[0, 0] = l_s[...]

    pl.run_scoped(
        scoped,
        pltpu.VMEM((2, chunk, dh), k_hbm.dtype),
        pltpu.VMEM((2, chunk, dh), v_hbm.dtype),
        pltpu.SemaphoreType.DMA((2, 2)),
        pltpu.VMEM((g, dh), jnp.float32),
        pltpu.VMEM((g, 1), jnp.float32),
        pltpu.VMEM((g, 1), jnp.float32),
    )


@functools.partial(jax.jit,
                   static_argnames=("interpret", "chunk"))
def flash_decode_segment_db(q: Array, k: Array, v: Array,
                            valid_len: Array, interpret: bool = False,
                            chunk: int = 512):
    """Double-buffered flash decode: same contract as
    ``flash_decode_segment``, but K/V stay in HBM (ANY memory space) and
    chunk tiles are DMA'd into a 2-slot VMEM scratch so chunk i+1's
    loads overlap chunk i's MXU work. Grid is (b, KV); the chunk loop
    runs in-kernel (fori_loop) around the manual copies."""
    b, KV, g, dh = q.shape
    S = k.shape[2]
    C = _chunk_of(S, chunk)
    nchunks = S // C
    valid = valid_vec(valid_len, b)

    kern = functools.partial(_kernel_db, nchunks=nchunks, chunk=C,
                             g=g, dh=dh)
    out, m, l = pl.pallas_call(
        kern,
        grid=(b, KV),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KV, g, dh), q.dtype),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, KV, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid, q, k, v)
    return out, m, l


def combine_segments(parts):
    """Exact softmax combine of per-segment (out, m, l) triples."""
    m_star = parts[0][1]
    for (_, m, _) in parts[1:]:
        m_star = jnp.maximum(m_star, m)
    num = 0.0
    den = 0.0
    for (out, m, l) in parts:
        w = l * jnp.exp(m - m_star)
        num = num + out.astype(jnp.float32) * w
        den = den + w
    return (num / jnp.maximum(den, 1e-30)).astype(parts[0][0].dtype)
