"""Token data pipeline: synthetic LM streams (zipf-distributed with
markovian structure so the loss actually decreases) and file-backed token
shards, packed into fixed-length training batches. Shard-aware: each data
rank reads a disjoint slice."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0


def synthetic_stream(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-bigram synthetic LM data: learnable structure (each token
    mostly determines a small successor set) so training drivers can show
    decreasing loss; zipf marginals mimic natural text frequencies."""
    rng = np.random.default_rng(cfg.seed + cfg.shard_id)
    V = cfg.vocab_size
    succ = rng.integers(0, V, size=(V, 4))          # successor table
    while True:
        toks = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        state = rng.zipf(1.5, size=cfg.batch_size).clip(max=V - 1)
        for t in range(cfg.seq_len + 1):
            toks[:, t] = state
            nxt = succ[state, rng.integers(0, 4, size=cfg.batch_size)]
            noise = rng.random(cfg.batch_size) < 0.1
            state = np.where(noise,
                             rng.zipf(1.5, size=cfg.batch_size).clip(
                                 max=V - 1),
                             nxt).astype(np.int64)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def file_stream(path: str, cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Reads a flat .npy/.bin int32 token file, packs fixed windows,
    striding by shard so ranks never overlap."""
    data = np.load(path, mmap_mode="r") if path.endswith(".npy") else \
        np.memmap(path, dtype=np.int32, mode="r")
    window = cfg.seq_len + 1
    n_windows = len(data) // window
    idx = np.arange(cfg.shard_id, n_windows, cfg.num_shards)
    rng = np.random.default_rng(cfg.seed)
    while True:
        rng.shuffle(idx)
        for start in range(0, len(idx) - cfg.batch_size + 1,
                           cfg.batch_size):
            sel = idx[start:start + cfg.batch_size]
            toks = np.stack([data[i * window:(i + 1) * window]
                             for i in sel]).astype(np.int32)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def make_stream(cfg: DataConfig,
                path: Optional[str] = None) -> Iterator[Dict[str, np.ndarray]]:
    return file_stream(path, cfg) if path else synthetic_stream(cfg)
