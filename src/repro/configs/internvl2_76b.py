"""InternVL2-76B [arXiv:2404.16821] — VLM. InternViT vision tower +
projector are STUBS: input_specs provides patch embeddings prepended to the
token stream. The 80-layer LLM backbone (Llama-3-70B-style GQA) is real."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,  # padded to 128512? 128256 % 256 == 0 -> unchanged
    max_seq_len=32768,
    rope_theta=500_000.0,
    num_patch_tokens=256,  # stub vision prefix per image
    source="[arXiv:2404.16821]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=2, d_ff=512, vocab_size=512,
                          max_seq_len=1024, num_patch_tokens=16)
