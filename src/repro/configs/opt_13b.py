"""OPT-13B [arXiv:2205.01068] — paper evaluation model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-13b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=20480,
    vocab_size=50272,
    max_seq_len=2048,
    act="gelu",
    gated_mlp=False,
    pos_embedding="learned",
    source="[arXiv:2205.01068]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=8, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
