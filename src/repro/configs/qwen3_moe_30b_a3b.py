"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE 128 experts top-8, GQA kv=4."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert hidden dim
    vocab_size=151936,
    max_seq_len=131072,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768, sharding="expert"),
    source="[hf:Qwen/Qwen3-30B-A3B]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=64,
                          vocab_size=512, max_seq_len=1024,
                          moe=MoEConfig(num_experts=4, top_k=2,
                                        d_ff_expert=64, sharding="expert",
                                        capacity_factor=8.0))
