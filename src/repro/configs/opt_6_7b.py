"""OPT-6.7B [arXiv:2205.01068] — the paper's primary evaluation model.
MHA (kv=heads), learned positions, GELU, non-gated FFN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-6.7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=16384,
    vocab_size=50272,  # padded to 50432
    max_seq_len=2048,
    act="gelu",
    gated_mlp=False,
    pos_embedding="learned",
    source="[arXiv:2205.01068]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=8, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
