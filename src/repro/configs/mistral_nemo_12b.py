"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA, 128k ctx."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,  # Nemo uses head_dim 128 (not d_model/heads=160)
    d_ff=14336,
    vocab_size=131072,
    max_seq_len=131072,
    rope_theta=1_000_000.0,
    source="[hf:mistralai/Mistral-Nemo-Base-2407]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=2, head_dim=32, d_ff=512,
                          vocab_size=512, max_seq_len=1024)
