"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder audio. Conv+mel
frontend is a STUB: input_specs provides (b, 1500, 384) frame embeddings.
4 encoder + 4 decoder layers, MHA (kv=heads=6), learned positions, GELU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    encoder_seq_len=1500,    # stub frontend output frames
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,        # padded to 51968
    # whisper's real decoder ctx is 448; raised so the assigned decode_32k
    # input shape exercises the backbone (pos table is learned -> sized up)
    max_seq_len=32768,
    act="gelu",
    gated_mlp=False,
    pos_embedding="learned",
    source="[arXiv:2212.04356]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, encoder_seq_len=64,
                          d_model=128, num_heads=4, num_kv_heads=4,
                          d_ff=256, vocab_size=512, max_seq_len=256)
