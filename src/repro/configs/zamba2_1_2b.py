"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone with a SHARED
attention(+MLP) block invoked every 6 layers (weights reused each time).
ssm_state=64. Attention is MHA-ish (kv=32=heads per pool spec)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    max_seq_len=131072,
    rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    shared_attn_every=6,   # shared block applied after mamba layers 5,11,...
    source="[arXiv:2411.15242]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=4, d_model=128, num_heads=4,
                          num_kv_heads=4, head_dim=32, d_ff=256,
                          vocab_size=512, max_seq_len=1024,
                          ssm=SSMConfig(state_dim=16, head_dim=32, expand=2,
                                        conv_width=4, chunk=32),
                          shared_attn_every=2)
