"""Architecture config system.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (full-scale, exercised only via the AOT dry-run) and
``smoke_config()`` (reduced variant run on CPU in tests).

``arch_type`` selects the block stack:
  dense   — attention + MLP every layer
  moe     — attention + mixture-of-experts MLP
  hybrid  — Mamba2 blocks + a shared attention block every k layers (zamba2)
  ssm     — xLSTM (alternating mLSTM/sLSTM blocks, no FFN)
  audio   — encoder-decoder (whisper): self+cross attention decoder,
            encoder consumes stub frame embeddings
  vlm     — decoder-only LLM backbone consuming stub patch-prefixed tokens
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

VOCAB_PAD = 256


def pad_vocab(v: int, multiple: int = VOCAB_PAD) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int          # hidden dim of each expert
    capacity_factor: float = 1.25
    # 'expert' = expert-parallel over model axis; 'tensor' = shard each
    # expert's d_ff over model axis (used when E % model_axis != 0).
    sharding: str = "expert"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int            # Mamba2 d_state / xLSTM per-head memory dim
    num_heads: int = 0        # SSD heads (0 -> derive d_model // head_dim)
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128          # SSD chunked-scan block length
    expand: int = 2           # Mamba inner expansion


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    max_seq_len: int = 131072
    head_dim: Optional[int] = None          # default d_model // num_heads
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""          # citation bracket from the assignment pool
    # --- sliding window / local-global pattern (gemma3) ---
    sliding_window: int = 0                 # 0 = full attention
    global_every: int = 0                   # e.g. 6 -> layers 5,11,... global
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0              # zamba2: shared attn block cadence
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq_len: int = 0                # stub frontend output length
    # --- vlm ---
    num_patch_tokens: int = 0               # stub vision prefix length
    # --- activation / norm flavour ---
    act: str = "silu"                       # silu (gated) | gelu (opt/whisper)
    gated_mlp: bool = True
    pos_embedding: str = "rope"             # rope | learned (opt/whisper)

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory/compute is sub-quadratic-safe at 500k:
        SSM/hybrid (O(1) state) or sliding-window locals."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token KV cache footprint across all attention layers —
        the quantity KVPR streams/recomputes."""
        n_attn = num_attention_layers(self)
        return 2 * n_attn * self.num_kv_heads * self.dh * dtype_bytes

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def num_attention_layers(cfg: ModelConfig) -> int:
    if cfg.arch_type == "ssm":
        return 0
    if cfg.arch_type == "hybrid":
        # one shared attention block applied every shared_attn_every layers
        return cfg.num_layers // max(cfg.shared_attn_every, 1)
    if cfg.arch_type == "audio":
        return cfg.num_layers  # decoder self-attn (cross handled separately)
    return cfg.num_layers


ARCH_IDS: Tuple[str, ...] = (
    "mistral-nemo-12b",
    "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m",
    "gemma3-12b",
    "tinyllama-1.1b",
    "whisper-tiny",
    "internvl2-76b",
    "zamba2-1.2b",
    "llama3.2-1b",
    "xlstm-350m",
    # the paper's own evaluation models
    "opt-6.7b",
    "opt-13b",
    "opt-30b",
    # paper appendix A.6 models
    "llama2-7b",
    "llama2-13b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.smoke_config()
