from repro.configs.base import (
    ARCH_IDS,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    get_smoke_config,
    num_attention_layers,
    pad_vocab,
)

__all__ = [
    "ARCH_IDS", "ModelConfig", "MoEConfig", "SSMConfig",
    "get_config", "get_smoke_config", "num_attention_layers", "pad_vocab",
]
