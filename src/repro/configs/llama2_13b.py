"""LLaMa2-13B [arXiv:2307.09288] — paper appendix A.6 evaluation model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=13824,
    vocab_size=32000,
    max_seq_len=4096,
    act="silu",
    gated_mlp=True,
    pos_embedding="rope",
    source="[arXiv:2307.09288]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=8, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
