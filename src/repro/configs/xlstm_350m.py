"""xLSTM-350M [arXiv:2405.04517] — alternating mLSTM (matrix memory) and
sLSTM (scalar memory) blocks; no separate FFN (d_ff=0 per pool spec; the
blocks carry their own up/down projections). O(1) decode state -> KVPR
inapplicable (no KV cache); built without the technique per spec."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    ssm=SSMConfig(state_dim=256, num_heads=4, head_dim=256, expand=2),
    source="[arXiv:2405.04517]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=2,
                          num_kv_heads=2, vocab_size=512, max_seq_len=1024,
                          ssm=SSMConfig(state_dim=32, num_heads=2,
                                        head_dim=64, expand=2, chunk=32))
