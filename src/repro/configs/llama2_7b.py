"""LLaMa2-7B [arXiv:2307.09288] — paper appendix A.6 evaluation model.
MHA (kv=heads), RoPE, SiLU gated FFN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    max_seq_len=4096,
    act="silu",
    gated_mlp=True,
    pos_embedding="rope",
    source="[arXiv:2307.09288]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=8, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
