"""OPT-30B [arXiv:2205.01068] — paper evaluation model (throughput workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-30b",
    arch_type="dense",
    num_layers=48,
    d_model=7168,
    num_heads=56,
    num_kv_heads=56,
    d_ff=28672,
    vocab_size=50272,
    max_seq_len=2048,
    act="gelu",
    gated_mlp=False,
    pos_embedding="learned",
    source="[arXiv:2205.01068]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=8, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
