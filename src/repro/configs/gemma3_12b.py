"""Gemma3-12B [hf:google/gemma-3 family] — 5:1 local:global attention,
sliding window 1024 on local layers, 128k ctx. GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    max_seq_len=131072,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,  # layers 5, 11, 17, ... are global (5:1 local:global)
    act="gelu",
    source="[hf:google/gemma-3-1b-pt]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=6, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=256,
                          vocab_size=512, max_seq_len=1024,
                          sliding_window=64, global_every=3)
