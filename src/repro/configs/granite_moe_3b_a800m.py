"""Granite-MoE-3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base family]
MoE 40 experts top-8 (pool spec), GQA kv=8. 40 % 16 != 0 so experts are
tensor-parallel (d_ff sharded) rather than expert-parallel."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,  # padded to 49408 for sharding (base.pad_vocab)
    max_seq_len=4096,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512, sharding="tensor"),
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=64,
                          vocab_size=512, max_seq_len=1024,
                          moe=MoEConfig(num_experts=3, top_k=2,
                                        d_ff_expert=64, sharding="tensor",
                                        capacity_factor=8.0))
