"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    max_seq_len=131072,
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="[hf:meta-llama/Llama-3.2-1B]",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8,
                          num_kv_heads=2, d_ff=512, vocab_size=512,
                          max_seq_len=1024)
