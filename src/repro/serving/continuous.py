"""Continuous (iteration-level) batching engine — Orca-style scheduling
on top of the Model decode path (beyond-paper extension; the paper
batches statically, §2, and its related-work cites Orca's scheduler as
the serving-side complement).

Design: B slots, each holding one request's KV cache at its own decode
position. `decode_step` is vmapped over the slot axis, so slots advance
in lockstep on the device while carrying *independent* positions — no
cross-request padding, and a finished slot is refilled from the queue at
the next step boundary (admission = b=1 prefill + cache splice into the
stacked slot pytree). Works for every arch family the Model supports,
since vmap treats the cache pytree generically.

KVPR interaction: continuous batching changes WHEN a sequence's KV is
needed, not WHERE it lives — the offload runtime's per-layer split
decision applies per step exactly as in static batching; here we run the
resident-cache path (the offload runtime covers the paper's setting).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serving.engine import Generation, Request


@dataclasses.dataclass
class _Slot:
    uid: int = -1                 # -1 = empty
    emitted: int = 0
    budget: int = 0
    tokens: Optional[list] = None


class ContinuousBatchingEngine:
    """serve(requests) with iteration-level admission into fixed slots."""

    def __init__(self, model: Model, params, num_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))
        # vmap over the slot axis: params broadcast, cache + token mapped
        self._step = jax.jit(jax.vmap(model.decode_step,
                                      in_axes=(None, 0, 0)))

    # ------------------------------------------------------------ plumbing

    def _splice(self, slots_cache, one_cache, i: int):
        """Write a b=1 cache into slot i of the stacked cache pytree."""
        def put(dst, src):
            return jax.lax.dynamic_update_slice(
                dst, src[None].astype(dst.dtype),
                (i,) + (0,) * (dst.ndim - 1))
        return jax.tree.map(put, slots_cache, one_cache)

    # --------------------------------------------------------------- serve

    def serve(self, reqs: List[Request]) -> List[Generation]:
        queue: Deque[Request] = deque(reqs)
        done: Dict[int, Generation] = {}
        slots = [_Slot() for _ in range(self.B)]

        # bootstrap: build the stacked cache from B empty prefills
        stacked = None
        tokens = np.zeros((self.B, 1), np.int32)

        def admit(i):
            nonlocal stacked
            r = queue.popleft()
            logits, cache = self._prefill(
                self.params, jnp.asarray(r.prompt)[None],
                max_len=self.max_len)
            first = int(jnp.argmax(logits[0, -1]))
            slots[i] = _Slot(uid=r.uid, emitted=1, budget=r.max_new_tokens,
                             tokens=[first])
            tokens[i, 0] = first
            if stacked is None:
                stacked = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.B,) + a.shape).copy(), cache)
            else:
                stacked = self._splice(stacked, cache, i)

        while queue or any(s.uid >= 0 for s in slots):
            for i, s in enumerate(slots):
                if s.uid < 0 and queue:
                    admit(i)
            # per-slot token shape is (1, 1): add the slot axis up front
            logits, stacked = self._step(self.params, stacked,
                                         jnp.asarray(tokens)[:, None])
            nxt = np.asarray(jnp.argmax(logits[:, 0, -1], axis=-1),
                             np.int32)
            for i, s in enumerate(slots):
                if s.uid < 0:
                    continue
                if s.emitted < s.budget:
                    s.tokens.append(int(nxt[i]))
                    s.emitted += 1
                    tokens[i, 0] = nxt[i]
                if s.emitted >= s.budget:
                    done[s.uid] = Generation(
                        s.uid, np.asarray(s.tokens[:s.budget], np.int32),
                        0.0, 0.0)
                    slots[i] = _Slot()
        return [done[r.uid] for r in reqs]
