"""Continuous (iteration-level) batching engine — Orca-style slot
scheduling over both decode backends:

  - mode="resident": B slots of HBM-resident KV caches; `decode_step`
    is vmapped over the slot axis, so slots advance in lockstep while
    carrying independent positions (the original beyond-paper path).
  - mode="offload":  the paper's host-offloaded KVPR runtime, made
    iteration-level: each HostKVStore slot holds one request's KV +
    activations at its own length, a new request is admitted mid-decode
    by prefilling (b=1) and spilling into a free slot, and the
    scheduler's ExecutionPlan picks a per-slot split for the ragged
    lengths every step.  The runtime masks inactive/padded positions
    exactly, so an admitted request's tokens are identical to serving
    it alone.

Both backends share the admission/bookkeeping loop below and the
Request/Generation plumbing from `serving.engine`; the offload backend
shares `OffloadDecodeRuntime.step` with the static engine, so there is
one decode hot path and one scheduler across the whole serving stack.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.cache import broadcast_slots, splice_slot
from repro.models.transformer import Model
from repro.serving.engine import Generation, Request


@dataclasses.dataclass
class _Slot:
    uid: int = -1                 # -1 = empty
    emitted: int = 0
    budget: int = 0
    tokens: Optional[list] = None
    t_prefill: float = 0.0
    t_admit: float = 0.0


class ContinuousBatchingEngine:
    """serve(requests) with iteration-level admission into fixed slots,
    over a resident (HBM) or offloaded (host DRAM, KVPR) KV cache."""

    def __init__(self, model: Model, params, num_slots: int = 4,
                 max_len: int = 256, mode: str = "resident",
                 hw: Optional[HardwareProfile] = None,
                 scheduler: Optional[Scheduler] = None,
                 kvpr: bool = True, schedule: str = "row",
                 align: int = 1, compress: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self.mode = mode
        self.compress = compress
        self.scheduler = scheduler or Scheduler(hw or TPU_V5E)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))
        if mode == "offload":
            self.runtime = OffloadDecodeRuntime(
                self.cfg, params, scheduler=self.scheduler,
                mode="kvpr" if kvpr else "flexgen", schedule=schedule,
                align=align, compress=compress)
        else:
            # vmap over the slot axis: params broadcast, cache + token
            # mapped
            self._step = jax.jit(jax.vmap(model.decode_step,
                                          in_axes=(None, 0, 0)))

    # --------------------------------------------------------------- serve

    def serve(self, reqs: List[Request]) -> List[Generation]:
        if self.mode == "offload":
            return self._serve_offload(reqs)
        return self._serve_resident(reqs)

    # ------------------------------------------------- shared bookkeeping

    def _advance(self, slots, tokens, nxt, done, release):
        """Append each active slot's next token; finalize exhausted
        slots (calling `release(i)` to free backend state)."""
        now = time.perf_counter()
        for i, s in enumerate(slots):
            if s.uid < 0:
                continue
            if s.emitted < s.budget:
                s.tokens.append(int(nxt[i]))
                s.emitted += 1
                tokens[i, 0] = nxt[i]
            if s.emitted >= s.budget:
                done[s.uid] = Generation(
                    s.uid, np.asarray(s.tokens[:s.budget], np.int32),
                    s.t_prefill, now - s.t_admit)
                slots[i] = _Slot()
                release(i)

    # ------------------------------------------------------------ resident

    def _serve_resident(self, reqs: List[Request]) -> List[Generation]:
        queue: Deque[Request] = deque(reqs)
        done: Dict[int, Generation] = {}
        slots = [_Slot() for _ in range(self.B)]

        # bootstrap: build the stacked cache from the first admission
        stacked = None
        tokens = np.zeros((self.B, 1), np.int32)

        def admit(i):
            nonlocal stacked
            r = queue.popleft()
            t0 = time.perf_counter()
            logits, cache = self._prefill(
                self.params, jnp.asarray(r.prompt)[None],
                max_len=self.max_len)
            first = int(jnp.argmax(logits[0, -1]))
            t1 = time.perf_counter()
            slots[i] = _Slot(uid=r.uid, emitted=1, budget=r.max_new_tokens,
                             tokens=[first], t_prefill=t1 - t0, t_admit=t1)
            tokens[i, 0] = first
            if stacked is None:
                stacked = broadcast_slots(cache, self.B)
            else:
                stacked = splice_slot(stacked, cache, i)

        while queue or any(s.uid >= 0 for s in slots):
            for i, s in enumerate(slots):
                if s.uid < 0 and queue:
                    admit(i)
            # per-slot token shape is (1, 1): add the slot axis up front
            logits, stacked = self._step(self.params, stacked,
                                         jnp.asarray(tokens)[:, None])
            nxt = np.asarray(jnp.argmax(logits[:, 0, -1], axis=-1),
                             np.int32)
            self._advance(slots, tokens, nxt, done, lambda i: None)
        return [done[r.uid] for r in reqs]

    # ------------------------------------------------------------- offload

    def _serve_offload(self, reqs: List[Request]) -> List[Generation]:
        """Iteration-level batching over the KVPR offload runtime: one
        HostKVStore slot per request in flight, per-slot splits from the
        scheduler's plan, admission between steps."""
        queue: Deque[Request] = deque(reqs)
        done: Dict[int, Generation] = {}
        slots = [_Slot() for _ in range(self.B)]
        store = HostKVStore(self.cfg, self.B, self.max_len,
                            compress=self.compress)
        plan = self.runtime.plan_for(self.B)
        tokens = np.zeros((self.B, 1), np.int32)
        active = np.zeros(self.B, bool)

        def admit(i):
            r = queue.popleft()
            t0 = time.perf_counter()
            logits, ks, vs, hs = prefill_with_activations(
                self.model, self.params, jnp.asarray(r.prompt)[None])
            store.fill_slot(i, np.asarray(ks), np.asarray(vs),
                            np.asarray(hs), len(r.prompt))
            first = int(jnp.argmax(logits[0, -1]))
            t1 = time.perf_counter()
            slots[i] = _Slot(uid=r.uid, emitted=1, budget=r.max_new_tokens,
                             tokens=[first], t_prefill=t1 - t0, t_admit=t1)
            tokens[i, 0] = first
            active[i] = True

        def release(i):
            active[i] = False
            store.clear_slot(i)

        while queue or active.any():
            for i, s in enumerate(slots):
                if s.uid < 0 and queue:
                    admit(i)
            # the plan owns the pad geometry: step_geometry buckets the
            # jitted layer's static shapes, so the trace cache stays at
            # O(#buckets) instead of recompiling as sequences grow
            logits, _ = self.runtime.step(
                store, jnp.asarray(tokens), plan, active=active.copy())
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                             np.int32)
            self._advance(slots, tokens, nxt, done, release)
        # drain the final step's write-back fences: surfaces any store
        # error and leaves the pool idle before the store is dropped
        store.sync()
        return [done[r.uid] for r in reqs]
