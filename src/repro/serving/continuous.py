"""Legacy continuous (iteration-level) batching engine — a thin shim
over the request-level API in ``serving.api``.

``ContinuousBatchingEngine(model, params, mode="resident"|"offload")``
maps onto ``LLMEngine`` with ``EngineConfig(batching="continuous")``:
Orca-style slot admission over either the vmapped resident cache or the
paper's host-offloaded KVPR runtime, now with the full request
lifecycle (per-request ``SamplingParams``, early EOS freeing the slot
mid-decode).  New code should use ``LLMEngine`` directly — see
docs/api.md.
"""
from __future__ import annotations

from typing import Optional

from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving.api import EngineConfig, LLMEngine
from repro.serving.engine import EngineShim

__all__ = ["ContinuousBatchingEngine"]


class ContinuousBatchingEngine(EngineShim):
    """serve(requests) with iteration-level admission into fixed slots,
    over a resident (HBM) or offloaded (host DRAM, KVPR) KV cache.
    Thin shim over ``api.LLMEngine``."""

    def __init__(self, model: Model, params, num_slots: int = 4,
                 max_len: int = 256, mode: str = "resident",
                 hw: Optional[HardwareProfile] = None,
                 scheduler: Optional[Scheduler] = None,
                 kvpr: bool = True, schedule: str = "row",
                 align: int = 1, compress: Optional[str] = None,
                 sampler: str = "greedy", seed: int = 0,
                 kernels="auto"):
        self.mode = mode
        self.sampler = sampler
        config = EngineConfig(
            backend="offload" if mode == "offload" else "resident",
            batching="continuous", slots=num_slots, max_len=max_len,
            kvpr=kvpr, schedule=schedule, align=align,
            compress=compress, hw=hw or TPU_V5E, seed=seed,
            kernels=kernels)
        self.engine = LLMEngine(model, params, config,
                                scheduler=scheduler)
