"""RouterEngine: a multi-replica serving tier over N ``LLMEngine``
replicas with prefix-aware placement, SLO scheduling and preemption.

This is the ROADMAP's tier ABOVE the single engine (item 1, modeled on
the vLLM production-stack deployment shape): one router process fronts
N in-process replicas, each on its own worker thread with its own
engine (own KV store, own prefix cache, optionally its own
``EngineConfig``).  The pieces:

  admission    every ``submit()`` passes an ``AdmissionQueue`` —
               priority ordering, bounded depth (``RouterQueueFull``),
               deadline drops (``finish_reason="deadline"``).
  placement    ``RouterConfig.policy`` picks the replica: prefix-aware
               (warm-prefix overlap via the non-mutating
               ``PrefixCache.peek`` probe, balanced against load),
               with round_robin / least_loaded baselines for the
               trace-replay comparison.
  preemption   a high-priority arrival may preempt the lowest-priority
               running decode on its chosen replica
               (``LLMEngine.preempt`` — the existing mid-decode
               slot-release machinery).  The preempted request
               requeues as a CONTINUATION: prompt extended by the
               tokens generated so far, ``token_offset`` advanced so
               its sampling stream resumes where it stopped, and —
               with the prefix cache on — the resume restores the
               prompt through the paper's transfer-vs-recompute split
               instead of recomputing from scratch.
               ``max_preemptions`` bounds how often one request can be
               bounced (the no-starvation guarantee).
  isolation    a ``RequestFaultError`` contained by a replica finishes
               ONLY that request (``finish_reason="error"``); an
               escalated engine error fails the in-flight batch but
               the worker survives and the queue keeps draining.

Cross-replica identity: every replica derives the same sampling stream
for a uid (``fold_in(engine_key, uid)`` with a shared engine seed), so
routed outputs are token-identical to a single-engine reference no
matter which replica serves them — the property
``tests/test_identity_matrix.py`` pins.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from repro.core.prefix_cache import (PrefixCacheStats,
                                     RadixPrefixIndex)
from repro.core.scheduler import Scheduler
from repro.serving.api import (EngineConfig, LLMEngine, Request,
                               RequestOutput, SamplingParams)
from repro.serving.router.admission import (AdmissionQueue,
                                            DEFAULT_SLO_CLASSES,
                                            RouterQueueFull, SLOClass,
                                            slo_attained)
from repro.serving.router.placement import (POLICIES, PlacementView,
                                            make_policy)

__all__ = ["ReplicaStats", "RouterConfig", "RouterEngine",
           "RouterStats"]


# ------------------------------------------------------------- configs

@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Knobs of the serving tier (see docs/serving.md).

    replicas: number of in-process ``LLMEngine`` replicas (threads).
    policy: placement policy — "prefix" | "round_robin" |
        "least_loaded".
    max_batch: most requests one replica serves per engine batch; the
        rest wait in its queue (smaller = lower TTFT under load,
        larger = more batching throughput).
    max_queue: admission bound across ALL queued requests; 0 means
        unbounded.  ``submit`` raises ``RouterQueueFull`` beyond it.
    warmth_weight / load_weight: the prefix policy's score weights.
    preemption: allow a strictly-higher-priority arrival to preempt
        the lowest-priority running decode on its chosen replica.
    max_preemptions: per-request bound on preempt-resume cycles —
        after this many, the request runs to completion no matter what
        arrives (the no-starvation guarantee).
    slo_classes: named TTFT/TPOT targets; ``Request.slo`` picks one
        and inherits its default priority when the request leaves
        priority at 0.
    """
    replicas: int = 2
    policy: str = "prefix"
    max_batch: int = 4
    max_queue: int = 0
    warmth_weight: float = 1.0
    load_weight: float = 0.5
    preemption: bool = True
    max_preemptions: int = 1
    # prefix policy: shortest prompt-prefix overlap worth treating as a
    # family affinity.  The router remembers WHERE it routed each new
    # prefix family; later members of the family see that replica as
    # speculatively warm even while its cache insert is still in
    # flight — without this, an arrival burst lands entirely on cold
    # caches and placement degenerates to load balancing.
    affinity_min: int = 8
    slo_classes: Mapping[str, SLOClass] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_SLO_CLASSES))

    def validate(self) -> "RouterConfig":
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got "
                             f"{self.replicas}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got "
                             f"{self.policy!r}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.max_batch}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got "
                             f"{self.max_queue}")
        if self.max_preemptions < 0:
            raise ValueError(f"max_preemptions must be >= 0, got "
                             f"{self.max_preemptions}")
        if self.affinity_min < 1:
            raise ValueError(f"affinity_min must be >= 1, got "
                             f"{self.affinity_min}")
        for slo in self.slo_classes.values():
            slo.validate()
        return self


@dataclasses.dataclass
class ReplicaStats:
    """Per-replica counters (a snapshot; see ``RouterEngine.stats``)."""
    index: int
    queued: int = 0
    running: int = 0
    dispatched: int = 0
    batches: int = 0
    preemptions: int = 0         # victims preempted ON this replica
    deadline_drops: int = 0
    deferrals: int = 0           # cold family-duplicates held one batch
    errors: int = 0
    prefix: Optional[PrefixCacheStats] = None


@dataclasses.dataclass
class RouterStats:
    """Router-level snapshot: per-replica counters plus the aggregate
    warm-prefix picture placement is optimizing."""
    replicas: List[ReplicaStats]
    submitted: int = 0
    finished: int = 0
    preemptions: int = 0
    deadline_drops: int = 0
    rejected: int = 0

    @property
    def warm_hit_rate(self) -> float:
        hits = lookups = 0
        for r in self.replicas:
            if r.prefix is not None:
                hits += r.prefix.hits
                lookups += r.prefix.lookups
        return hits / max(lookups, 1)

    @property
    def warm_tokens(self) -> int:
        return sum(r.prefix.tokens_matched for r in self.replicas
                   if r.prefix is not None)


# ----------------------------------------------------------- internals

@dataclasses.dataclass
class _Tracked:
    """One request's router-side lifecycle record, living from submit
    to finalize across any number of preempt-resume segments."""
    req: Request                     # the ORIGINAL request
    sp: SamplingParams
    seq: int                         # arrival order (priority tiebreak)
    t_enqueue: float
    prompt: np.ndarray               # current (possibly extended)
    token_offset: int = 0
    segments: List[np.ndarray] = dataclasses.field(default_factory=list)
    first: Optional[RequestOutput] = None    # first segment (ttft)
    preemptions: int = 0
    preempt_pending: bool = False    # flagged, not yet observed
    replica: Optional[int] = None
    out: Optional[RequestOutput] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    @property
    def priority(self) -> int:
        return self.req.priority

    @property
    def deadline_s(self) -> Optional[float]:
        return self.req.deadline_s

    @property
    def budget_left(self) -> int:
        return self.sp.max_tokens - sum(len(s) for s in self.segments)


@dataclasses.dataclass
class _Affinity:
    """Router-side placement record: the replica a prefix family was
    routed to (indexed by the family head's prompt tokens in a
    ``RadixPrefixIndex``)."""
    replica: int


def _common_prefix(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = a[:n] != b[:n]
    return int(np.argmax(neq)) if neq.any() else n


class _Replica:
    """One serving replica: its engine, its queue, its worker thread."""

    def __init__(self, index: int, engine: LLMEngine,
                 cond: threading.Condition):
        self.index = index
        self.engine = engine
        self.queue = AdmissionQueue()
        self.running: Dict[int, _Tracked] = {}
        self.cond = cond             # shares the router lock
        self.stats = ReplicaStats(index)
        self.thread: Optional[threading.Thread] = None

    def view(self, pending: int = 0) -> PlacementView:
        pc = self.engine.prefix_cache
        return PlacementView(self.index, len(self.queue),
                             len(self.running),
                             peek=pc.peek if pc is not None else None,
                             pending=pending)


# -------------------------------------------------------------- router

class RouterEngine:
    """The multi-replica serving front door.

    Construction mirrors ``LLMEngine.from_config`` one level up::

        router = RouterEngine(model, params,
                              EngineConfig(prefix_cache=...),
                              RouterConfig(replicas=2, policy="prefix"))
        outs = router.generate(requests, SamplingParams(max_tokens=16))

    ``engine_config`` may be a single config (replicated — replicas
    then share the engine seed, which is what makes routed outputs
    token-identical to a single-engine reference) or one config per
    replica.  ``generate`` is the batch convenience; ``submit`` /
    ``wait`` is the online interface the benchmark drives.
    """

    def __init__(self, model, params,
                 engine_config: Union[EngineConfig,
                                      Sequence[EngineConfig], None]
                 = None,
                 config: Optional[RouterConfig] = None,
                 scheduler: Optional[Scheduler] = None):
        self.config = (config or RouterConfig()).validate()
        n = self.config.replicas
        if engine_config is None:
            engine_config = EngineConfig()
        if isinstance(engine_config, EngineConfig):
            engine_configs = [engine_config] * n
        else:
            engine_configs = list(engine_config)
            if len(engine_configs) != n:
                raise ValueError(
                    f"got {len(engine_configs)} engine configs for "
                    f"{n} replicas")
        self._lock = threading.Lock()
        self._policy = make_policy(self.config.policy,
                                   self.config.warmth_weight,
                                   self.config.load_weight)
        self.replicas: List[_Replica] = []
        for i, ec in enumerate(engine_configs):
            eng = LLMEngine.from_config(model, params, ec,
                                        scheduler=scheduler)
            self.replicas.append(
                _Replica(i, eng, threading.Condition(self._lock)))
        self._track: Dict[int, _Tracked] = {}
        self._affinity = RadixPrefixIndex()
        self._seq = 0
        self._auto_uid = 0
        self._submitted = 0
        self._finished = 0
        self._preemptions = 0
        self._deadline_drops = 0
        self._rejected = 0
        self._closed = False
        for rep in self.replicas:
            rep.thread = threading.Thread(
                target=self._worker, args=(rep,),
                name=f"router-replica-{rep.index}", daemon=True)
            rep.thread.start()

    # ------------------------------------------------------- lifecycle

    def close(self) -> None:
        """Drain nothing — stop the workers after their current batch,
        fail still-queued requests, close every replica engine.
        Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for rep in self.replicas:
                rep.cond.notify_all()
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout=60.0)
        now = time.perf_counter()
        with self._lock:
            for tr in self._track.values():
                if not tr.done.is_set():
                    self._finalize_locked(tr, RequestOutput(
                        tr.req.uid, np.zeros((0,), np.int32),
                        finish_reason="error",
                        error="RouterClosed: router closed before the "
                              "request was served",
                        t_enqueue=tr.t_enqueue, t_finish=now,
                        slo=tr.req.slo))
        for rep in self.replicas:
            rep.engine.close()

    def __enter__(self) -> "RouterEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------ submission

    def submit(self, request: Union[Request, np.ndarray, Sequence[int]],
               sampling: Optional[SamplingParams] = None) -> int:
        """Admit one request; returns its uid.  Raises
        ``RouterQueueFull`` when admission control rejects it (the
        bounded queue is at capacity)."""
        if not isinstance(request, Request):
            request = Request(uid=self._next_uid(),
                              prompt=np.asarray(request, np.int32))
        sp = sampling or request.params or SamplingParams(
            max_tokens=request.max_new_tokens)
        sp = sp.validate()
        req = request
        if req.slo is not None and req.slo not in self.config.slo_classes:
            raise ValueError(
                f"unknown SLO class {req.slo!r}; configured: "
                f"{sorted(self.config.slo_classes)}")
        if req.slo is not None and req.priority == 0:
            req = dataclasses.replace(
                req, priority=self.config.slo_classes[req.slo].priority)
        with self._lock:
            if self._closed:
                raise RuntimeError("router is closed")
            if req.uid in self._track:
                raise ValueError(f"uid {req.uid} is already in flight")
            if self.config.max_queue:
                depth = sum(len(r.queue) for r in self.replicas)
                if depth >= self.config.max_queue:
                    self._rejected += 1
                    raise RouterQueueFull(
                        f"router queue at max_queue="
                        f"{self.config.max_queue}")
            now = time.perf_counter()
            if req.t_enqueue is None:
                req = dataclasses.replace(req, t_enqueue=now)
            tr = _Tracked(req, sp, self._seq, req.t_enqueue,
                          np.asarray(req.prompt, np.int32))
            self._seq += 1
            self._submitted += 1
            self._track[req.uid] = tr
            self._assign_locked(tr)
        return req.uid

    def _next_uid(self) -> int:
        with self._lock:
            self._auto_uid += 1
            return 1_000_000 + self._auto_uid

    def _assign_locked(self, tr: _Tracked) -> None:
        """Place ``tr`` on a replica queue (policy decision) and, under
        load, preempt a strictly-lower-priority running decode there.

        For the prefix policy, the router's affinity index supplies
        SPECULATIVE warmth: a family member routed earlier but not yet
        finished hasn't inserted into its replica's cache, so the
        cache probe alone would scatter a whole arrival burst across
        cold replicas — the affinity record keeps the family together
        until the real warmth takes over."""
        m, aff = 0, None
        if self.config.policy == "prefix":
            toks = [int(t) for t in tr.prompt]
            m, aff = self._affinity.match(toks)
            if m < self.config.affinity_min:
                m, aff = 0, None
        views = [rep.view(pending=(m if aff is not None
                                   and aff.replica == rep.index
                                   else 0))
                 for rep in self.replicas]
        idx = self._policy(views, tr.prompt)
        if self.config.policy == "prefix" and aff is None:
            # a new prefix family: remember where it went
            self._affinity.insert(tuple(int(t) for t in tr.prompt),
                                  _Affinity(idx))
        rep = self.replicas[idx]
        tr.replica = idx
        rep.queue.push(tr)
        rep.cond.notify_all()
        if self.config.preemption and rep.running:
            self._maybe_preempt_locked(rep, tr)

    def _maybe_preempt_locked(self, rep: _Replica,
                              tr: _Tracked) -> None:
        """Preempt the lowest-priority running request on ``rep`` when
        the arrival strictly outranks it — "long low-priority decodes
        yield to interactive traffic".  Victims are preempted at most
        ``max_preemptions`` times (starvation bound) and at most once
        per flight (``preempt_pending``)."""
        victims = [v for v in rep.running.values()
                   if not v.preempt_pending
                   and v.priority < tr.priority
                   and v.preemptions < self.config.max_preemptions]
        if not victims:
            return
        # lowest priority first; among equals, the longest remaining
        # decode (most budget left) frees its slot for the longest
        victim = min(victims,
                     key=lambda v: (v.priority, -v.budget_left, v.seq))
        victim.preempt_pending = True
        rep.stats.preemptions += 1
        self._preemptions += 1
        rep.engine.preempt(victim.req.uid)

    # ---------------------------------------------------------- worker

    def _worker(self, rep: _Replica) -> None:
        while True:
            with self._lock:
                while not self._closed and len(rep.queue) == 0:
                    rep.cond.wait(timeout=0.1)
                if self._closed:
                    return
                ready, expired = rep.queue.pop_ready(
                    time.perf_counter(), limit=self.config.max_batch)
                now = time.perf_counter()
                for tr in expired:
                    rep.stats.deadline_drops += 1
                    self._deadline_drops += 1
                    self._finalize_locked(tr, RequestOutput(
                        tr.req.uid, np.zeros((0,), np.int32),
                        finish_reason="deadline",
                        error=f"deadline_s={tr.req.deadline_s} "
                              f"exceeded in queue",
                        t_enqueue=tr.t_enqueue, t_finish=now,
                        queue_wait=now - tr.t_enqueue,
                        slo=tr.req.slo, replica=rep.index))
                if not ready:
                    continue
                ready, deferred = self._compose_batch(rep, ready)
                for tr in deferred:
                    rep.stats.deferrals += 1
                    rep.queue.push(tr)
                for tr in ready:
                    rep.running[tr.req.uid] = tr
                rep.stats.dispatched += len(ready)
                rep.stats.batches += 1
            self._serve_batch(rep, ready)

    def _compose_batch(self, rep: _Replica, ready: List[_Tracked]
                       ) -> Tuple[List[_Tracked], List[_Tracked]]:
        """Cache-aware batch composition: admit at most ONE cold member
        of each prefix family per batch; defer the rest one batch.

        Inserts happen at finish, so two cold members of the same
        family in one batch BOTH prefill from scratch — the second
        gains nothing from the first.  Held back one batch, the second
        finds the family head's KV in the cache and restores it via
        the transfer-vs-recompute split instead.  ``pop_ready``
        returns in priority order, so the admitted head is the
        highest-priority member of its family; deferral never lets a
        lower-priority family member jump an admitted higher one.
        Inert for single-request batches and cache-less replicas."""
        pc = rep.engine.prefix_cache
        if pc is None or len(ready) <= 1:
            return ready, []
        amin = self.config.affinity_min
        take, defer, cold_heads = [], [], []
        for tr in ready:
            matched, _ = pc.peek(tr.prompt)
            if matched >= amin:
                take.append(tr)
                continue
            if any(_common_prefix(tr.prompt, h) >= amin
                   for h in cold_heads):
                defer.append(tr)
            else:
                cold_heads.append(tr.prompt)
                take.append(tr)
        return take, defer

    def _serve_batch(self, rep: _Replica,
                     batch: List[_Tracked]) -> None:
        """Run one engine batch outside the router lock; reconcile the
        outcome (finish / resume-after-preemption / contained error)
        back under it."""
        reqs, sps = [], []
        for tr in batch:
            reqs.append(dataclasses.replace(
                tr.req, prompt=tr.prompt, t_enqueue=tr.t_enqueue,
                token_offset=tr.token_offset))
            sps.append(dataclasses.replace(
                tr.sp, max_tokens=max(tr.budget_left, 1)))
        outs: Optional[List[RequestOutput]] = None
        err: Optional[BaseException] = None
        try:
            outs = rep.engine.generate(reqs, sps)
        except Exception as e:            # noqa: BLE001 — isolation:
            # an ESCALATED engine error (beyond per-request
            # containment) fails this batch but must not kill the
            # worker or stall the queue behind it
            err = e
        now = time.perf_counter()
        with self._lock:
            for tr in batch:
                rep.running.pop(tr.req.uid, None)
            if err is not None:
                rep.stats.errors += len(batch)
                for tr in batch:
                    self._finalize_locked(tr, RequestOutput(
                        tr.req.uid, np.zeros((0,), np.int32),
                        finish_reason="error",
                        error=f"{type(err).__name__}: {err}",
                        t_enqueue=tr.t_enqueue, t_finish=now,
                        slo=tr.req.slo, replica=rep.index))
                return
            for tr, out in zip(batch, outs):
                if out.finish_reason == "preempted":
                    self._resume_locked(tr, out)
                else:
                    if out.finish_reason == "error":
                        rep.stats.errors += 1
                    self._finalize_locked(tr, out, replica=rep.index)

    def _resume_locked(self, tr: _Tracked, out: RequestOutput) -> None:
        """Requeue a preempted request as a continuation: prompt grown
        by the segment's tokens, sampling stream offset past them —
        the resume's admission then restores the (now cached) prompt
        via the scheduler's transfer-vs-recompute split instead of
        recomputing it from scratch."""
        seg = np.asarray(out.tokens, np.int32)
        tr.segments.append(seg)
        tr.token_offset += len(seg)
        tr.prompt = np.concatenate([tr.prompt, seg])
        tr.preemptions += 1
        tr.preempt_pending = False
        if tr.first is None:
            tr.first = out
        if tr.budget_left <= 0:
            # preempted exactly at budget: nothing left to generate
            self._finalize_locked(tr, dataclasses.replace(
                out, tokens=np.zeros((0,), np.int32),
                finish_reason="length"))
            return
        if self._closed:
            return            # close() will fail it
        self._assign_locked(tr)

    def _finalize_locked(self, tr: _Tracked, out: RequestOutput,
                         replica: Optional[int] = None) -> None:
        """Stitch the final segment onto any preempted prefix segments
        and publish the request's single RequestOutput."""
        if tr.done.is_set():
            return
        tokens = (np.concatenate(tr.segments + [np.asarray(
            out.tokens, np.int32)]) if tr.segments
            else np.asarray(out.tokens, np.int32))
        first = tr.first or out
        tr.out = dataclasses.replace(
            out, tokens=tokens,
            prefill_time=first.prefill_time,
            t_enqueue=tr.t_enqueue,
            t_first_token=first.t_first_token,
            queue_wait=first.queue_wait,
            preemptions=tr.preemptions,
            replica=replica if replica is not None else out.replica,
            slo=tr.req.slo)
        self._finished += 1
        tr.done.set()

    # --------------------------------------------------------- results

    def wait(self, uid: int, timeout: Optional[float] = None
             ) -> RequestOutput:
        with self._lock:
            tr = self._track.get(uid)
        if tr is None:
            raise KeyError(f"unknown uid {uid}")
        if not tr.done.wait(timeout):
            raise TimeoutError(f"request {uid} not finished within "
                               f"{timeout}s")
        with self._lock:
            self._track.pop(uid, None)
        return tr.out

    def generate(self, requests: Iterable, sampling=None
                 ) -> List[RequestOutput]:
        """Batch convenience: submit everything, wait for everything;
        outputs in request order.  ``sampling`` follows the
        ``LLMEngine.generate`` convention (one shared SamplingParams, a
        per-request list, or None for each request's own params)."""
        requests = list(requests)
        sampling_seq = isinstance(sampling, (list, tuple))
        if sampling_seq and len(sampling) != len(requests):
            raise ValueError(
                f"per-request sampling list has {len(sampling)} "
                f"entries for {len(requests)} requests")
        uids = []
        for i, r in enumerate(requests):
            sp = sampling[i] if sampling_seq else sampling
            uids.append(self.submit(r, sp))
        return [self.wait(uid) for uid in uids]

    # ----------------------------------------------------------- stats

    def stats(self) -> RouterStats:
        with self._lock:
            reps = []
            for rep in self.replicas:
                s = dataclasses.replace(rep.stats)
                s.queued = len(rep.queue)
                s.running = len(rep.running)
                s.prefix = rep.engine.prefix_stats
                reps.append(s)
            return RouterStats(reps, self._submitted, self._finished,
                               self._preemptions, self._deadline_drops,
                               self._rejected)

    def per_class(self, outs: Iterable[RequestOutput]
                  ) -> Dict[str, Dict[str, float]]:
        """Per-SLO-class attainment summary over finished outputs:
        share of requests that met their class's TTFT and TPOT targets
        (errors and deadline drops count as missed)."""
        by: Dict[str, List[RequestOutput]] = {}
        for o in outs:
            if o.slo is not None:
                by.setdefault(o.slo, []).append(o)
        summary = {}
        for name, group in sorted(by.items()):
            slo = self.config.slo_classes[name]
            ok = sum(slo_attained(o, slo) for o in group)
            served = [o for o in group if len(o.tokens)]
            summary[name] = {
                "n": len(group),
                "attained": ok / len(group),
                "ttft_target_s": slo.ttft_s,
                "tpot_target_s": slo.tpot_s,
                "mean_ttft_s": (float(np.mean([o.ttft for o in served]))
                                if served else float("nan")),
                "mean_tpot_s": (float(np.mean([o.tpot for o in served]))
                                if served else float("nan")),
            }
        return summary
