"""Admission control for the multi-replica router: SLO classes and the
priority admission queue.

The router (``serving.router.engine``) fronts N ``LLMEngine`` replicas;
every request passes through an ``AdmissionQueue`` before it reaches an
engine.  The queue gives the serving tier three properties the engines
themselves don't have:

  - **priority ordering** — a higher-``priority`` request never waits
    behind a lower-priority one in the same queue (ties break FIFO by
    arrival sequence), the invariant the scheduling property tests pin;
  - **bounded depth** — ``max_queue`` rejects work at the door
    (``RouterQueueFull``) instead of building unbounded backlog;
  - **deadline drops** — a request still queued past its
    ``deadline_s`` is dropped at pop time (``finish_reason=
    "deadline"``) rather than served uselessly late.

``SLOClass`` names a TTFT/TPOT target pair; per-class attainment is
computed from the ``RequestOutput`` timing fields by the trace-replay
benchmark (``benchmarks/bench_router_replay.py``) and the router's own
``per_class`` summary.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

__all__ = ["AdmissionQueue", "DEFAULT_SLO_CLASSES", "RouterQueueFull",
           "SLOClass", "slo_attained"]


class RouterQueueFull(RuntimeError):
    """Admission control rejected the request: the router queue is at
    ``RouterConfig.max_queue``.  Callers should shed or retry later —
    the router never buffers beyond the configured bound."""


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A named latency target pair.

    ttft_s: time-to-first-token target (includes queue wait).
    tpot_s: mean per-output-token target after the first token.
    priority: default ``Request.priority`` for requests that declare
        this class without an explicit priority.
    """
    name: str
    ttft_s: float
    tpot_s: float
    priority: int = 0

    def validate(self) -> "SLOClass":
        if self.ttft_s <= 0 or self.tpot_s <= 0:
            raise ValueError(f"SLO targets must be positive, got "
                             f"{self}")
        return self


# the three-tier default ladder: interactive chat, standard API calls,
# throughput batch jobs.  Targets are generous on purpose — they are
# defaults for a CPU smoke container; real deployments pass their own.
DEFAULT_SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", ttft_s=2.0, tpot_s=0.25,
                            priority=2),
    "standard": SLOClass("standard", ttft_s=10.0, tpot_s=1.0,
                         priority=1),
    "batch": SLOClass("batch", ttft_s=120.0, tpot_s=10.0, priority=0),
}


def slo_attained(out, slo: SLOClass) -> bool:
    """Did a finished ``RequestOutput`` meet its class targets?  Only
    requests that actually produced tokens are judged (errors /
    deadline drops count as missed by the caller)."""
    if len(out.tokens) == 0:
        return False
    if out.ttft > slo.ttft_s:
        return False
    return len(out.tokens) <= 1 or out.tpot <= slo.tpot_s


class AdmissionQueue:
    """Priority queue over tracked requests: pop order is
    (-priority, arrival seq) — strictly higher priority first, FIFO
    within a priority.  NOT thread-safe: the router serializes access
    under its own lock.

    Entries must expose ``priority``, ``seq``, ``t_enqueue`` and
    ``deadline_s`` attributes (the router's ``_Tracked`` records do).
    """

    def __init__(self, max_queue: int = 0):
        self.max_queue = max_queue
        self._heap: List[Tuple[int, int, object]] = []

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, entry) -> None:
        if self.max_queue and len(self._heap) >= self.max_queue:
            raise RouterQueueFull(
                f"admission queue at max_queue={self.max_queue}")
        heapq.heappush(self._heap, (-entry.priority, entry.seq, entry))

    def pop_ready(self, now: float, limit: Optional[int] = None
                  ) -> Tuple[List[object], List[object]]:
        """Pop up to ``limit`` entries in priority order; entries whose
        queue deadline has already passed are returned separately as
        ``expired`` (they don't consume the limit — a dead request must
        never block a live one behind it)."""
        ready: List[object] = []
        expired: List[object] = []
        while self._heap and (limit is None or len(ready) < limit):
            _, _, entry = heapq.heappop(self._heap)
            dl = entry.deadline_s
            if dl is not None and now - entry.t_enqueue > dl:
                expired.append(entry)
            else:
                ready.append(entry)
        return ready, expired
