"""Replica placement policies for the router.

Placement answers one question per request: WHICH replica's queue does
it join?  Three policies are selectable via ``RouterConfig.policy`` so
the trace-replay benchmark can compare them on the same trace:

  round_robin   rotate over replicas regardless of state — the
                classic stateless baseline.
  least_loaded  pick the replica with the fewest queued + running
                requests — balances depth, blind to cache state.
  prefix        score each replica by warm-prefix overlap (via the
                non-mutating ``PrefixCache.peek`` probe — probing must
                not touch LRU recency or placement itself would
                distort eviction) balanced against its load:

                    score = warmth_weight * matched/len(prompt)
                          - load_weight   * load

                The load term is the ABSOLUTE queue depth, not a
                normalized share: a full warm hit saves about one
                prompt's prefill while every queued request ahead
                costs about one batch, so affinity should hold only
                up to a bounded load gap (~warmth_weight/load_weight
                requests) and then divert — otherwise a backlogged
                replica keeps attracting its families no matter how
                long its queue grows.

                A replica whose prefix cache already holds the
                request's system prompt / RAG prefix restores it
                through the KVPR transfer-vs-recompute split instead
                of prefilling it, so keeping a family of prompts on
                the replica that is warm for them directly reduces the
                bytes every split must move ("Understanding
                Bottlenecks…", PAPERS.md).

All policies break ties toward the lower replica index, which makes
placement deterministic for the tests.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

__all__ = ["POLICIES", "PlacementView", "make_policy"]


class PlacementView:
    """The slice of replica state a policy may read: queue depth,
    in-flight count, and the warmth probe.  ``warmth(prompt)`` returns
    the matched-prefix length WITHOUT touching the cache's LRU state
    (``PrefixCache.peek``); replicas without a prefix cache are always
    cold."""

    def __init__(self, index: int, queued: int, running: int,
                 peek: Optional[Callable] = None, pending: int = 0):
        self.index = index
        self.queued = queued
        self.running = running
        self._peek = peek
        # speculative warmth: tokens of this prompt already ROUTED to
        # this replica but not yet inserted into its cache (the
        # router's affinity index) — during an arrival burst the cache
        # is still cold when placement runs, so the in-flight family
        # member, not the cache, is the signal that keeps a family
        # together
        self.pending = pending

    @property
    def load(self) -> int:
        return self.queued + self.running

    def warmth(self, prompt) -> int:
        matched = 0
        if self._peek is not None:
            matched, _ = self._peek(prompt)
        return max(matched, self.pending)


def _round_robin() -> Callable:
    state = {"next": 0}

    def choose(views: Sequence[PlacementView], prompt) -> int:
        i = state["next"] % len(views)
        state["next"] += 1
        return views[i].index

    return choose


def _least_loaded() -> Callable:
    def choose(views: Sequence[PlacementView], prompt) -> int:
        return min(views, key=lambda v: (v.load, v.index)).index

    return choose


def _prefix(warmth_weight: float, load_weight: float) -> Callable:
    def choose(views: Sequence[PlacementView], prompt) -> int:
        n = max(len(prompt), 1)
        best, best_key = views[0].index, None
        for v in views:
            score = (warmth_weight * v.warmth(prompt) / n
                     - load_weight * v.load)
            # deterministic: higher score wins, then lower load, then
            # lower index
            key = (-score, v.load, v.index)
            if best_key is None or key < best_key:
                best, best_key = v.index, key
        return best

    return choose


POLICIES = ("prefix", "round_robin", "least_loaded")


def make_policy(name: str, warmth_weight: float = 1.0,
                load_weight: float = 0.5) -> Callable:
    """Build a fresh policy closure (round-robin keeps its own rotation
    state, so each router instance needs its own)."""
    if name == "round_robin":
        return _round_robin()
    if name == "least_loaded":
        return _least_loaded()
    if name == "prefix":
        return _prefix(warmth_weight, load_weight)
    raise ValueError(f"unknown placement policy {name!r}; expected one "
                     f"of {POLICIES}")
