"""Multi-replica serving tier (see docs/serving.md).

    from repro.serving.router import RouterConfig, RouterEngine

A ``RouterEngine`` fronts N in-process ``LLMEngine`` replicas with an
admission-control queue (priority / deadline / SLO classes),
prefix-aware placement (warm-prefix overlap via the non-mutating
``PrefixCache.peek`` probe, with round_robin / least_loaded baselines)
and preemption of low-priority decodes that resume through the prefix
cache's transfer-vs-recompute restore.
"""
from repro.serving.router.admission import (AdmissionQueue,
                                            DEFAULT_SLO_CLASSES,
                                            RouterQueueFull, SLOClass,
                                            slo_attained)
from repro.serving.router.engine import (ReplicaStats, RouterConfig,
                                         RouterEngine, RouterStats)
from repro.serving.router.placement import (POLICIES, PlacementView,
                                            make_policy)

__all__ = [
    "AdmissionQueue", "DEFAULT_SLO_CLASSES", "POLICIES",
    "PlacementView", "ReplicaStats", "RouterConfig", "RouterEngine",
    "RouterQueueFull", "RouterStats", "SLOClass", "make_policy",
    "slo_attained",
]
