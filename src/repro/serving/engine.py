"""Batched serving engine with KVPR-aware decode.

Two execution modes, both driven by the profiler → scheduler → runtime
automation loop (paper §3; `core/scheduler.py`):
  - "resident": classic HBM-resident KV cache (prefill + decode_step);
    this is the baseline serving path and the dry-run `serve_step`.
  - "offload":  host-offloaded KV via core.runtime.OffloadDecodeRuntime —
    the paper's system. The engine asks its Scheduler for an
    ExecutionPlan; the runtime merely executes it (no inline solves).

Requests are grouped into fixed-size batches (padded to the same prompt
length); the engine runs prefill once and then the decode loop,
returning per-request generations.  The configured sampler (greedy or
temperature) applies identically in both modes — the offload runtime
receives the engine's sampling function and PRNG stream.

For iteration-level admission (slots at ragged decode positions, new
requests admitted mid-decode, in either mode) use
`serving.continuous.ContinuousBatchingEngine`, which shares this
module's Request/Generation plumbing and the same scheduler-driven
offload runtime.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models import layers as L
from repro.models.transformer import Model
from repro.serving import sampler as samplers

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (s,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Generation:
    uid: int
    tokens: np.ndarray
    prefill_time: float
    decode_time: float

    @property
    def decode_tps(self) -> float:
        return len(self.tokens) / max(self.decode_time, 1e-9)


def pad_batch(reqs: List[Request]) -> np.ndarray:
    """Left-pad prompts to a common length (shared by both engines)."""
    s = max(len(r.prompt) for r in reqs)
    out = np.zeros((len(reqs), s), np.int32)
    for i, r in enumerate(reqs):
        out[i, s - len(r.prompt):] = r.prompt
    return out


def get_sampler(name: str):
    return samplers.greedy if name == "greedy" else samplers.temperature


class ServingEngine:
    def __init__(self, model: Model, params, mode: str = "resident",
                 hw: Optional[HardwareProfile] = None,
                 sampler: str = "greedy", seed: int = 0,
                 kvpr: bool = True, schedule: str = "row",
                 align: int = 1, compress: Optional[str] = None,
                 scheduler: Optional[Scheduler] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.mode = mode
        self.hw = hw or TPU_V5E
        self.kvpr = kvpr
        self.schedule = schedule
        self.align = align
        self.compress = compress
        self.scheduler = scheduler or Scheduler(self.hw)
        self.key = jax.random.PRNGKey(seed)
        self.sample = get_sampler(sampler)
        self._prefill = jax.jit(self.model.prefill,
                                static_argnames=("max_len",))
        self._decode = jax.jit(self.model.decode_step)
        # one persistent runtime: jit traces and the transfer engine's
        # staging buffers survive across serve() calls
        self.runtime = None
        if mode == "offload":
            self.runtime = OffloadDecodeRuntime(
                self.cfg, params, scheduler=self.scheduler,
                mode="kvpr" if kvpr else "flexgen",
                schedule=schedule, align=align, compress=compress)

    # -------------------------------------------------------------- serve

    def serve(self, reqs: List[Request],
              extra: Optional[Dict[str, Array]] = None
              ) -> List[Generation]:
        prompts = pad_batch(reqs)
        gen_len = max(r.max_new_tokens for r in reqs)
        if self.mode == "offload":
            return self._serve_offload(reqs, prompts, gen_len)
        return self._serve_resident(reqs, prompts, gen_len, extra)

    def _serve_resident(self, reqs, prompts, gen_len, extra):
        b, s = prompts.shape
        max_len = s + gen_len + 1
        if self.cfg.arch_type == "vlm" and extra:
            max_len += extra["patches"].shape[1]
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extra, max_len=max_len)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        toks = []
        self.key, k = jax.random.split(self.key)
        tok = self.sample(logits[:, -1], k)[:, None]
        t0 = time.perf_counter()
        for _ in range(gen_len):
            toks.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            self.key, k = jax.random.split(self.key)
            tok = self.sample(logits[:, -1], k)[:, None]
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        all_toks = np.concatenate(toks, axis=1)
        return [Generation(r.uid, all_toks[i, : r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(reqs)]

    # --------------------------------------------------- offload (KVPR)

    def _serve_offload(self, reqs, prompts, gen_len):
        """Prefill on-device, spill KV + activations to host, decode with
        the KVPR runtime (dense-family archs) under the scheduler's
        ExecutionPlan, sampling with the engine's configured sampler."""
        cfg = self.cfg
        b, s = prompts.shape
        store = HostKVStore(cfg, b, s + gen_len + 1,
                            compress=self.compress)
        t0 = time.perf_counter()
        logits, ks, vs, hs = prefill_with_activations(
            self.model, self.params, jnp.asarray(prompts))
        store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
        t_prefill = time.perf_counter() - t0

        self.key, k = jax.random.split(self.key)
        first = self.sample(logits[:, -1], k)[:, None]

        rt = self.runtime
        t0 = time.perf_counter()
        # Hand the runtime the engine's PRNG stream; the runtime splits it
        # once per step exactly as the resident loop does, so the two
        # modes draw identical sampling keys from the same seed.
        toks, stats = rt.decode(store, np.asarray(first), gen_len,
                                sample_fn=self.sample, key=self.key)
        t_decode = time.perf_counter() - t0
        # mirror the runtime's key consumption (decode() contract: one
        # split per generated token) so a later serve() continues the
        # stream exactly where the resident loop would
        for _ in range(gen_len):
            self.key, _ = jax.random.split(self.key)
        # runtime emits tokens *after* consuming `first`; prepend it
        all_toks = np.concatenate([np.asarray(first), toks], axis=1)
        return [Generation(r.uid, all_toks[i, : r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(reqs)]


def _prefill_with_activations(model: Model, params, tokens: Array):
    """Back-compat shim: greedy first token + spill tensors.  New code
    should use core.runtime.prefill_with_activations (returns logits so
    the caller's sampler decides the first token)."""
    logits, ks, vs, hs = prefill_with_activations(model, params, tokens)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return first, ks, vs, hs
