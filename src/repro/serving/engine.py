"""Legacy static-batching serving engine — a thin shim over the
request-level API in ``serving.api``.

``ServingEngine(model, params, mode="resident"|"offload", ...)`` maps
straight onto ``LLMEngine`` with ``EngineConfig(backend=mode,
batching="static")``; ``serve()`` translates each ``Request`` into
per-request ``SamplingParams`` (the engine-level ``sampler=`` /
``seed=`` become request defaults) and returns the same ``Generation``
records as before (``Generation`` is an alias of
``api.RequestOutput``).  New code should use ``LLMEngine`` directly —
see docs/api.md for the migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax

from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import sampler as samplers
from repro.serving.api import (EngineConfig, LLMEngine, Request,
                               RequestOutput, SamplingParams, pad_batch)

Array = jax.Array

# back-compat aliases: Generation(uid, tokens, prefill_time,
# decode_time) is positionally unchanged
Generation = RequestOutput

__all__ = ["Generation", "Request", "ServingEngine", "get_sampler",
           "pad_batch"]


def get_sampler(name: str):
    return samplers.greedy if name == "greedy" else samplers.temperature


class EngineShim:
    """Shared plumbing of the legacy engine facades: proxy the
    introspected LLMEngine internals and translate the engine-level
    ``sampler=`` default into per-request SamplingParams."""

    engine: LLMEngine
    sampler: str

    # engine internals some callers/tests introspect
    @property
    def model(self) -> Model:
        return self.engine.model

    @property
    def cfg(self):
        return self.engine.cfg

    @property
    def params(self):
        return self.engine.params

    @property
    def scheduler(self) -> Scheduler:
        return self.engine.scheduler

    @property
    def runtime(self):
        return self.engine.runtime

    def close(self) -> None:
        """Release the underlying engine's thread pools (idempotent)."""
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def _default_params(self, r: Request) -> SamplingParams:
        base = r.params or SamplingParams(max_tokens=r.max_new_tokens)
        if self.sampler == "temperature" and base.greedy is None \
                and base.temperature <= 0:
            base = dataclasses.replace(base, temperature=0.8)
        return base

    def serve(self, reqs: List[Request],
              extra: Optional[Dict[str, Array]] = None
              ) -> List[Generation]:
        sps = [self._default_params(r) for r in reqs]
        return self.engine.generate(reqs, sps, extra=extra)


class ServingEngine(EngineShim):
    """Fixed-batch serving over a resident or host-offloaded (KVPR) KV
    cache.  Thin shim over ``api.LLMEngine``."""

    def __init__(self, model: Model, params, mode: str = "resident",
                 hw: Optional[HardwareProfile] = None,
                 sampler: str = "greedy", seed: int = 0,
                 kvpr: bool = True, schedule: str = "row",
                 align: int = 1, compress: Optional[str] = None,
                 scheduler: Optional[Scheduler] = None,
                 kernels="auto"):
        self.mode = mode
        self.sampler = sampler
        config = EngineConfig(
            backend="offload" if mode == "offload" else "resident",
            batching="static", kvpr=kvpr, schedule=schedule,
            align=align, compress=compress, hw=hw or TPU_V5E, seed=seed,
            kernels=kernels)
        self.engine = LLMEngine(model, params, config,
                                scheduler=scheduler)
