"""Batched serving engine with KVPR-aware decode.

Two execution modes:
  - "resident": classic HBM-resident KV cache (prefill + decode_step);
    this is the baseline serving path and the dry-run `serve_step`.
  - "offload":  host-offloaded KV via core.runtime.OffloadDecodeRuntime —
    the paper's system (KVPR split solver + overlapped streams), for
    dense-family models.

Requests are grouped into fixed-size batches (padded to the same prompt
length, as the paper's workloads do); the engine runs prefill once and
then the decode loop, returning per-request generations. Continuous
batching is intentionally out of scope (the paper batches statically).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.runtime import HostKVStore, OffloadDecodeRuntime
from repro.models import layers as L
from repro.models.transformer import Model
from repro.serving import sampler as samplers

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (s,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Generation:
    uid: int
    tokens: np.ndarray
    prefill_time: float
    decode_time: float

    @property
    def decode_tps(self) -> float:
        return len(self.tokens) / max(self.decode_time, 1e-9)


class ServingEngine:
    def __init__(self, model: Model, params, mode: str = "resident",
                 hw: Optional[HardwareProfile] = None,
                 sampler: str = "greedy", seed: int = 0,
                 kvpr: bool = True, schedule: str = "row",
                 compress: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.mode = mode
        self.hw = hw or TPU_V5E
        self.kvpr = kvpr
        self.schedule = schedule
        self.compress = compress
        self.key = jax.random.PRNGKey(seed)
        self.sample = (samplers.greedy if sampler == "greedy"
                       else samplers.temperature)
        self._prefill = jax.jit(self.model.prefill,
                                static_argnames=("max_len",))
        self._decode = jax.jit(self.model.decode_step)

    # ------------------------------------------------------------ batching

    def _pad_batch(self, reqs: List[Request]) -> np.ndarray:
        s = max(len(r.prompt) for r in reqs)
        out = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            out[i, s - len(r.prompt):] = r.prompt  # left-pad
        return out

    # -------------------------------------------------------------- serve

    def serve(self, reqs: List[Request],
              extra: Optional[Dict[str, Array]] = None
              ) -> List[Generation]:
        prompts = self._pad_batch(reqs)
        gen_len = max(r.max_new_tokens for r in reqs)
        if self.mode == "offload":
            return self._serve_offload(reqs, prompts, gen_len)
        return self._serve_resident(reqs, prompts, gen_len, extra)

    def _serve_resident(self, reqs, prompts, gen_len, extra):
        b, s = prompts.shape
        max_len = s + gen_len + 1
        if self.cfg.arch_type == "vlm" and extra:
            max_len += extra["patches"].shape[1]
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extra, max_len=max_len)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        toks = []
        self.key, k = jax.random.split(self.key)
        tok = self.sample(logits[:, -1], k)[:, None]
        t0 = time.perf_counter()
        for _ in range(gen_len):
            toks.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            self.key, k = jax.random.split(self.key)
            tok = self.sample(logits[:, -1], k)[:, None]
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        all_toks = np.concatenate(toks, axis=1)
        return [Generation(r.uid, all_toks[i, : r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(reqs)]

    # --------------------------------------------------- offload (KVPR)

    def _serve_offload(self, reqs, prompts, gen_len):
        """Prefill on-device, spill KV + activations to host, decode with
        the KVPR runtime (dense-family archs)."""
        cfg = self.cfg
        b, s = prompts.shape
        store = HostKVStore(cfg, b, s + gen_len + 1,
                            compress=self.compress)
        t0 = time.perf_counter()
        first, ks, vs, hs = _prefill_with_activations(
            self.model, self.params, jnp.asarray(prompts))
        store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
        t_prefill = time.perf_counter() - t0

        rt = OffloadDecodeRuntime(
            cfg, self.params, self.hw,
            mode="kvpr" if self.kvpr else "flexgen",
            schedule=self.schedule, compress=self.compress)
        t0 = time.perf_counter()
        toks, stats = rt.decode(store, np.asarray(first), gen_len)
        t_decode = time.perf_counter() - t0
        # runtime emits tokens *after* consuming `first`; prepend it
        all_toks = np.concatenate([np.asarray(first), toks], axis=1)
        return [Generation(r.uid, all_toks[i, : r.max_new_tokens],
                           t_prefill, t_decode)
                for i, r in enumerate(reqs)]


def _prefill_with_activations(model: Model, params, tokens: Array):
    """Dense-family prefill that also returns per-layer attention-input
    activations (the host-resident tensors KVPR recomputes from)."""
    cfg = model.cfg
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(tokens, params["embed"], cfg, jnp.arange(s))

    def body(x, lp):
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
        out = L.chunked_causal_attend(q, k, v)
        out = out.reshape(b, s, cfg.num_heads * cfg.dh)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        return x, (k, v, h)

    x, (ks, vs, hs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(x[:, -1:], params["embed"], cfg)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return first, ks, vs, hs
