"""Request-level serving API (see docs/api.md).

    from repro.serving import EngineConfig, LLMEngine, SamplingParams

Fault model (see docs/robustness.md): ``FaultPolicy`` plugs into
``EngineConfig.faults``; the typed errors are what ``generate`` /
``generate_stream`` raise when a failure cannot be contained to one
request.
"""
from repro.core.faults import (DiskFullError, DiskReadError, FaultPolicy,
                               KernelLaunchError, RequestFaultError,
                               TransferError, TransferStallError,
                               TransientTransferError, WriteBackError)
from repro.core.kvstore import (KVTiersConfig, StoreCapacityError,
                                TieredStoreStats)
from repro.core.prefix_cache import PrefixCacheConfig, PrefixCacheStats
from repro.launch.mesh import MeshConfig
from repro.serving.api import (EngineConfig, LLMEngine, Request,
                               RequestOutput, SamplingParams,
                               TokenEvent, pad_batch)
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Generation, ServingEngine
from repro.serving.router import (RouterConfig, RouterEngine,
                                  RouterQueueFull, RouterStats,
                                  SLOClass, slo_attained)

__all__ = [
    "ContinuousBatchingEngine", "DiskFullError", "DiskReadError",
    "EngineConfig", "FaultPolicy", "Generation", "KVTiersConfig",
    "KernelLaunchError", "LLMEngine", "MeshConfig", "PrefixCacheConfig",
    "PrefixCacheStats", "Request", "RequestFaultError", "RequestOutput",
    "RouterConfig", "RouterEngine", "RouterQueueFull", "RouterStats",
    "SLOClass", "SamplingParams", "ServingEngine", "StoreCapacityError",
    "TieredStoreStats", "TokenEvent", "TransferError",
    "TransferStallError", "TransientTransferError", "WriteBackError",
    "pad_batch", "slo_attained",
]
