"""Request-level serving API (see docs/api.md).

    from repro.serving import EngineConfig, LLMEngine, SamplingParams
"""
from repro.core.prefix_cache import PrefixCacheConfig, PrefixCacheStats
from repro.serving.api import (EngineConfig, LLMEngine, Request,
                               RequestOutput, SamplingParams,
                               TokenEvent, pad_batch)
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Generation, ServingEngine

__all__ = [
    "ContinuousBatchingEngine", "EngineConfig", "Generation",
    "LLMEngine", "PrefixCacheConfig", "PrefixCacheStats", "Request",
    "RequestOutput", "SamplingParams", "ServingEngine", "TokenEvent",
    "pad_batch",
]
