"""One request-level serving API over every decode path.

This module is the single front door to the serving stack (the
ROADMAP's "serving system" layer on top of the paper's profiler →
scheduler → runtime loop):

  - ``EngineConfig``    declarative engine choice — ``backend``
                        ("resident" HBM cache vs "offload" host KV +
                        KVPR) × ``batching`` ("static" padded batches
                        vs "continuous" iteration-level slots) —
                        replacing the old four mode strings.
  - ``SamplingParams``  per-request sampling + termination: greedy or
                        temperature/top-k, an optional per-request
                        seed, ``max_tokens``, and EOS/stop ids.  One
                        batch can mix greedy and stochastic requests;
                        the params travel as vectorized per-slot arrays
                        through ``serving.sampler.sample_step``.
  - ``LLMEngine``       ``generate()`` → ``RequestOutput``s and
                        ``generate_stream()`` → per-token
                        ``TokenEvent``s, over all four backend×batching
                        combinations, with request lifecycle: a request
                        whose EOS fires at step k finishes with
                        ``finish_reason="stop"`` after exactly k tokens
                        (the stop token is included), its slot is
                        released mid-decode, and — under continuous
                        batching — the next queued request is admitted
                        into the freed slot.

Sampling-stream invariant (see ``serving.sampler``): request uid's t-th
token is always drawn with ``fold_in(request_key, t)``, so generations
are identical across backends and batch compositions given one seed —
the property the old engines maintained with an O(gen_len) host-side
key-mirroring loop, now by construction.

The legacy ``ServingEngine`` / ``ContinuousBatchingEngine`` classes are
thin shims over this module; new code should use::

    from repro.serving import EngineConfig, LLMEngine, SamplingParams
    eng = LLMEngine.from_config(model, params,
                                EngineConfig(backend="offload"))
    outs = eng.generate(prompts, SamplingParams(max_tokens=16,
                                                eos_id=2))
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from typing import (Deque, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import HardwareProfile, TPU_V5E
from repro.core.faults import (FaultPolicy, RequestFaultError,
                               TransferStallError)
from repro.core.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                     PrefixCacheStats)
from repro.core.kvstore import KVTiersConfig, TieredKVStore
from repro.core.runtime import (ChunkedPrefill, HostKVStore,
                                OffloadDecodeRuntime, RestoreStats,
                                StepStats, TransferEngine, chunk_width,
                                prefill_with_activations,
                                restore_prefix_kv)
from repro.core.scheduler import Scheduler
from repro.launch.mesh import MeshConfig, resolve_mesh
from repro.models.cache import broadcast_slots, splice_slot
from repro.models.transformer import Model
from repro.serving import sampler as samplers

Array = jax.Array

_MODE_MAP = {
    "resident": ("resident", "static"),
    "offload": ("offload", "static"),
    "continuous": ("resident", "continuous"),
    "continuous-offload": ("offload", "continuous"),
}


# ------------------------------------------------------------- configs

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling + termination parameters (vLLM-style).

    temperature <= 0 (or greedy=True) means argmax decoding.  ``seed``
    pins the request's PRNG stream independently of the engine seed.
    ``eos_id`` / ``stop_ids`` terminate the request early with
    ``finish_reason="stop"``; the stop token itself is included in the
    returned tokens.
    """
    max_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    greedy: Optional[bool] = None        # None -> temperature <= 0
    seed: Optional[int] = None
    eos_id: Optional[int] = None
    stop_ids: Tuple[int, ...] = ()

    @property
    def is_greedy(self) -> bool:
        return self.greedy if self.greedy is not None \
            else self.temperature <= 0

    @property
    def stop_set(self) -> frozenset:
        ids = set(self.stop_ids)
        if self.eos_id is not None:
            ids.add(self.eos_id)
        return frozenset(ids)

    def validate(self) -> "SamplingParams":
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got "
                             f"{self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        return self


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Declarative engine configuration: which KV backend and which
    batching discipline, plus the KVPR knobs the scheduler needs.
    Replaces the old mode strings ("resident" / "offload" /
    "continuous" / "continuous-offload") — see ``from_mode`` for the
    migration map (documented in docs/api.md)."""
    backend: str = "resident"            # "resident" | "offload"
    batching: str = "static"             # "static" | "continuous"
    slots: int = 4                       # continuous: concurrent slots
    max_len: int = 256                   # continuous: per-slot capacity
    compress: Optional[str] = None       # None | "int4" (offload)
    kvpr: bool = True                    # offload: partial recompute
    schedule: str = "row"                # KVPR split schedule
    align: int = 1                       # KVPR split alignment
    hw: Optional[HardwareProfile] = None
    seed: int = 0
    # shared-prefix KV cache (cross-request prompt reuse): admission
    # looks up the longest cached prefix of each prompt and restores it
    # via the scheduler's KVPR split instead of prefilling it.  None
    # disables.  Dense-family archs only.
    prefix_cache: Optional[PrefixCacheConfig] = None
    # chunked prefill: process prompts in chunks instead of one
    # monolithic pass.  On the offload backend each finished chunk's KV
    # streams to the host while the next chunk computes; under
    # continuous batching prompt chunks interleave with decode steps
    # (see max_step_tokens).  A positive int fixes the chunk width;
    # "auto" asks the scheduler's chunk_split cost model; None keeps
    # inline (monolithic) prefill.  Execution strategy only — tokens
    # are identical either way.  Dense-family archs only.
    prefill_chunk: Optional[Union[int, str]] = None
    # continuous batching: per-step token budget shared by decode (one
    # token per active slot, always served first) and admission prefill
    # chunks (the remainder) — a long prompt admits over several steps
    # instead of stalling every in-flight decode.  Requires
    # prefill_chunk.
    max_step_tokens: Optional[int] = None
    # Pallas kernel dispatch for the offload decode hot path (fused
    # recompute+attend, flash decode, in-kernel int4 dequant).  "auto"
    # compiles the kernels natively on TPU and keeps the jnp oracle
    # path elsewhere; True opts in everywhere (interpret mode off-TPU —
    # what tests and CI parity lanes use); False forces the jnp path.
    # Tokens are identical either way; see kernels.ops.kernel_mode.
    kernels: Union[bool, str] = "auto"
    # ---- fault isolation (docs/robustness.md) -----------------------
    # fault injection hook threaded through the transfer engine, the
    # store fences and admission (None = no injection; the check is a
    # single None test on the hot path)
    faults: Optional[FaultPolicy] = None
    # fence-watchdog deadline: a write-back fence or KV fetch that
    # exceeds it raises TransferStallError instead of hanging decode
    # forever.  None = wait forever (the pre-fault-layer behavior).
    fence_timeout_s: Optional[float] = 60.0
    # transient transfer/write-back failures retry with exponential
    # backoff: io_backoff_s * 2**attempt, up to io_retries times
    io_retries: int = 2
    io_backoff_s: float = 0.01
    # ---- tiered KV storage (docs/storage.md) ------------------------
    # pinned host DRAM over an mmap disk rung: KVTiersConfig sets the
    # accounted host capacity (tokens past it demote, coldest first),
    # dual LRU+TTL eviction, compress-on-demote, emulated disk
    # bandwidth, and the scheduling policy ("tier_split" plans the
    # transfer-vs-recompute split over both links; "demand" is the
    # naive demand-paging baseline).  None keeps the single-tier store.
    # Offload backend only — a no-op on the resident backend (like
    # `kernels`), which is what pins the identity-matrix reference.
    kv_tiers: Optional[KVTiersConfig] = None
    # ---- mesh sharding (docs/scaling.md) ----------------------------
    # (data, model) topology.  A model-axis size k shards the offload
    # data plane k ways: every KV fetch streams k disjoint head-slices
    # concurrently over 1/k of the link each, and the scheduler solves
    # all four plan kinds from ONE shard's point of view
    # (PlanKey.shards).  Accepts a MeshConfig, "auto" (every visible
    # device on the model axis), or None; None and a 1x1 mesh are the
    # unsharded path and behave bit-identically to a mesh-free engine.
    # Offload backend only — a no-op on the resident backend (like
    # `kernels` and `kv_tiers`), which is what pins the identity-matrix
    # reference.
    mesh: Union[None, str, MeshConfig] = None

    def validate(self) -> "EngineConfig":
        if self.backend not in ("resident", "offload"):
            raise ValueError(
                f"backend must be 'resident' or 'offload', got "
                f"{self.backend!r}")
        if self.batching not in ("static", "continuous"):
            raise ValueError(
                f"batching must be 'static' or 'continuous', got "
                f"{self.batching!r}")
        if self.compress not in (None, "int4"):
            raise ValueError(f"compress must be None or 'int4', got "
                             f"{self.compress!r}")
        if self.kernels not in (True, False, None, "auto", "on", "off",
                                "interpret", "pallas"):
            raise ValueError(
                f"kernels must be a bool, 'auto', 'on', 'off', "
                f"'interpret' or 'pallas', got {self.kernels!r}")
        if self.batching == "continuous" and self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.prefix_cache is not None:
            self.prefix_cache.validate()
        pc = self.prefill_chunk
        if pc is not None:
            if pc != "auto" and not (isinstance(pc, int)
                                     and not isinstance(pc, bool)
                                     and pc >= 1):
                raise ValueError(
                    f"prefill_chunk must be a positive int or 'auto', "
                    f"got {pc!r}")
            if self.prefix_cache is not None:
                raise ValueError(
                    "prefill_chunk is not supported together with "
                    "prefix_cache (prefix-cache hits admit inline)")
        if self.max_step_tokens is not None:
            if self.max_step_tokens < 1:
                raise ValueError(f"max_step_tokens must be >= 1, got "
                                 f"{self.max_step_tokens}")
            if self.batching != "continuous":
                raise ValueError(
                    "max_step_tokens requires batching='continuous' "
                    "(static batches have no step loop to budget)")
            if pc is None:
                raise ValueError(
                    "max_step_tokens requires prefill_chunk (an inline "
                    "prefill cannot be split across steps)")
        if self.fence_timeout_s is not None and self.fence_timeout_s <= 0:
            raise ValueError(f"fence_timeout_s must be positive or "
                             f"None, got {self.fence_timeout_s}")
        if self.io_retries < 0:
            raise ValueError(f"io_retries must be >= 0, got "
                             f"{self.io_retries}")
        if self.io_backoff_s < 0:
            raise ValueError(f"io_backoff_s must be >= 0, got "
                             f"{self.io_backoff_s}")
        if self.kv_tiers is not None:
            self.kv_tiers.validate()
        if self.mesh is not None:
            resolve_mesh(self.mesh)
        return self

    @property
    def shards(self) -> int:
        """Model-axis mesh size the offload data plane shards over.
        Always 1 on the resident backend — it never streams KV, so
        there is nothing to shard and the identity reference stays
        pinned."""
        if self.backend != "offload":
            return 1
        return resolve_mesh(self.mesh).model

    @property
    def mode(self) -> str:
        """The legacy mode string this config corresponds to."""
        for mode, (backend, batching) in _MODE_MAP.items():
            if (backend, batching) == (self.backend, self.batching):
                return mode
        raise AssertionError(self)

    @classmethod
    def from_mode(cls, mode: str, **overrides) -> "EngineConfig":
        """Migration helper: map an old mode string to an EngineConfig."""
        if mode not in _MODE_MAP:
            raise ValueError(
                f"unknown mode {mode!r}; expected one of "
                f"{sorted(_MODE_MAP)} — or construct EngineConfig("
                f"backend=..., batching=...) directly")
        backend, batching = _MODE_MAP[mode]
        return cls(backend=backend, batching=batching,
                   **overrides).validate()


# ------------------------------------------------------------ requests

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (s,) int32
    max_new_tokens: int = 32             # legacy budget (no params)
    params: Optional[SamplingParams] = None
    # ---- scheduling metadata (serving/router; see docs/serving.md) --
    # larger priority = more urgent; ties broken by arrival order
    priority: int = 0
    # admission deadline: a request still QUEUED this long after
    # t_enqueue is dropped (finish_reason="deadline") instead of served
    deadline_s: Optional[float] = None
    # SLO class name (RouterConfig.slo_classes key); attainment is
    # judged against that class's TTFT/TPOT targets
    slo: Optional[str] = None
    # when the request entered the SYSTEM (router admission queue) —
    # stamped by the engine at generate() when absent, so queue_wait /
    # ttft measure end-to-end latency, not engine-internal latency
    t_enqueue: Optional[float] = None
    # sampling-stream offset for preemption resume: token t of this
    # request draws with fold_in(request_key, token_offset + t), so a
    # continuation request (prompt extended by the tokens generated
    # before preemption) continues the SAME stream the uninterrupted
    # run would have used
    token_offset: int = 0


@dataclasses.dataclass
class RequestOutput:
    """One finished request.  Also serves as the legacy ``Generation``
    (same leading fields, positionally compatible).

    ``finish_reason="error"`` means THIS request failed (hard fault on
    its admission, write-back or restore) and was contained: ``error``
    carries the reason, ``tokens`` holds whatever was generated before
    the fault, and the rest of the batch is unaffected (see
    docs/robustness.md)."""
    uid: int
    tokens: np.ndarray
    prefill_time: float = 0.0
    decode_time: float = 0.0
    finish_reason: str = "length"        # "length" | "stop" | "error"
                                         # | "preempted" | "deadline"
    cached_prefix: int = 0               # prompt tokens restored from
                                         # the shared-prefix cache
    restore: Optional[RestoreStats] = None   # how they were restored
    error: Optional[str] = None          # "ExcType: message" when
                                         # finish_reason == "error"
    # ---- per-request timing (perf_counter timestamps; SLO accounting,
    # see docs/serving.md).  t_enqueue is when the request entered the
    # system (router queue or generate() call), so queue_wait / ttft
    # include scheduling delay, not just engine time.
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    queue_wait: float = 0.0              # t_enqueue -> prefill start
    # ---- router metadata (left at defaults by a bare LLMEngine) -----
    slo: Optional[str] = None            # SLO class the request declared
    replica: Optional[int] = None        # replica that finished it
    preemptions: int = 0                 # times preempted + resumed

    @property
    def decode_tps(self) -> float:
        return len(self.tokens) / max(self.decode_time, 1e-9)

    @property
    def ttft(self) -> float:
        """Time to first token, measured from t_enqueue."""
        return max(self.t_first_token - self.t_enqueue, 0.0)

    @property
    def tpot(self) -> float:
        """Mean per-output-token latency after the first token."""
        n = len(self.tokens)
        if n <= 1 or self.t_first_token <= 0:
            return 0.0
        return (self.t_finish - self.t_first_token) / (n - 1)


@dataclasses.dataclass
class TokenEvent:
    """One streamed token: request uid, the token, its index within the
    request, the engine step that produced it, the finish reason when
    this is the request's last token, and the producing step's
    ``StepStats`` on offload backends.

    A contained per-request failure is streamed as a sentinel event
    with ``finish_reason="error"`` and ``token == -1`` / ``index ==
    -1`` (no token was produced) — consumers should treat it as the
    request's terminal event."""
    uid: int
    token: int
    index: int
    step: int
    finish_reason: Optional[str] = None
    stats: Optional[StepStats] = None


def pad_batch(reqs: Sequence[Request]) -> np.ndarray:
    """Left-pad prompts to a common length (static batching)."""
    s = max(len(r.prompt) for r in reqs)
    out = np.zeros((len(reqs), s), np.int32)
    for i, r in enumerate(reqs):
        out[i, s - len(r.prompt):] = r.prompt
    return out


# --------------------------------------------------- internal plumbing

@dataclasses.dataclass
class _Live:
    """One in-flight request's lifecycle state."""
    req: Request
    sp: SamplingParams
    stop: frozenset
    tokens: List[int]
    t_prefill: float = 0.0
    t_start: float = 0.0
    t_enqueue: float = 0.0               # system arrival (Request stamp)
    t_admit: float = 0.0                 # prefill start (queue_wait end)
    t_first: float = 0.0                 # first token sampled
    finish_reason: Optional[str] = None
    restore: Optional[RestoreStats] = None   # prefix-cache restore info
    blocks: Optional[tuple] = None       # (ks, vs, hs) prompt blocks,
                                         # inserted into the prefix
                                         # cache when the request ends


@dataclasses.dataclass
class _ResidentChunk:
    """Resumable chunked prefill of one b=1 resident cache (continuous
    admission): the mirror of the offload path's ``ChunkedPrefill``,
    building the device cache chunk by chunk via ``Model.prefill_chunk``
    instead of streaming host blocks."""
    cache: dict
    prompt: np.ndarray
    chunk: int
    q_block: int = 512
    pos: int = 0
    logits: Optional[Array] = None

    @property
    def done(self) -> bool:
        return self.pos >= len(self.prompt)

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.pos

    @property
    def next_width(self) -> int:
        return chunk_width(self.chunk, self.remaining, self.q_block)


@dataclasses.dataclass
class _Pending:
    """One admission in flight under chunked (mixed-step) prefill.
    ``credit`` banks unspent step-budget tokens: chunks only run at
    their full (grid) width once enough credit accrued, so the XLA
    trace set stays O(n / chunk) instead of one trace per
    budget-truncated sliver, while the budget stays an amortized
    per-step cap."""
    req: Request
    sp: SamplingParams
    state: object                  # ChunkedPrefill | _ResidentChunk
    t_start: float
    credit: int = 0


class _SlotSampling:
    """Vectorized per-slot sampling state: request base keys and
    sampling params as (b,) arrays, one row per batch slot, consumed by
    ``sampler.sample_step``.  Static batches fill every row once;
    continuous engines rewrite a row at each admission."""

    def __init__(self, engine_key: Array, b: int):
        self.engine_key = engine_key
        self.keys = np.zeros((b, 2), np.uint32)
        self.temps = np.zeros((b,), np.float32)
        self.top_ks = np.zeros((b,), np.int32)
        self.greedy = np.ones((b,), bool)
        self._dev = None             # device copies, rebuilt on set_slot

    def set_slot(self, i: int, uid: int, sp: SamplingParams) -> None:
        self.keys[i] = np.asarray(
            samplers.request_key(self.engine_key, uid, sp.seed))
        self.temps[i] = max(sp.temperature, 0.0)
        self.top_ks[i] = sp.top_k
        self.greedy[i] = sp.is_greedy
        self._dev = None

    def _device(self):
        """Slot params change only at admission; keep their device
        copies across decode steps (the hot loop transfers only the
        per-step ``steps`` vector)."""
        if self._dev is None:
            self._dev = (jnp.asarray(self.keys), jnp.asarray(self.temps),
                         jnp.asarray(self.top_ks),
                         jnp.asarray(self.greedy))
        return self._dev

    def sample(self, logits: Array, steps) -> Array:
        """Draw every slot's next token; ``steps`` is the per-slot token
        index t (scalar broadcasts), feeding fold_in(request_key, t)."""
        keys, temps, top_ks, greedy = self._device()
        b = self.keys.shape[0]
        if np.ndim(steps) == 0:
            steps = np.full((b,), steps)
        return samplers.sample_step(
            logits, keys, jnp.asarray(np.asarray(steps), jnp.uint32),
            temps, top_ks, greedy)

    def sample_one(self, logits_row: Array, i: int, step: int) -> int:
        """Draw slot i's token t=``step`` alone (admission prefill)."""
        out = samplers.sample_step(
            logits_row, jnp.asarray(self.keys[i:i + 1]),
            jnp.asarray([step], jnp.uint32),
            jnp.asarray(self.temps[i:i + 1]),
            jnp.asarray(self.top_ks[i:i + 1]),
            jnp.asarray(self.greedy[i:i + 1]))
        return int(out[0])


RequestLike = Union[Request, np.ndarray, Sequence[int]]
SamplingLike = Union[None, SamplingParams, Sequence[SamplingParams]]


# -------------------------------------------------------------- engine

class LLMEngine:
    """The request-level serving engine over all four decode paths.

    One instance owns one persistent offload runtime (jit traces and
    staging buffers survive across ``generate()`` calls) and one
    Scheduler, so every path runs through the paper's profiler →
    scheduler → runtime automation loop.
    """

    def __init__(self, model: Model, params,
                 config: Optional[EngineConfig] = None,
                 scheduler: Optional[Scheduler] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.config = (config or EngineConfig()).validate()
        self.scheduler = scheduler or Scheduler(self.config.hw or TPU_V5E)
        self.key = jax.random.PRNGKey(self.config.seed)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))
        # resumable chunked prefill: one XLA trace per (p0, chunk) pair
        # (drivers keep chunk widths fixed, so traces stay O(n / chunk))
        self._prefill_chunk = jax.jit(model.prefill_chunk,
                                      static_argnames=("p0",))
        self.faults = self.config.faults
        self._closed = False
        # cooperative preemption flags (router load shedding): uids to
        # finish with "preempted" at the next step boundary
        self._preempt: set = set()
        self._preempt_lock = threading.Lock()
        self.runtime: Optional[OffloadDecodeRuntime] = None
        if self.config.backend == "offload":
            self.runtime = OffloadDecodeRuntime(
                self.cfg, params, scheduler=self.scheduler,
                mode="kvpr" if self.config.kvpr else "flexgen",
                schedule=self.config.schedule, align=self.config.align,
                compress=self.config.compress,
                kernels=self.config.kernels, faults=self.faults,
                io_retries=self.config.io_retries,
                io_backoff_s=self.config.io_backoff_s,
                fence_timeout_s=self.config.fence_timeout_s,
                shards=self.config.shards)
        elif self.config.batching == "continuous":
            # vmap over the slot axis: params broadcast, cache + token
            # mapped
            self._vstep = jax.jit(jax.vmap(model.decode_step,
                                           in_axes=(None, 0, 0)))
        else:
            self._decode = jax.jit(model.decode_step)
        self.prefix_cache: Optional[PrefixCache] = None
        self._restore_xfer: Optional[TransferEngine] = None
        self._owns_restore_xfer = False
        self._keep_blocks = False
        if self.config.prefix_cache is not None:
            # same support envelope as prefill_with_activations (the
            # admission path): dense layers only — MoE layer params
            # carry "moe", not "mlp"
            if self.cfg.arch_type != "dense" or model.is_local_global:
                raise ValueError(
                    "prefix_cache requires a dense arch without "
                    f"local/global layers, got {self.cfg.arch_type!r}")
            self.prefix_cache = PrefixCache(self.config.prefix_cache)
            # only hold prompt blocks across a request's lifetime when
            # they will actually be inserted at finish
            self._keep_blocks = self.prefix_cache.config.insert_on_finish
            if self.runtime is not None:
                self._restore_xfer = self.runtime.xfer
            else:
                self._restore_xfer = TransferEngine(
                    n_copy_threads=1, faults=self.faults,
                    retries=self.config.io_retries,
                    backoff_s=self.config.io_backoff_s)
                self._owns_restore_xfer = True

    @classmethod
    def from_config(cls, model: Model, params, config: EngineConfig,
                    scheduler: Optional[Scheduler] = None) -> "LLMEngine":
        return cls(model, params, config, scheduler)

    # ------------------------------------------------------- lifecycle

    def close(self) -> None:
        """Release the engine's thread pools (the offload runtime's
        transfer engine and/or the resident prefix-restore pool).
        Idempotent and safe while a stream is in flight: a second close
        returns immediately (flag-guarded, and the pool shutdowns
        themselves are lock-guarded in TransferEngine), and any fault-
        injected dead-store hang is released before joining workers.
        The engine must not be used afterwards."""
        if self._closed:
            return
        self._closed = True
        if self.runtime is not None:
            self.runtime.close()
        if self._owns_restore_xfer and self._restore_xfer is not None:
            self._restore_xfer.close()

    def __enter__(self) -> "LLMEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    @property
    def prefix_stats(self) -> Optional[PrefixCacheStats]:
        """Hit/eviction/saved-token counters of the shared-prefix cache
        (None when the cache is disabled)."""
        return (self.prefix_cache.stats if self.prefix_cache is not None
                else None)

    # -------------------------------------------------------- frontend

    def generate(self, requests: Iterable[RequestLike],
                 sampling: SamplingLike = None,
                 extra: Optional[Dict[str, Array]] = None
                 ) -> List[RequestOutput]:
        """Serve the requests to completion; outputs in request order."""
        pairs = self._normalize(requests, sampling)
        done: Dict[int, RequestOutput] = {}
        for _ in self._stream(pairs, done, extra):
            pass
        return [done[r.uid] for r, _ in pairs]

    def generate_stream(self, requests: Iterable[RequestLike],
                        sampling: SamplingLike = None,
                        extra: Optional[Dict[str, Array]] = None
                        ) -> Iterator[TokenEvent]:
        """Yield one ``TokenEvent`` per generated token, in engine-step
        order (slots of one step yield consecutively)."""
        pairs = self._normalize(requests, sampling)
        done: Dict[int, RequestOutput] = {}
        yield from self._stream(pairs, done, extra)

    def _normalize(self, requests, sampling
                   ) -> List[Tuple[Request, SamplingParams]]:
        requests = list(requests)
        sampling_seq = isinstance(sampling, (list, tuple))
        if sampling_seq and len(sampling) != len(requests):
            raise ValueError(
                f"per-request sampling list has {len(sampling)} "
                f"entries for {len(requests)} requests")
        now = time.perf_counter()
        pairs = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(uid=i, prompt=np.asarray(r, np.int32))
            if r.t_enqueue is None:
                r.t_enqueue = now
            sp = sampling[i] if sampling_seq else sampling
            if sp is None:
                sp = r.params or SamplingParams(
                    max_tokens=r.max_new_tokens)
            pairs.append((r, sp.validate()))
        if not pairs:
            raise ValueError("generate() needs at least one request")
        return pairs

    def _stream(self, pairs, done, extra) -> Iterator[TokenEvent]:
        if self.config.batching == "continuous":
            if extra:
                raise ValueError(
                    "extra (VLM patches) is only supported under "
                    "static batching")
            return self._stream_continuous(pairs, done)
        if self.config.backend == "offload":
            if extra:
                raise ValueError(
                    "extra (VLM patches) is only supported on the "
                    "resident backend")
            return self._stream_static(pairs, done, extra, offload=True)
        return self._stream_static(pairs, done, extra, offload=False)

    def _stream_static(self, pairs, done, extra, offload: bool
                       ) -> Iterator[TokenEvent]:
        """Static dispatch behind the admission fault gate: hard-failed
        requests yield their sentinel error events up front, the
        survivors run as the (smaller) static batch."""
        pairs, err_evs = self._admit_filter(pairs, done)
        yield from err_evs
        if not pairs:
            return
        if offload:
            yield from self._stream_static_offload(pairs, done)
        else:
            yield from self._stream_static_resident(pairs, done, extra)

    # ----------------------------------------------- shared lifecycle

    def _lives(self, pairs, t_prefill: float, t_start: float
               ) -> List[_Live]:
        t_admit = t_start - t_prefill
        return [_Live(r, sp, sp.stop_set, [], t_prefill, t_start,
                      t_enqueue=r.t_enqueue or t_admit, t_admit=t_admit)
                for r, sp in pairs]

    def _finish(self, lv: _Live, reason: str, now: float, done) -> None:
        """Record a finished request's output; feed its prompt blocks
        into the shared-prefix cache (insertion on finish — including a
        PREEMPTED finish, so the resume restores the prompt through the
        transfer-vs-recompute split instead of re-prefilling it)."""
        lv.finish_reason = reason
        with self._preempt_lock:
            # a preempt flag that raced a natural finish must not
            # survive to hit a later request reusing this uid
            self._preempt.discard(lv.req.uid)
        done[lv.req.uid] = RequestOutput(
            lv.req.uid, np.asarray(lv.tokens, np.int32),
            lv.t_prefill, now - lv.t_start, reason,
            cached_prefix=lv.restore.matched if lv.restore else 0,
            restore=lv.restore, t_enqueue=lv.t_enqueue,
            t_first_token=lv.t_first, t_finish=now,
            queue_wait=max(lv.t_admit - lv.t_enqueue, 0.0),
            slo=lv.req.slo)
        if (self.prefix_cache is not None and lv.blocks is not None
                and self.prefix_cache.config.insert_on_finish):
            self.prefix_cache.insert(lv.req.prompt, *lv.blocks)
        lv.blocks = None

    def _advance(self, lives: List[_Live], toks: np.ndarray, step: int,
                 stats: Optional[StepStats], done
                 ) -> List[TokenEvent]:
        """Append each unfinished request's next token; mark stop/length
        finishes and record their outputs."""
        now = time.perf_counter()
        events = []
        for i, lv in enumerate(lives):
            if lv.finish_reason is not None:
                continue
            tok = int(toks[i])
            lv.tokens.append(tok)
            if lv.t_first == 0.0:
                lv.t_first = now
            fin = None
            if tok in lv.stop:
                fin = "stop"
            elif len(lv.tokens) >= lv.sp.max_tokens:
                fin = "length"
            events.append(TokenEvent(lv.req.uid, tok,
                                     len(lv.tokens) - 1, step, fin,
                                     stats))
            if fin is not None:
                self._finish(lv, fin, now, done)
        return events

    # -------------------------------------------------- preemption

    def preempt(self, uid: int) -> None:
        """Request cooperative preemption of ``uid`` (thread-safe; the
        router's load-shedding hook).  The decode loop observes the
        flag at its next step boundary: the request finishes with
        ``finish_reason="preempted"`` keeping the tokens generated so
        far, its slot is released through the existing mid-decode
        machinery (active-mask dropout under static batching, slot
        clear + re-admission under continuous), and — when the prefix
        cache is on — its prompt blocks are inserted so a resume
        restores via the transfer-vs-recompute split instead of
        recomputing from scratch.  A uid that is not (or no longer)
        decoding is a no-op."""
        with self._preempt_lock:
            self._preempt.add(uid)

    def _take_preempts(self, uids) -> set:
        """Claim pending preemption flags for ``uids`` (consume-once)."""
        with self._preempt_lock:
            hit = self._preempt & set(uids)
            self._preempt -= hit
            return hit

    def _preempt_sweep(self, lives: List[_Live], step: int, done
                       ) -> List[TokenEvent]:
        """Static-path preemption point (between decode steps): finish
        every flagged live request and emit its sentinel event (token
        -1, index -1 — no token was produced by preemption)."""
        live = {lv.req.uid for lv in lives if lv.finish_reason is None}
        hit = self._take_preempts(live)
        if not hit:
            return []
        now = time.perf_counter()
        events = []
        for lv in lives:
            if lv.finish_reason is None and lv.req.uid in hit:
                self._finish(lv, "preempted", now, done)
                events.append(TokenEvent(lv.req.uid, -1, -1, step,
                                         "preempted", None))
        return events

    # ---------------------------------------------- fault containment

    def _fail_request(self, r: Request, exc: BaseException, done,
                      step: int = 0, t_start: float = 0.0
                      ) -> TokenEvent:
        """Contain a per-request failure: record an error output for
        THIS request (``finish_reason="error"``, ``error`` carries the
        cause) and return the sentinel error event (token -1, index
        -1).  The rest of the batch is untouched."""
        now = time.perf_counter()
        done[r.uid] = RequestOutput(
            r.uid, np.zeros((0,), np.int32), 0.0,
            (now - t_start) if t_start else 0.0, "error",
            error=f"{type(exc).__name__}: {exc}",
            t_enqueue=r.t_enqueue or 0.0, t_finish=now, slo=r.slo)
        return TokenEvent(r.uid, -1, -1, step, "error", None)

    def _admit_filter(self, pairs, done
                      ) -> Tuple[list, List[TokenEvent]]:
        """Static-batching admission gate: apply the fault policy's
        per-request admission hook BEFORE the batch is assembled, so a
        hard-failed request is excluded (error output + sentinel event)
        and the survivors run as a smaller batch.  The sampling-stream
        invariant (token t of uid is fold_in(request_key, t)) makes the
        survivors token-identical to the full-batch run."""
        if self.faults is None:
            return list(pairs), []
        ok, evs = [], []
        for r, sp in pairs:
            try:
                self.faults.on_admit(r.uid)
            except RequestFaultError as e:
                evs.append(self._fail_request(r, e, done))
            else:
                ok.append((r, sp))
        return ok, evs

    # ----------------------------------------------- chunked prefill

    @property
    def _chunked(self) -> bool:
        return self.config.prefill_chunk is not None

    def _chunk_for(self, n: int, batch: int = 1) -> int:
        """Resolve the configured chunk width for an n-token prompt —
        a fixed int, or the scheduler's chunk_split decision (the
        profiler-backed compute-vs-write-back balance, solved for the
        batch that will actually prefill) on "auto"."""
        pc = self.config.prefill_chunk
        if pc == "auto":
            return max(1, self.scheduler.chunk_split(
                self.cfg, n, batch=batch,
                compress=self.config.compress,
                shards=self.config.shards).chunk)
        return int(pc)

    def _chunked_resident_prefill(self, prompts: np.ndarray, lens,
                                  ragged: bool, max_len: int):
        """Static-resident chunked prefill: drive Model.prefill_chunk
        over the padded batch, returning (last logits, decode cache) —
        bit-identical to the monolithic ``self._prefill`` call."""
        b, s = prompts.shape
        cache = self.model.init_cache(b, max_len, jnp.float32)
        if ragged:
            cache["pad"] = jnp.asarray(s - lens, jnp.int32)
        chunk = self._chunk_for(s, batch=b)
        logits, pos = None, 0
        while pos < s:
            w = chunk_width(chunk, s - pos, q_block=self.model.q_block)
            logits, cache = self._prefill_chunk(
                self.params, cache, jnp.asarray(prompts[:, pos:pos + w]),
                p0=pos)
            pos += w
        return logits, cache

    # --------------------------------------- prefix-cache admission

    def _prefill_request(self, prompt: np.ndarray,
                         uid: Optional[int] = None):
        """Per-request prefill with shared-prefix restore.

        Looks up the longest cached prefix of ``prompt``; on a hit the
        scheduler's restore split decides how many of the matched
        tokens the device recomputes from cached activations vs
        streams as KV over the link (``restore_prefix_kv``), and only
        the suffix goes through prefill — attending over
        [restored prefix | causal suffix] from position p.

        Degradation ladder: a FAILED restore (after the transfer
        layer's retries) falls back to cold prefill of the whole
        prompt, with the poisoned trie entry evicted so later lookups
        stop rediscovering the bad blocks — the request survives,
        token-identical to a cache-cold run.  Only a
        ``TransferStallError`` escalates (the pipeline is stalled;
        prefilling through it would hang too).

        Returns (last_logits (1,1,V), ks, vs, hs host blocks covering
        the WHOLE prompt, RestoreStats or None).
        """
        prompt = np.asarray(prompt, np.int32)
        restore = None
        p, entry = (self.prefix_cache.lookup(prompt)
                    if self.prefix_cache is not None else (0, None))
        if entry is not None and p > 0:
            try:
                if self.faults is not None:
                    # engine-level injection point: fires regardless of
                    # the restore split (a pure-recompute restore has
                    # no link op for the transfer-layer hook to see)
                    self.faults.on_op("restore", uid=uid)
                split = self.scheduler.restore_split(
                    self.cfg, p,
                    mode="kvpr" if self.config.kvpr else "flexgen",
                    align=self.config.align,
                    shards=self.config.shards)
                k_pre, v_pre, restore = restore_prefix_kv(
                    self.cfg, self.params, entry.ks, entry.vs,
                    entry.hs, p, split.l, self._restore_xfer, uid=uid)
                logits, ks_s, vs_s, hs_s = prefill_with_activations(
                    self.model, self.params,
                    jnp.asarray(prompt[p:])[None],
                    prefix=(k_pre, v_pre, p))
                ks = np.concatenate([entry.ks[:, :, :p],
                                     np.asarray(ks_s)], axis=2)
                vs = np.concatenate([entry.vs[:, :, :p],
                                     np.asarray(vs_s)], axis=2)
                hs = np.concatenate([entry.hs[:, :, :p],
                                     np.asarray(hs_s)], axis=2)
                return logits, ks, vs, hs, restore
            except TransferStallError:
                raise
            except Exception as e:
                warnings.warn(
                    f"prefix restore failed ({type(e).__name__}: {e}); "
                    "evicting the cached entry and falling back to "
                    "cold prefill")
                self.prefix_cache.invalidate(entry.tokens)
                restore = None
        logits, ks, vs, hs = prefill_with_activations(
            self.model, self.params, jnp.asarray(prompt)[None])
        ks, vs, hs = (np.asarray(ks), np.asarray(vs), np.asarray(hs))
        return logits, ks, vs, hs, restore

    # ------------------------------------------------ static resident

    def _stream_static_resident(self, pairs, done, extra
                                ) -> Iterator[TokenEvent]:
        reqs = [r for r, _ in pairs]
        prompts = pad_batch(reqs)
        b, s = prompts.shape
        lens = np.array([len(r.prompt) for r in reqs], np.int64)
        ragged = bool((lens != s).any())
        gen_len = max(sp.max_tokens for _, sp in pairs)
        max_len = s + gen_len + 1
        if self.cfg.arch_type == "vlm" and extra:
            max_len += extra["patches"].shape[1]
        t0 = time.perf_counter()
        blocks = restores = None
        if self.prefix_cache is not None:
            if extra:
                raise ValueError("extra (VLM patches) is not supported "
                                 "with prefix_cache")
            logits, cache, blocks, restores = \
                self._prefix_resident_batch(reqs, s, lens, max_len)
        elif self._chunked:
            if extra:
                raise ValueError("extra (VLM patches) is not supported "
                                 "with prefill_chunk")
            logits, cache = self._chunked_resident_prefill(
                prompts, lens, ragged, max_len)
        else:
            pl = jnp.asarray(lens, jnp.int32) if ragged else None
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(prompts),
                                          extra, max_len=max_len,
                                          prompt_lens=pl)
        logits.block_until_ready()
        t1 = time.perf_counter()

        lives = self._lives(pairs, t1 - t0, t1)
        if blocks is not None:
            for lv, bl, rs in zip(lives, blocks, restores):
                lv.blocks, lv.restore = bl, rs
        ss = self._static_sampling(pairs)
        offs = np.array([r.token_offset for r, _ in pairs])
        tok = ss.sample(logits[:, -1], offs)[:, None]
        t = 0
        while True:
            yield from self._advance(lives, np.asarray(tok)[:, 0], t,
                                     None, done)
            yield from self._preempt_sweep(lives, t, done)
            if all(lv.finish_reason for lv in lives):
                break
            logits, cache = self._decode(self.params, cache, tok)
            t += 1
            tok = ss.sample(logits[:, -1], offs + t)[:, None]

    def _static_sampling(self, pairs) -> _SlotSampling:
        ss = _SlotSampling(self.key, len(pairs))
        for i, (r, sp) in enumerate(pairs):
            ss.set_slot(i, r.uid, sp)
        return ss

    def _prefix_resident_batch(self, reqs, s: int, lens, max_len: int):
        """Admit a static batch per-request through the prefix cache
        and assemble the LEFT-padded resident cache: request i's
        restored + prefilled KV occupies cache slots [s - len_i, s)
        with position-native RoPE, the padded slots masked via
        ``cache["pad"]`` — the ragged-prefill convention, which is what
        lets per-request restores splice into one static batch."""
        cfg = self.cfg
        Lh, KV, dh = cfg.num_layers, cfg.num_kv_heads, cfg.dh
        b = len(reqs)
        k_all = np.zeros((Lh, b, max_len, KV, dh), np.float32)
        v_all = np.zeros_like(k_all)
        rows, blocks, restores = [], [], []
        for i, r in enumerate(reqs):
            lg, ks, vs, hs, restore = self._prefill_request(r.prompt,
                                                            uid=r.uid)
            pad = s - len(r.prompt)
            k_all[:, i, pad:s] = ks[:, 0]
            v_all[:, i, pad:s] = vs[:, 0]
            rows.append(lg)
            blocks.append((ks, vs, hs) if self._keep_blocks else None)
            restores.append(restore)
        cache = {"k": jnp.asarray(k_all), "v": jnp.asarray(v_all),
                 "pos": jnp.asarray(s, jnp.int32),
                 "pad": jnp.asarray(s - lens, jnp.int32)}
        return jnp.concatenate(rows, axis=0), cache, blocks, restores

    def _resident_cache_from_blocks(self, ks, vs, n: int, max_len: int):
        """Build a b=1 resident decode cache from host KV blocks
        (continuous admission of a prefix-cache hit): same structure as
        ``model.prefill``'s cache, KV at slots [0, n)."""
        cfg = self.cfg
        Lh, KV, dh = cfg.num_layers, cfg.num_kv_heads, cfg.dh
        k1 = np.zeros((Lh, 1, max_len, KV, dh), np.float32)
        v1 = np.zeros_like(k1)
        k1[:, :, :n] = ks
        v1[:, :, :n] = vs
        return {"k": jnp.asarray(k1), "v": jnp.asarray(v1),
                "pos": jnp.asarray(n, jnp.int32),
                "pad": jnp.zeros((1,), jnp.int32)}

    # ------------------------------------------------- static offload

    def _make_store(self, batch: int, max_len: int) -> HostKVStore:
        """The offload paths' host store: single-tier by default, the
        tiered hierarchy (host DRAM over the mmap disk rung) when
        ``EngineConfig.kv_tiers`` is set.  The caller owns the result
        and must ``close()`` it (a no-op on the single-tier store)."""
        kt = self.config.kv_tiers
        if kt is None:
            return HostKVStore(
                self.cfg, batch, max_len, compress=self.config.compress,
                fence_timeout_s=self.config.fence_timeout_s)
        return TieredKVStore(
            self.cfg, batch, max_len, tiers=kt,
            compress=self.config.compress,
            fence_timeout_s=self.config.fence_timeout_s,
            faults=self.config.faults)

    def _stream_static_offload(self, pairs, done
                               ) -> Iterator[TokenEvent]:
        """Prefill on-device, spill KV + activations to host, decode
        with the KVPR runtime under the scheduler's plan.  Finished
        slots drop out of the ``active`` mask, so an early-EOS request
        stops paying write-back immediately."""
        reqs = [r for r, _ in pairs]
        prompts = pad_batch(reqs)
        b, s = prompts.shape
        lens = np.array([len(r.prompt) for r in reqs], np.int64)
        ragged = bool((lens != s).any())
        gen_len = max(sp.max_tokens for _, sp in pairs)
        store = self._make_store(b, s + gen_len + 1)
        rt = self.runtime
        try:
            t0 = time.perf_counter()
            blocks = restores = None
            if self.prefix_cache is not None:
                rows, blocks, restores = [], [], []
                for i, r in enumerate(reqs):
                    lg, ks, vs, hs, restore = self._prefill_request(
                        r.prompt, uid=r.uid)
                    rt.xfer.run_io("store", store.fill_slot, i, ks, vs,
                                   hs, len(r.prompt), uid=r.uid)
                    rows.append(lg)
                    blocks.append((ks, vs, hs) if self._keep_blocks
                                  else None)
                    restores.append(restore)
                logits = jnp.concatenate(rows, axis=0)
            elif self._chunked:
                # streamed prefill: each finished chunk's KV/activation
                # write-back overlaps the next chunk's compute (the
                # TransferEngine store pool + HostKVStore chunk fences)
                cp = ChunkedPrefill(self.model, self.params,
                                    jnp.asarray(prompts),
                                    self._chunk_for(s, batch=b),
                                    prompt_lens=lens,
                                    store=store, xfer=rt.xfer)
                logits = cp.finish()
                store.seq_lens[:] = lens
            else:
                pl = jnp.asarray(lens, jnp.int32) if ragged else None
                logits, ks, vs, hs = prefill_with_activations(
                    self.model, self.params, jnp.asarray(prompts),
                    prompt_lens=pl)
                rt.xfer.run_io(
                    "store", store.bulk_fill, np.asarray(ks),
                    np.asarray(vs), np.asarray(hs), s,
                    seq_lens=lens if ragged else None)
            t1 = time.perf_counter()

            lives = self._lives(pairs, t1 - t0, t1)
            if blocks is not None:
                for lv, bl, rs in zip(lives, blocks, restores):
                    lv.blocks, lv.restore = bl, rs
            ss = self._static_sampling(pairs)
            offs = np.array([r.token_offset for r, _ in pairs])
            plan = rt.plan_for(b, store)
            tok = ss.sample(logits[:, -1], offs)[:, None]
            t = 0
            stats: Optional[StepStats] = None
            while True:
                yield from self._advance(lives, np.asarray(tok)[:, 0],
                                         t, stats, done)
                yield from self._preempt_sweep(lives, t, done)
                if all(lv.finish_reason for lv in lives):
                    break
                active = np.array([lv.finish_reason is None
                                   for lv in lives])
                logits, stats = rt.step(store, tok, plan, active=active)
                t += 1
                tok = ss.sample(logits[:, -1], offs + t)[:, None]
        except BaseException:
            # the exception path (an engine-level fault, or the
            # consumer abandoning the stream mid-iteration): drain
            # EVERY fence without letting a second failure mask the
            # first, so no in-flight future survives to wedge the
            # engine's next call
            store.sync(strict=False)
            store.close()
            raise
        else:
            # drain the write-back fences before dropping the store
            # (surfaces any store error, leaves the pool idle)
            store.sync()
            store.close()

    # ----------------------------------------------------- continuous

    def _stream_continuous(self, pairs, done) -> Iterator[TokenEvent]:
        """Iteration-level batching over either backend: one slot per
        request in flight, admission between steps — including into
        slots freed mid-decode by early-EOS finishes.

        With ``prefill_chunk`` set, admission is CHUNKED: a queued
        prompt becomes a pending prefill that advances chunk by chunk
        between decode steps instead of prefilling inline, and
        ``max_step_tokens`` budgets each step — active decodes (one
        token per slot) are served first, pending prefills consume the
        remainder — so a long prompt admits over several steps without
        ever stalling in-flight decodes for its whole prefill."""
        B = self.config.slots
        max_len = self.config.max_len
        queue: Deque[Tuple[Request, SamplingParams]] = deque(pairs)
        slots: List[Optional[_Live]] = [None] * B
        pending: Dict[int, _Pending] = {}
        ss = _SlotSampling(self.key, B)
        tokens = np.zeros((B, 1), np.int32)
        offload = self.config.backend == "offload"
        chunked = self._chunked
        budget_cap = self.config.max_step_tokens
        if offload:
            store = self._make_store(B, max_len)
            plan = self.runtime.plan_for(B, store)
            active = np.zeros(B, bool)
        else:
            stacked = None
        t = 0

        def release(i: int) -> None:
            slots[i] = None
            if offload:
                active[i] = False
                store.clear_slot(i)

        def finish(i: int, lv: _Live, reason: str, now: float) -> None:
            self._finish(lv, reason, now, done)
            release(i)

        def activate(i, r, sp, logits, t0, cache=None, restore=None,
                     blocks=None) -> TokenEvent:
            """Admit a finished prefill into slot i: sample its first
            token and make the slot live (decode joins next step)."""
            nonlocal stacked
            ss.set_slot(i, r.uid, sp)
            first = ss.sample_one(logits[:, -1], i, r.token_offset)
            t1 = time.perf_counter()
            lv = _Live(r, sp, sp.stop_set, [first], t1 - t0, t1,
                       t_enqueue=r.t_enqueue or t0, t_admit=t0,
                       t_first=t1, restore=restore, blocks=blocks)
            slots[i] = lv
            tokens[i, 0] = first
            if offload:
                active[i] = True
            else:
                stacked = (broadcast_slots(cache, B) if stacked is None
                           else splice_slot(stacked, cache, i))
            fin = ("stop" if first in lv.stop else
                   "length" if 1 >= sp.max_tokens else None)
            if fin is not None:
                finish(i, lv, fin, t1)
            return TokenEvent(r.uid, first, 0, t, fin, None)

        def fail_slot(i: int, r: Request, exc: BaseException,
                      t0: float) -> TokenEvent:
            """Contain a per-request admission/prefill fault: reclaim
            slot i (quiet-draining ITS chunk fences so no failed future
            survives to poison the next tenant), record the error
            output, and return the sentinel error event.  Every other
            slot keeps decoding untouched."""
            pending.pop(i, None)
            if offload:
                try:
                    store.wait_chunks(i)
                except Exception:
                    pass             # the slot is being discarded
                store.clear_slot(i)
                active[i] = False
            slots[i] = None
            return self._fail_request(r, exc, done, step=t, t_start=t0)

        def admit(i: int) -> TokenEvent:
            """Inline (whole-prompt) admission into slot i.  A
            per-request fault here is contained to this request
            (``fail_slot``); only a stalled store pipeline escalates —
            nothing else could admit through it either."""
            r, sp = queue.popleft()
            t0 = time.perf_counter()
            blocks = restore = cache = None
            try:
                if self.faults is not None:
                    self.faults.on_admit(r.uid)
                if self.prefix_cache is not None:
                    logits, ks, vs, hs, restore = \
                        self._prefill_request(r.prompt, uid=r.uid)
                    blocks = (ks, vs, hs) if self._keep_blocks else None
                    if offload:
                        self.runtime.xfer.run_io(
                            "store", store.fill_slot, i, ks, vs, hs,
                            len(r.prompt), uid=r.uid)
                    else:
                        cache = self._resident_cache_from_blocks(
                            ks, vs, len(r.prompt), max_len)
                elif offload:
                    logits, ks, vs, hs = prefill_with_activations(
                        self.model, self.params,
                        jnp.asarray(r.prompt)[None])
                    self.runtime.xfer.run_io(
                        "store", store.fill_slot, i, np.asarray(ks),
                        np.asarray(vs), np.asarray(hs), len(r.prompt),
                        uid=r.uid)
                else:
                    logits, cache = self._prefill(
                        self.params, jnp.asarray(r.prompt)[None],
                        max_len=max_len)
            except TransferStallError:
                raise
            except Exception as e:
                return fail_slot(i, r, e, t0)
            return activate(i, r, sp, logits, t0, cache=cache,
                            restore=restore, blocks=blocks)

        def start_pending(i: int) -> Optional[TokenEvent]:
            """Chunked admission: claim slot i for a pending prefill
            that advances under the per-step token budget.  Returns an
            error event when the request hard-fails at admission."""
            r, sp = queue.popleft()
            t0 = time.perf_counter()
            try:
                if self.faults is not None:
                    self.faults.on_admit(r.uid)
                chunk = self._chunk_for(len(r.prompt))
            except TransferStallError:
                raise
            except Exception as e:
                return fail_slot(i, r, e, t0)
            if offload:
                state = ChunkedPrefill(
                    self.model, self.params, np.asarray(r.prompt)[None],
                    chunk, store=store, xfer=self.runtime.xfer, slot=i,
                    uid=r.uid)
            else:
                cache = self.model.init_cache(1, max_len, jnp.float32)
                state = _ResidentChunk(cache, np.asarray(r.prompt),
                                       chunk,
                                       q_block=self.model.q_block)
            pending[i] = _Pending(r, sp, state, t0)
            return None

        def pending_step(pd: _Pending) -> int:
            """Run the pending prefill's next FULL chunk (grid width:
            the configured chunk or the final partial one — never a
            budget-truncated sliver, so the XLA trace set stays
            O(n / chunk) per prompt length)."""
            st = pd.state
            if isinstance(st, ChunkedPrefill):
                return st.step()
            w = st.next_width
            st.logits, st.cache = self._prefill_chunk(
                self.params, st.cache,
                jnp.asarray(st.prompt[st.pos:st.pos + w])[None],
                p0=st.pos)
            st.pos += w
            return w

        def advance_pending(i: int, grant: Optional[int]
                            ) -> Tuple[int, Optional[TokenEvent]]:
            """Bank ``grant`` budget tokens with slot i's pending
            prefill, run whole chunks while the credit covers them,
            and on completion activate the slot (returning its
            first-token event)."""
            pd = pending[i]
            used = 0
            if grant is None:
                # no explicit budget: still interleave — ONE chunk per
                # engine step (an idle engine loops straight back here,
                # so a lone prompt completes without artificial delay;
                # with decodes in flight the stall is one chunk)
                used += pending_step(pd)
            else:
                pd.credit = min(pd.credit + grant, pd.state.remaining)
                while (not pd.state.done
                       and pd.credit >= pd.state.next_width):
                    n = pending_step(pd)
                    pd.credit -= n
                    used += n
            if not pd.state.done:
                return used, None
            del pending[i]
            st = pd.state
            if offload:
                # the only un-overlapped write-back: the last chunk's
                # (waits THIS slot's fences only — a concurrent
                # admission's in-flight chunks are not ours to drain)
                store.wait_chunks(i)
                store.seq_lens[i] = len(pd.req.prompt)
                return used, activate(i, pd.req, pd.sp, st.logits,
                                      pd.t_start)
            return used, activate(i, pd.req, pd.sp, st.logits,
                                  pd.t_start, cache=st.cache)

        try:
            while queue or pending or any(s is not None for s in slots):
                for i in range(B):
                    if slots[i] is None and i not in pending and queue:
                        if chunked:
                            ev = start_pending(i)
                            if ev is not None:
                                yield ev
                        else:
                            yield admit(i)
                if pending:
                    # decode has priority: each active slot advances one
                    # token per step, pending prefills get the remaining
                    # budget (a step with no actives always moves >= 1
                    # token, so admission cannot starve).  The whole
                    # remainder is banked with the OLDEST pending
                    # (dict order = admission order), so prompts admit
                    # FIFO and credits are never double-granted.
                    n_active = sum(s is not None for s in slots)
                    if budget_cap is None:
                        budget = None
                    else:
                        budget = max(budget_cap - n_active,
                                     1 if n_active == 0 else 0)
                    for i in list(pending):
                        # a fault in THIS slot's chunk pipeline (the
                        # uid-tagged write-backs surface at its
                        # wait_chunks) is contained to this request;
                        # only a stalled store pipeline escalates
                        pd = pending[i]
                        try:
                            used, ev = advance_pending(i, budget)
                        except TransferStallError:
                            raise
                        except Exception as e:
                            ev = fail_slot(i, pd.req, e, pd.t_start)
                            used = 0
                        if budget is not None:
                            budget = 0
                        if ev is not None:
                            yield ev
                live_uids = {s.req.uid: i for i, s in enumerate(slots)
                             if s is not None}
                hit = self._take_preempts(live_uids)
                if hit:
                    # cooperative preemption: finish the flagged
                    # requests NOW (keeping their tokens), release
                    # their slots — the next loop iteration admits
                    # queued work into the freed capacity
                    now = time.perf_counter()
                    for uid in sorted(hit):
                        i = live_uids[uid]
                        finish(i, slots[i], "preempted", now)
                        yield TokenEvent(uid, -1, -1, t, "preempted",
                                         None)
                if not any(s is not None for s in slots):
                    continue
                steps = np.array([len(s.tokens) + s.req.token_offset
                                  if s is not None else 0
                                  for s in slots])
                if offload:
                    logits, st = self.runtime.step(
                        store, jnp.asarray(tokens), plan,
                        active=active.copy())
                    nxt = np.asarray(ss.sample(logits[:, -1], steps))
                else:
                    # per-slot token shape is (1, 1): add the slot axis
                    # up front
                    logits, stacked = self._vstep(
                        self.params, stacked,
                        jnp.asarray(tokens)[:, None])
                    nxt = np.asarray(ss.sample(logits[:, 0, -1], steps))
                    st = None
                t += 1
                now = time.perf_counter()
                for i in range(B):
                    lv = slots[i]
                    if lv is None:
                        continue
                    tok = int(nxt[i])
                    lv.tokens.append(tok)
                    tokens[i, 0] = tok
                    fin = ("stop" if tok in lv.stop else
                           "length" if len(lv.tokens) >= lv.sp.max_tokens
                           else None)
                    yield TokenEvent(lv.req.uid, tok, len(lv.tokens) - 1,
                                     t, fin, st)
                    if fin is not None:
                        finish(i, lv, fin, now)
        except BaseException:
            # engine-level fault, or the consumer abandoning the stream
            # mid-iteration: drain every fence without a second failure
            # masking the first, so the engine stays reusable
            if offload:
                store.sync(strict=False)
                store.close()
            raise
        else:
            # drain write-back fences before dropping the store
            # (surfaces any store error, leaves the pool idle)
            if offload:
                store.sync()
                store.close()
