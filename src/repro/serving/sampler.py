"""Token samplers for the serving stack.

Two layers:

  - the legacy scalar-config samplers (``greedy`` / ``temperature``)
    kept for direct use and back-compat;
  - the vectorized request-level path used by ``serving.api``: one
    jitted ``sample_step`` draws every batch slot's next token in a
    single call, with *per-slot* temperature / top-k / greediness and
    *per-slot* PRNG keys, so one batch can mix greedy and temperature
    requests (paper-style static batches and continuous slots alike).

PRNG convention (the request-level sampling stream): every request owns
a base key — ``request_key(engine_key, uid, seed)`` — and its t-th
token is always drawn with ``fold_in(base, t)``.  The draw therefore
depends only on (request identity, token index), never on which engine,
backend, or batch composition executed it: resident and offload decode
are sampling-stream identical by construction, and a request admitted
mid-decode draws the same tokens it would draw served alone.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array, key=None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 0.8,
                top_k: int = 0) -> jax.Array:
    logits = logits.astype(jnp.float32) / max(temp, 1e-6)
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


# --------------------------------------------------- request-level path

def request_key(engine_key: jax.Array, uid: int,
                seed: Optional[int] = None) -> jax.Array:
    """A request's base PRNG key: its own seed when it carries one,
    otherwise derived from the engine key by uid."""
    if seed is not None:
        return jax.random.PRNGKey(seed)
    return jax.random.fold_in(engine_key, uid)


@jax.jit
def sample_step(logits: jax.Array, req_keys: jax.Array, steps: jax.Array,
                temps: jax.Array, top_ks: jax.Array,
                greedy_mask: jax.Array) -> jax.Array:
    """Draw one token per batch slot, each slot under its own sampling
    params and PRNG stream.

    logits      (b, V)   last-position logits
    req_keys    (b, 2)   per-slot request base keys (stacked raw keys)
    steps       (b,)     per-slot token index t (fold_in counter)
    temps       (b,)     per-slot temperature (ignored where greedy)
    top_ks      (b,)     per-slot top-k (0 = no truncation)
    greedy_mask (b,)     True -> argmax, ignoring the stochastic draw
    """
    V = logits.shape[-1]
    arg = jnp.argmax(logits, axis=-1)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    # per-row top-k with per-row k: kth largest via a sorted row
    srt = jnp.sort(scaled, axis=-1)                     # ascending
    kth_idx = jnp.clip(V - top_ks, 0, V - 1)
    kth = jnp.take_along_axis(srt, kth_idx[:, None], axis=-1)
    truncated = jnp.where(scaled < kth, -1e30, scaled)
    scaled = jnp.where((top_ks > 0)[:, None], truncated, scaled)
    keys = jax.vmap(jax.random.fold_in)(req_keys, steps)
    drawn = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(greedy_mask, arg, drawn).astype(jnp.int32)
