"""Unit tests for launch/shardings: strategy knob, cache seq-axis
sharding, and divisibility fallbacks (run on a tiny virtual mesh via
subprocess-free spec construction — specs don't touch devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.launch import shardings as SH


class FakeMesh:
    """Duck-typed mesh: param_spec/cache_shardings only read axis_names
    and shape — but NamedSharding needs a real mesh, so we test the spec
    helpers directly."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def teardown_function(_fn):
    SH.set_strategy()    # restore defaults after every test


def test_default_strategy_attn_specs():
    cfg = get_config("mistral-nemo-12b")
    # wq: (L, h, H, dh) -> FSDP on h over data, heads over model
    spec = SH.param_spec("layers/attn/wq", (40, 5120, 32, 128), cfg, MESH)
    assert spec == P(None, ("data",), "model", None)
    # kv heads (8) don't divide model=16 -> replicated on that dim
    spec = SH.param_spec("layers/attn/wk", (40, 5120, 8, 128), cfg, MESH)
    assert spec == P(None, ("data",), None, None)


def test_no_tp_strategy_removes_model_axis():
    cfg = get_config("mistral-nemo-12b")
    SH.set_strategy(tp=None, fsdp=("data", "model"), dp=("data", "model"))
    spec = SH.param_spec("layers/attn/wq", (40, 5120, 32, 128), cfg, MESH)
    assert spec == P(None, ("data", "model"), None, None)
    spec = SH.param_spec("layers/mlp/w1", (40, 5120, 14336), cfg, MESH)
    assert spec == P(None, ("data", "model"), None)
    assert SH._dp_axes(MESH) == ("data", "model")


def test_strategy_restored():
    assert SH.get_strategy()["tp"] == "model"
    assert SH._dp_axes(MESH) == ("data",)


@pytest.mark.parametrize("batch,seq_axis,expect_s,expect_b", [
    (128, "model", "model", ("data",)),   # decode_32k style: both shard
    (128, "data", None, ("data",)),       # conflict -> seq stays unsharded
    (1, "data", "data", None),            # long_500k style: seq over data
])
def test_cache_seq_axis(batch, seq_axis, expect_s, expect_b):
    cfg = get_smoke_config("mistral-nemo-12b")
    # build shapes only; cache leaf (L, b, S, KV, dh)
    leaf = jax.ShapeDtypeStruct((2, batch, 4096, 8, 64), jnp.bfloat16)

    # exercise the spec logic by reproducing cache_shardings' branch
    # through a real mesh of host devices is overkill here; call the
    # internal helpers the same way it does.
    dp = SH._dp_axes(MESH)
    dp_n = SH._dp_size(MESH)
    bspec = dp if batch % dp_n == 0 and batch > 1 else None
    sspec = None
    if SH._fits(leaf.shape[2], MESH, seq_axis):
        conflict = bspec is not None and seq_axis in (
            bspec if isinstance(bspec, tuple) else (bspec,))
        if not conflict:
            sspec = seq_axis
    assert sspec == expect_s
    assert bspec == (expect_b if expect_b is None else tuple(expect_b))


def test_granite_experts_fall_back_to_tensor_parallel():
    """granite has 40 experts; 40 % 16 != 0 -> expert dim replicated,
    d_ff sharded instead (config sets sharding='tensor')."""
    cfg = get_config("granite-moe-3b-a800m")
    spec = SH.param_spec("layers/moe/w1", (32, 40, 1536, 512), cfg, MESH)
    assert spec[-3] is None or cfg.moe.sharding != "expert"
