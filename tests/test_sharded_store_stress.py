"""Concurrency stress for the mesh-sharded KV data plane.

A k-way model-axis mesh never splits the host store — it splits the
COPIES: ``TransferEngine.fetch_layer(..., shards=k)`` fans each layer
window into k per-KV-head-slice streams on a dedicated shard pool, and
``HostKVStore.head_slice`` hands out disjoint views of the same host
arrays.  The invariants under threaded interleave are therefore exactly
the unsharded ones, plus two sharded obligations:

  - no torn reads: k concurrent slice streams racing fenced appends,
    prefill chunk write-backs, and (tiered) demotion/page-in churn must
    still reproduce every position-derived value — and the merged
    staging buffer must be byte-identical to an unsharded fetch,
  - zero staging growth: shard streams write slices of the SAME
    parity-keyed buffers, so ``staging_allocs`` stays flat after
    warmup exactly as in the unsharded stress test.

The file also carries the deterministic mirror of the mesh-size-1 plan
exactness property (tests/test_scheduler_props.py needs hypothesis;
this sweep always runs).
"""
import threading
import time

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import HardwareProfile, TierLink
from repro.core.kvstore import KVTiersConfig, TieredKVStore
from repro.core.runtime import HostKVStore, TransferEngine
from repro.core.scheduler import Scheduler

SHARDS = 4
STEPS = 16
CHUNK = 6
CHUNK_TOTAL = 24


def _kv_pattern(pos, KV, dh, base=0.0):
    """(len(pos), KV, dh) values derived from position: torn reads can't
    reproduce them."""
    p = np.asarray(pos, np.float32)[:, None, None]
    return np.broadcast_to(base + p + 0.5, (len(pos), KV, dh)).copy()


# ------------------------------------------------------ head_slice views

def test_head_slices_are_disjoint_zero_copy_views():
    """Shard slices must alias the host planes (zero-copy), cover every
    KV head exactly once, and reject geometries that don't divide."""
    cfg = get_smoke_config("opt-6.7b")
    store = HostKVStore(cfg, 2, 16)
    KV = cfg.num_kv_heads
    seen = np.zeros(KV, np.int64)
    for si in range(SHARDS):
        sl = store.head_slice(SHARDS, si)
        assert set(sl) == {"k", "v"}
        for name in ("k", "v"):
            assert sl[name].base is getattr(store, name), \
                "head_slice must view, not copy"
            assert sl[name].shape[3] == KV // SHARDS
        # a write through the view lands in the store plane
        sl["k"][0, 0, 0, 0, 0] = 7.0
        lo = si * (KV // SHARDS)
        assert store.k[0, 0, 0, lo, 0] == 7.0
        seen[lo:lo + KV // SHARDS] += 1
    assert (seen == 1).all(), "slices must partition the KV-head axis"
    with pytest.raises(ValueError):
        store.head_slice(3, 0)            # 3 does not divide 8 heads
    with pytest.raises(ValueError):
        store.head_slice(SHARDS, SHARDS)  # shard index out of range


# -------------------------------------- sharded fetch/append interleave

@pytest.mark.slow
def test_sharded_fetch_append_chunk_interleave_untorn():
    """The unsharded stress flow (decode fetches racing fenced appends
    racing prefill chunk write-backs) with every fetch fanned out over
    4 shard streams.  Asserts untorn values, byte-identity of the
    sharded fetch against an unsharded reference fetch, per-shard link
    byte accounting (each stream carries exactly 1/4 of the streamed KV
    bytes), and zero staging allocations after warmup."""
    cfg = get_smoke_config("opt-6.7b").replace(num_layers=4)
    Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                     cfg.d_model)
    max_len = 8 + STEPS + CHUNK_TOTAL
    store = HostKVStore(cfg, 2, max_len)
    xfer = TransferEngine(n_copy_threads=2)
    errors = []

    s0 = 8
    pos0 = np.arange(s0)
    for li in range(Lh):
        store.k[li, 0, :s0] = _kv_pattern(pos0, KV, dh)
        store.v[li, 0, :s0] = _kv_pattern(pos0, KV, dh, base=1000.0)
    store.act[:, 0, :s0] = np.arange(s0, dtype=np.float32)[:, None]
    store.seq_lens[0] = s0

    def chunk_writer():
        try:
            for start in range(0, CHUNK_TOTAL, CHUNK):
                pos = np.arange(start, start + CHUNK)
                ks = np.broadcast_to(
                    _kv_pattern(pos, KV, dh, base=5e4)[None, None],
                    (Lh, 1, CHUNK, KV, dh)).copy()
                vs = np.broadcast_to(
                    _kv_pattern(pos, KV, dh, base=6e4)[None, None],
                    (Lh, 1, CHUNK, KV, dh)).copy()
                acts = np.broadcast_to(
                    pos.astype(np.float32)[None, None, :, None],
                    (Lh, 1, CHUNK, h)).copy()
                store.push_chunk_fence(xfer.submit_store(
                    store.fill_chunk_slot, 1, ks, vs, acts, start))
                time.sleep(0.001)
        except Exception as e:           # pragma: no cover
            errors.append(e)

    writer = threading.Thread(target=chunk_writer)
    writer.start()

    ls = np.zeros(2, np.int64)
    s_pad = max_len
    allocs_after_warmup = None
    xfer.drain_shard_bytes()
    for step in range(STEPS):
        seq = store.seq_lens.copy()
        s_strs = seq - ls
        for li in range(Lh):
            fut = xfer.submit(xfer.fetch_layer, store, li, ls, s_strs,
                              0, s_pad, "", SHARDS)
            h_res, k_str, v_str, _ = fut.result()
            valid = int(seq[0])
            want_pos = np.arange(valid)
            np.testing.assert_array_equal(
                np.asarray(k_str)[0, :valid],
                _kv_pattern(want_pos, KV, dh),
                err_msg=f"torn sharded K read step={step} layer={li}")
            np.testing.assert_array_equal(
                np.asarray(v_str)[0, :valid],
                _kv_pattern(want_pos, KV, dh, base=1000.0),
                err_msg=f"torn sharded V read step={step} layer={li}")
            new_pos = np.array([seq[0], -1])
            k_new = np.stack([_kv_pattern([seq[0]], KV, dh),
                              np.zeros((1, KV, dh), np.float32)])
            v_new = np.stack([_kv_pattern([seq[0]], KV, dh, 1000.0),
                              np.zeros((1, KV, dh), np.float32)])
            a_new = np.full((2, 1, h), float(seq[0]), np.float32)
            store.set_fence(li, xfer.submit_store(
                store.append, li, k_new, v_new, a_new, new_pos))
        store.seq_lens[0] += 1
        if step == 0:
            allocs_after_warmup = xfer.staging_allocs
    grew = xfer.staging_allocs - allocs_after_warmup

    writer.join()
    store.sync()
    assert not errors, errors
    assert grew == 0, f"staging allocated {grew} buffers after warmup"

    # each of the 4 shard streams carried exactly 1/4 of the streamed KV
    sb = xfer.drain_shard_bytes()
    assert sb is not None and len(sb) == SHARDS
    assert len(set(sb)) == 1 and sb[0] > 0, sb

    # merged sharded fetch == unsharded fetch, byte for byte
    seq = store.seq_lens.copy()
    _, k1, v1, _ = xfer.fetch_layer(store, 0, ls, seq - ls, 0, s_pad)
    _, k4, v4, _ = xfer.fetch_layer(store, 0, ls, seq - ls, 0, s_pad,
                                    "", SHARDS)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k4))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v4))

    # full decode trajectory intact end to end
    final = int(store.seq_lens[0])
    assert final == s0 + STEPS
    for li in range(Lh):
        np.testing.assert_array_equal(
            store.k[li, 0, :final],
            _kv_pattern(np.arange(final), KV, dh))
    xfer.close()


@pytest.mark.slow
def test_sharded_fetch_races_demoter_untorn():
    """Tiered variant: 4-way shard streams race an aggressive demoter
    the whole run; every fetch pages demoted blocks back in (windows
    start at l=0), then slices per shard.  Any demote/page-in/slice
    interleave that tears shows up as a wrong position-derived float."""
    cfg = get_smoke_config("opt-6.7b").replace(num_layers=4)
    Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                     cfg.d_model)
    s0, steps, bt = 24, 12, 8
    max_len = s0 + steps + 4
    store = TieredKVStore(cfg, 2, max_len, tiers=KVTiersConfig(
        host_capacity_tokens=bt * 2, block_tokens=bt))
    xfer = TransferEngine(n_copy_threads=2)

    pos0 = np.arange(s0)
    for li in range(Lh):
        store.k[li, 0, :s0] = _kv_pattern(pos0, KV, dh)
        store.v[li, 0, :s0] = _kv_pattern(pos0, KV, dh, base=1000.0)
    store.act[:, 0, :s0] = np.arange(s0, dtype=np.float32)[:, None]
    store.seq_lens[0] = s0
    store.enforce_capacity()
    assert store.disk_tokens()[0] > 0

    stop = threading.Event()
    errors = []

    def demoter():
        try:
            while not stop.is_set():
                store.sweep()
                time.sleep(0.0005)
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=demoter)
    t.start()
    try:
        ls = np.zeros(2, np.int64)
        for step in range(steps):
            seq = store.seq_lens.copy()
            s_strs = seq - ls
            for li in range(Lh):
                fut = xfer.submit(xfer.fetch_layer, store, li, ls,
                                  s_strs, 0, max_len, "", SHARDS)
                h_res, k_str, v_str, _ = fut.result()
                valid = int(seq[0])
                want = np.arange(valid)
                np.testing.assert_array_equal(
                    np.asarray(k_str)[0, :valid],
                    _kv_pattern(want, KV, dh),
                    err_msg=f"torn K read step={step} layer={li}")
                np.testing.assert_array_equal(
                    np.asarray(v_str)[0, :valid],
                    _kv_pattern(want, KV, dh, base=1000.0),
                    err_msg=f"torn V read step={step} layer={li}")
                new_pos = np.array([seq[0], -1])
                k_new = np.stack([_kv_pattern([seq[0]], KV, dh),
                                  np.zeros((1, KV, dh), np.float32)])
                v_new = np.stack(
                    [_kv_pattern([seq[0]], KV, dh, 1000.0),
                     np.zeros((1, KV, dh), np.float32)])
                a_new = np.full((2, 1, h), float(seq[0]), np.float32)
                store.set_fence(li, xfer.submit_store(
                    store.append, li, k_new, v_new, a_new, new_pos))
            store.seq_lens[0] += 1
        store.sync()
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    stats = store.stats()
    assert stats.demotions > 0 and stats.promotions > 0
    assert stats.demote_failures == 0
    final = int(store.seq_lens[0])
    assert final == s0 + steps
    for li in range(Lh):
        np.testing.assert_array_equal(
            store.k[li, 0, :final],
            _kv_pattern(np.arange(final), KV, dh))
    store.close()
    xfer.close()


# ------------------------------------------- mesh-1 plan exactness

def test_mesh1_plans_equal_unsharded_exactly_sweep():
    """Deterministic mirror of the hypothesis property in
    tests/test_scheduler_props.py (which skips without hypothesis):
    mesh size 1 must reproduce the unsharded solver BIT-EXACTLY for all
    four plan kinds — ``per_shard(1)`` is the identity, so decisions
    compare equal as dataclasses.  Fresh Scheduler per side so
    memoization can't mask a divergence."""
    cfgs = [get_smoke_config("opt-6.7b"),
            get_smoke_config("tinyllama-1.1b")]
    hws = [HardwareProfile("pcie", 32e9, 1e14, 1e12,
                           gemm_efficiency=0.5),
           HardwareProfile("slowlink", 4e9, 3e14, 2e12,
                           dispatch_overhead=1e-4)]
    for cfg in cfgs:
        for hw in hws:
            hw_t = hw.with_tiers(TierLink("disk", hw.link_bandwidth / 4,
                                          hw.link_bandwidth / 8))
            for n in (1, 33, 1024):
                for batch in (1, 4):
                    s1, s0 = Scheduler(hw), Scheduler(hw)
                    assert s1.plan_for(cfg, batch, shards=1) \
                        .split_for(n) == \
                        s0.plan_for(cfg, batch).split_for(n)
                    assert s1.restore_split(cfg, n, shards=1) == \
                        s0.restore_split(cfg, n)
                    assert s1.chunk_split(cfg, n, batch=batch,
                                          shards=1) == \
                        s0.chunk_split(cfg, n, batch=batch)
                    t1 = s1.plan_for(cfg, batch, hw=hw_t,
                                     disk_bytes_per_el=4.0, shards=1) \
                        .tier_split_for(n, n // 2)
                    t0 = s0.plan_for(cfg, batch, hw=hw_t,
                                     disk_bytes_per_el=4.0) \
                        .tier_split_for(n, n // 2)
                    assert t1 == t0
