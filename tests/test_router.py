"""Multi-replica router tier: admission control, placement policies,
preemption bounds, fault isolation, and the scheduling invariants.

The cheap layers (AdmissionQueue, placement scoring, the preemption
victim rule) are tested model-free; the end-to-end properties (fault
isolation, deadline drops, bounded preempt-resume under sustained
high-priority load) run real replicas over the smoke model.  Token
identity of routed outputs lives in test_identity_matrix.py
(test_router_identity_matrix).
"""
import threading
import time
import types

import numpy as np
import pytest

from repro.core.faults import FaultPolicy
from repro.serving import (EngineConfig, PrefixCacheConfig, Request,
                           SamplingParams)
from repro.serving.router import (RouterConfig, RouterEngine,
                                  RouterQueueFull, SLOClass)
from repro.serving.router.admission import (AdmissionQueue,
                                            DEFAULT_SLO_CLASSES,
                                            slo_attained)
from repro.serving.router.engine import (_Replica, _Tracked,
                                         _common_prefix)
from repro.serving.router.placement import PlacementView, make_policy

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep (docs/automation.md)
    HAVE_HYPOTHESIS = False


def _entry(priority=0, seq=0, t_enqueue=0.0, deadline_s=None):
    return types.SimpleNamespace(priority=priority, seq=seq,
                                 t_enqueue=t_enqueue,
                                 deadline_s=deadline_s)


# -------------------------------------------------------- admission queue

def test_admission_pop_priority_then_fifo():
    q = AdmissionQueue()
    for seq, pri in enumerate([0, 2, 1, 2, 0]):
        q.push(_entry(priority=pri, seq=seq))
    ready, expired = q.pop_ready(now=0.0)
    assert not expired
    assert [(e.priority, e.seq) for e in ready] == \
        [(2, 1), (2, 3), (1, 2), (0, 0), (0, 4)]


def test_admission_queue_bounded():
    q = AdmissionQueue(max_queue=2)
    q.push(_entry(seq=0))
    q.push(_entry(seq=1))
    with pytest.raises(RouterQueueFull):
        q.push(_entry(seq=2))


def test_admission_deadline_expired_do_not_consume_limit():
    """Dead requests must never block live ones behind them: expired
    entries come back separately and don't count against the batch."""
    q = AdmissionQueue()
    q.push(_entry(priority=9, seq=0, t_enqueue=0.0, deadline_s=0.5))
    for seq in range(1, 4):
        q.push(_entry(seq=seq, t_enqueue=1.0))
    ready, expired = q.pop_ready(now=2.0, limit=3)
    assert [e.seq for e in expired] == [0]
    assert [e.seq for e in ready] == [1, 2, 3]


# ------------------------------------------------------------- placement

def _view(index, queued=0, running=0, matched=0, pending=0):
    return PlacementView(index, queued, running,
                         peek=lambda p: (matched, None), pending=pending)


def test_prefix_policy_prefers_warm_replica():
    choose = make_policy("prefix")
    prompt = np.arange(16)
    views = [_view(0, queued=1), _view(1, queued=1, matched=12)]
    assert choose(views, prompt) == 1


def test_prefix_policy_diverts_past_load_gap():
    """Affinity holds only up to ~warmth_weight/load_weight queued
    requests; past that, the warm replica is a worse place to wait."""
    choose = make_policy("prefix", warmth_weight=1.0, load_weight=0.5)
    prompt = np.arange(16)
    warm_ok = [_view(0, queued=1, matched=15), _view(1, queued=0)]
    assert choose(warm_ok, prompt) == 0          # gap 1 < 0.94/0.5
    warm_backlogged = [_view(0, queued=3, matched=15), _view(1)]
    assert choose(warm_backlogged, prompt) == 1  # gap 3 > 0.94/0.5


def test_prefix_policy_pending_counts_as_warmth():
    """Speculative warmth (the router's affinity index) substitutes for
    the still-cold cache during an arrival burst."""
    choose = make_policy("prefix")
    prompt = np.arange(16)
    views = [_view(0, queued=1), _view(1, queued=1, pending=12)]
    assert choose(views, prompt) == 1


def test_prefix_policy_cold_tie_breaks_toward_low_load():
    choose = make_policy("prefix")
    prompt = np.arange(8)
    views = [_view(0, queued=2), _view(1, queued=1)]
    assert choose(views, prompt) == 1
    views = [_view(0, queued=1), _view(1, queued=1)]
    assert choose(views, prompt) == 0            # full tie -> low index


def test_round_robin_rotates_per_instance():
    choose = make_policy("round_robin")
    views = [_view(0), _view(1)]
    assert [choose(views, None) for _ in range(4)] == [0, 1, 0, 1]
    # a fresh policy has its own rotation state
    assert make_policy("round_robin")(views, None) == 0


def test_least_loaded_picks_min_load():
    choose = make_policy("least_loaded")
    views = [_view(0, queued=2), _view(1, queued=1, running=2),
             _view(2, running=1)]
    assert choose(views, None) == 2


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown placement policy"):
        make_policy("sticky")


def test_common_prefix():
    a = np.array([1, 2, 3, 4], np.int32)
    assert _common_prefix(a, np.array([1, 2, 3, 4], np.int32)) == 4
    assert _common_prefix(a, np.array([1, 2, 9], np.int32)) == 2
    assert _common_prefix(a, np.array([9], np.int32)) == 0
    assert _common_prefix(a, np.zeros((0,), np.int32)) == 0


# ----------------------------------------------------- config / SLO units

def test_router_config_validation():
    with pytest.raises(ValueError, match="replicas"):
        RouterConfig(replicas=0).validate()
    with pytest.raises(ValueError, match="policy"):
        RouterConfig(policy="sticky").validate()
    with pytest.raises(ValueError, match="max_batch"):
        RouterConfig(max_batch=0).validate()
    with pytest.raises(ValueError, match="affinity_min"):
        RouterConfig(affinity_min=0).validate()
    with pytest.raises(ValueError, match="positive"):
        RouterConfig(slo_classes={
            "bad": SLOClass("bad", ttft_s=0.0, tpot_s=1.0)}).validate()


def test_slo_attained_judges_ttft_and_tpot():
    from repro.serving import RequestOutput
    slo = DEFAULT_SLO_CLASSES["interactive"]
    ok = RequestOutput(0, np.arange(3, dtype=np.int32),
                       t_enqueue=10.0, t_first_token=11.0,
                       t_finish=11.2)
    assert slo_attained(ok, slo)
    late = RequestOutput(0, np.arange(3, dtype=np.int32),
                         t_enqueue=10.0, t_first_token=13.0,
                         t_finish=13.2)
    assert not slo_attained(late, slo)
    slow_decode = RequestOutput(0, np.arange(3, dtype=np.int32),
                                t_enqueue=10.0, t_first_token=11.0,
                                t_finish=12.0)   # tpot 0.5 > 0.25
    assert not slo_attained(slow_decode, slo)
    empty = RequestOutput(0, np.zeros((0,), np.int32))
    assert not slo_attained(empty, slo)


# ------------------------------------------- preemption victim rule (pure)

def _tracked(uid, priority, seq, max_tokens=8, preemptions=0,
             pending=False):
    tr = _Tracked(Request(uid=uid, prompt=np.arange(4, dtype=np.int32),
                          priority=priority),
                  SamplingParams(max_tokens=max_tokens), seq, 0.0,
                  np.arange(4, dtype=np.int32))
    tr.preemptions = preemptions
    tr.preempt_pending = pending
    return tr


def _preempt_harness(max_preemptions=1):
    """Drive the REAL RouterEngine._maybe_preempt_locked victim rule
    against a stub replica (fake engine records preempt calls)."""
    preempted = []
    fake_engine = types.SimpleNamespace(
        preempt=preempted.append, prefix_cache=None)
    rep = _Replica(0, fake_engine, threading.Condition())
    self_stub = types.SimpleNamespace(
        config=RouterConfig(max_preemptions=max_preemptions).validate(),
        _preemptions=0)
    return rep, self_stub, preempted


def test_victim_rule_picks_lowest_priority_longest_remaining():
    rep, stub, preempted = _preempt_harness()
    rep.running = {1: _tracked(1, priority=1, seq=0, max_tokens=4),
                   2: _tracked(2, priority=0, seq=1, max_tokens=4),
                   3: _tracked(3, priority=0, seq=2, max_tokens=32)}
    RouterEngine._maybe_preempt_locked(stub, rep,
                                       _tracked(9, priority=2, seq=9))
    assert preempted == [3]          # lowest priority, most budget left


def test_victim_rule_requires_strictly_higher_priority():
    rep, stub, preempted = _preempt_harness()
    rep.running = {1: _tracked(1, priority=1, seq=0)}
    RouterEngine._maybe_preempt_locked(stub, rep,
                                       _tracked(9, priority=1, seq=9))
    assert preempted == []


def test_victim_rule_honors_max_preemptions():
    """The no-starvation bound: a request already bounced
    max_preemptions times runs to completion no matter what arrives."""
    rep, stub, preempted = _preempt_harness(max_preemptions=1)
    rep.running = {1: _tracked(1, priority=0, seq=0, preemptions=1)}
    RouterEngine._maybe_preempt_locked(stub, rep,
                                       _tracked(9, priority=5, seq=9))
    assert preempted == []


def test_victim_rule_skips_inflight_preempts():
    rep, stub, preempted = _preempt_harness()
    rep.running = {1: _tracked(1, priority=0, seq=0, pending=True)}
    RouterEngine._maybe_preempt_locked(stub, rep,
                                       _tracked(9, priority=5, seq=9))
    assert preempted == []


# ---------------------------------------------------- hypothesis properties

if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.integers(0, 3), min_size=1, max_size=40),
           st.integers(1, 4))
    def test_pop_never_serves_lower_priority_first(prios, limit):
        """Scheduling invariant: at equal arrival, a higher-priority
        request never waits behind a lower one — every pop_ready batch
        is a priority-sorted prefix of what is queued."""
        q = AdmissionQueue()
        entries = [_entry(priority=p, seq=i)
                   for i, p in enumerate(prios)]
        for e in entries:
            q.push(e)
        popped = []
        while len(q):
            ready, _ = q.pop_ready(now=0.0, limit=limit)
            popped.extend(ready)
        assert len(popped) == len(entries)
        for a, b in zip(popped, popped[1:]):
            assert (a.priority, -a.seq) >= (b.priority, -b.seq), \
                (a.priority, a.seq, b.priority, b.seq)

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.integers(0, 5), min_size=1, max_size=30),
           st.integers(0, 3))
    def test_victim_rule_never_exceeds_preemption_bound(arrivals,
                                                        max_p):
        """No starvation under sustained load: drive the real victim
        rule with an arbitrary stream of arrivals against one running
        low-priority request; it is never preempted more than
        max_preemptions times, and only by strictly higher priority."""
        rep, stub, preempted = _preempt_harness(max_preemptions=max_p)
        victim = _tracked(1, priority=1, seq=0, max_tokens=64)
        rep.running = {1: victim}
        for i, pri in enumerate(arrivals):
            RouterEngine._maybe_preempt_locked(
                stub, rep, _tracked(100 + i, priority=pri, seq=1 + i))
            if preempted and preempted[-1] == 1:
                # the engine would bounce it; model the resume
                assert pri > victim.priority
                victim.preemptions += 1
                victim.preempt_pending = False
                preempted.clear()
        assert victim.preemptions <= max_p
        assert stub._preemptions == victim.preemptions


# ------------------------------------------------------------- end to end

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def sched():
    from repro.core.cost_model import A100_PCIE4
    from repro.core.scheduler import Scheduler
    return Scheduler(A100_PCIE4)


def _prompts(cfg, n, length=10, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _wait_running(router, rep_index=0, timeout=30.0):
    t0 = time.perf_counter()
    while router.stats().replicas[rep_index].running == 0:
        if time.perf_counter() - t0 > timeout:
            raise TimeoutError("replica never started serving")
        time.sleep(0.005)


def test_router_fault_isolation(setup, sched):
    """A RequestFaultError contained by one replica finishes ONLY that
    request (finish_reason='error'); everything else on the router —
    including later submissions — keeps serving (PR 7 fault-matrix
    regression, one level up)."""
    cfg, model, params = setup
    ec = EngineConfig(
        faults=FaultPolicy(hard_fail_uids=frozenset({1})))
    prompts = _prompts(cfg, 5)
    with RouterEngine(model, params, ec,
                      RouterConfig(replicas=2, policy="round_robin"),
                      scheduler=sched) as router:
        outs = router.generate(
            [Request(uid=i, prompt=p) for i, p in
             enumerate(prompts[:4])],
            SamplingParams(max_tokens=3))
        # the queue did not stall: a later submission still serves
        late = router.generate([Request(uid=9, prompt=prompts[4])],
                               SamplingParams(max_tokens=3))[0]
        st = router.stats()
    assert outs[1].finish_reason == "error"
    assert "RequestFault" in outs[1].error
    assert len(outs[1].tokens) == 0
    for o in (outs[0], outs[2], outs[3], late):
        assert o.finish_reason == "length" and len(o.tokens) == 3
    assert sum(r.errors for r in st.replicas) == 1
    assert st.finished == 5


def test_router_timing_fields_populated(setup, sched):
    cfg, model, params = setup
    with RouterEngine(model, params, EngineConfig(),
                      RouterConfig(replicas=1, policy="least_loaded"),
                      scheduler=sched) as router:
        outs = router.generate(
            [Request(uid=i, prompt=p, slo="standard")
             for i, p in enumerate(_prompts(cfg, 2))],
            SamplingParams(max_tokens=3))
        classes = router.per_class(outs)
    for o in outs:
        assert o.t_enqueue > 0
        assert o.t_first_token > o.t_enqueue
        assert o.t_finish >= o.t_first_token
        assert o.queue_wait >= 0 and o.ttft > 0 and o.tpot > 0
        assert o.slo == "standard" and o.replica == 0
    assert classes["standard"]["n"] == 2


def test_router_queue_full_and_deadline_drop(setup, sched):
    """With the single worker busy on a long decode: a bounded queue
    rejects at the door (RouterQueueFull), and a queued request whose
    deadline lapses is dropped at pop time without stalling the queue
    behind it."""
    cfg, model, params = setup
    prompts = _prompts(cfg, 4, seed=3)
    with RouterEngine(model, params, EngineConfig(),
                      RouterConfig(replicas=1, policy="least_loaded",
                                   max_batch=1, max_queue=2,
                                   preemption=False),
                      scheduler=sched) as router:
        u_long = router.submit(Request(uid=0, prompt=prompts[0]),
                               SamplingParams(max_tokens=16))
        _wait_running(router)
        u_dead = router.submit(
            Request(uid=1, prompt=prompts[1], deadline_s=0.01),
            SamplingParams(max_tokens=3))
        u_live = router.submit(Request(uid=2, prompt=prompts[2]),
                               SamplingParams(max_tokens=3))
        with pytest.raises(RouterQueueFull):
            router.submit(Request(uid=3, prompt=prompts[3]),
                          SamplingParams(max_tokens=3))
        dead = router.wait(u_dead)
        live = router.wait(u_live)
        router.wait(u_long)
        st = router.stats()
    assert dead.finish_reason == "deadline"
    assert len(dead.tokens) == 0 and dead.queue_wait > 0
    assert live.finish_reason == "length" and len(live.tokens) == 3
    assert st.deadline_drops == 1 and st.rejected == 1


@pytest.mark.slow
def test_router_preemption_bound_under_sustained_load(setup, sched):
    """End-to-end no-starvation: a low-priority decode facing a stream
    of high-priority arrivals is preempted at most max_preemptions
    times, still finishes, and its stitched tokens are identical to an
    uninterrupted run."""
    from repro.serving import LLMEngine
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    low_req = Request(uid=50, prompt=rng.integers(
        1, cfg.vocab_size, 10).astype(np.int32), priority=0)
    low_sp = SamplingParams(max_tokens=20, temperature=0.6, seed=5)
    hi_prompts = _prompts(cfg, 3, length=8, seed=8)
    with LLMEngine.from_config(model, params, EngineConfig(),
                               scheduler=sched) as eng:
        ref = eng.generate([low_req], [low_sp])[0]
    ec = EngineConfig(prefix_cache=PrefixCacheConfig(min_prefix=4))
    with RouterEngine(model, params, ec,
                      RouterConfig(replicas=1, policy="least_loaded",
                                   max_batch=1, max_preemptions=1),
                      scheduler=sched) as router:
        u_low = router.submit(low_req, low_sp)
        his = []
        for i, p in enumerate(hi_prompts):
            _wait_running(router)
            his.append(router.submit(
                Request(uid=60 + i, prompt=p, priority=5),
                SamplingParams(max_tokens=2)))
            time.sleep(0.05)
        out_low = router.wait(u_low)
        hi_outs = [router.wait(u) for u in his]
    assert out_low.preemptions <= 1
    assert out_low.finish_reason == ref.finish_reason
    assert list(out_low.tokens) == list(ref.tokens)
    for o in hi_outs:
        assert o.finish_reason == "length" and len(o.tokens) == 2
