"""Executable offload runtime + serving engine integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def opt_setup():
    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    return cfg, model, params


def _reference_greedy(model, params, toks, gen):
    lg, cache = model.prefill(params, toks, max_len=toks.shape[1] + gen + 2)
    out = []
    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    for _ in range(gen):
        out.append(np.asarray(tok))
        lg, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return np.concatenate(out, axis=1)


@pytest.mark.parametrize("mode", ["flexgen", "kvpr"])
def test_offload_runtime_matches_resident(opt_setup, mode):
    cfg, model, params = opt_setup
    b, s, gen = 2, 16, 5
    toks = jax.random.randint(jax.random.PRNGKey(0), (b, s), 1,
                              cfg.vocab_size)
    ref = _reference_greedy(model, params, toks, gen)

    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    store = HostKVStore(cfg, b, s + gen + 2)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
    with OffloadDecodeRuntime(cfg, params, A100_PCIE4, mode=mode) as rt:
        out, stats = rt.decode(store, np.asarray(first), gen - 1)
    # runtime emits tokens produced AFTER consuming `first` == ref[1:]
    np.testing.assert_array_equal(np.asarray(first), ref[:, :1])
    np.testing.assert_array_equal(out, ref[:, 1:gen])
    assert all(st.bytes_transferred > 0 for st in stats)


def test_serving_engine_modes_agree(opt_setup):
    cfg, model, params = opt_setup
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 12).astype(np.int32), max_new_tokens=4)
        for i in range(2)]
    with ServingEngine(model, params, mode="resident") as eng:
        res = eng.serve(reqs)
    with ServingEngine(model, params, mode="offload") as eng:
        off = eng.serve(reqs)
    for r, o in zip(res, off):
        np.testing.assert_array_equal(r.tokens, o.tokens)
        assert r.decode_time > 0 and o.decode_time > 0


def test_serving_engine_vlm(opt_setup):
    cfg = get_smoke_config("internvl2-76b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(4))
    rng = np.random.default_rng(2)
    reqs = [Request(uid=0, prompt=rng.integers(
        1, cfg.vocab_size, 10).astype(np.int32), max_new_tokens=3)]
    extra = {"patches": jnp.asarray(
        rng.normal(size=(1, cfg.num_patch_tokens, cfg.d_model)),
        jnp.float32)}
    with ServingEngine(model, params, mode="resident") as eng:
        gens = eng.serve(reqs, extra)
    assert gens[0].tokens.shape == (3,)


def test_host_store_roundtrip():
    cfg = get_smoke_config("opt-6.7b")
    store = HostKVStore(cfg, batch=2, max_len=10)
    k = np.ones((2, 1, cfg.num_kv_heads, cfg.dh), np.float32)
    store.append(0, k, k * 2, np.ones((2, 1, cfg.d_model)), pos=3)
    assert store.k[0, :, 3].sum() == k.sum()
    assert store.v[0, :, 3].sum() == 2 * k.sum()


def test_runtime_close_idempotent(opt_setup):
    """The thread-leak fix: close() joins the transfer-engine pools and
    is safe to call twice / via the context manager."""
    cfg, model, params = opt_setup
    rt = OffloadDecodeRuntime(cfg, params, A100_PCIE4, mode="kvpr")
    with rt:
        pass
    rt.close()                               # second close is a no-op
    assert rt.xfer.pool._shutdown and rt.xfer.store_pool._shutdown
