"""Launcher entry points: train (single-device smoke + loss decreases,
checkpoint round-trip) and serve (each mode produces the right number of
tokens)."""
import os

import numpy as np
import pytest

from repro.launch import serve as serve_launcher
from repro.launch import train as train_launcher
from repro.training import checkpoint


def test_train_launcher_smoke(tmp_path, capsys):
    ck = str(tmp_path / "ck.msgpack")
    train_launcher.main(["--arch", "llama3.2-1b", "--smoke",
                         "--steps", "8", "--batch", "4", "--seq", "32",
                         "--log-every", "4", "--ckpt", ck])
    out = capsys.readouterr().out
    assert "loss" in out
    tree = checkpoint.load(ck)
    assert "params" in tree and "opt" in tree


@pytest.mark.parametrize("mode,extra", [
    ("resident", []),
    ("offload", []),
    ("offload", ["--compress", "int4"]),
    ("continuous", ["--slots", "2"]),
])
def test_serve_launcher_modes(mode, extra, capsys):
    serve_launcher.main(["--arch", "llama3.2-1b", "--mode", mode,
                         "--requests", "2", "--prompt", "12",
                         "--gen", "3"] + extra)
    out = capsys.readouterr().out
    assert "2 requests, 6 tokens" in out
