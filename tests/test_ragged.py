"""Ragged-prompt prefill correctness (the silent-wrongness bugfix):
left-padding must be masked out of attention with exactly zero weight,
per-row RoPE/embedding positions must start each prompt's first real
token at position 0, and the host store must record TRUE per-slot
lengths with position-native (shifted) blocks.

End-to-end identity of ragged static batches against the per-request
reference on all four backend x batching combos lives in the golden
matrix (tests/test_identity_matrix.py); this module covers the
unit-level pieces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.runtime import HostKVStore, prefill_with_activations
from repro.models import layers as L
from repro.models.transformer import Model


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def opt_setup():
    cfg = get_smoke_config("opt-6.7b")      # learned positions (no rope)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return cfg, model, params


def test_chunked_attend_kv_start_masks_leftpad():
    """Each row's outputs beyond its pad equal a solo (unpadded) call:
    left-pad keys carry exactly zero attention weight."""
    rng = np.random.default_rng(0)
    b, s, H, dh = 3, 10, 4, 8
    pads = [0, 3, 6]
    q = jnp.asarray(rng.normal(size=(b, s, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, H, dh)), jnp.float32)
    out = L.chunked_causal_attend(q, k, v,
                                  kv_start=jnp.asarray(pads))
    for i, pad in enumerate(pads):
        solo = L.chunked_causal_attend(q[i:i + 1, pad:], k[i:i + 1, pad:],
                                       v[i:i + 1, pad:])
        np.testing.assert_allclose(np.asarray(out[i, pad:]),
                                   np.asarray(solo[0]), rtol=1e-5,
                                   atol=1e-5)


@pytest.mark.parametrize("setup_name", ["tiny_setup", "opt_setup"])
def test_prefill_with_activations_ragged_rows_match_solo(request,
                                                         setup_name):
    """Every row of a ragged (left-padded) batch produces the same
    logits / KV / activations as prefilling that prompt alone — for
    both RoPE (tinyllama) and learned-position (opt) models."""
    cfg, model, params = request.getfixturevalue(setup_name)
    rng = np.random.default_rng(2)
    lens = [5, 9, 12]
    s = max(lens)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    batch = np.zeros((len(lens), s), np.int32)
    for i, p in enumerate(prompts):
        batch[i, s - len(p):] = p
    logits, ks, vs, hs = prefill_with_activations(
        model, params, jnp.asarray(batch),
        prompt_lens=jnp.asarray(lens, jnp.int32))
    for i, p in enumerate(prompts):
        lg1, k1, v1, h1 = prefill_with_activations(
            model, params, jnp.asarray(p)[None])
        pad = s - len(p)
        np.testing.assert_allclose(np.asarray(logits[i]),
                                   np.asarray(lg1[0]), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(ks[:, i, pad:]),
                                   np.asarray(k1[:, 0]), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(vs[:, i, pad:]),
                                   np.asarray(v1[:, 0]), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(hs[:, i, pad:]),
                                   np.asarray(h1[:, 0]), rtol=2e-5,
                                   atol=2e-5)


def test_model_prefill_ragged_decode_matches_solo(tiny_setup):
    """Resident path: ragged prefill + a few decode steps are token-
    identical to serving each prompt alone (pad mask + shifted
    positions thread through decode_step via cache['pad'])."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(3)
    lens = [6, 10]
    s, gen = max(lens), 4
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    batch = np.zeros((len(lens), s), np.int32)
    for i, p in enumerate(prompts):
        batch[i, s - len(p):] = p
    lg, cache = model.prefill(params, jnp.asarray(batch),
                              max_len=s + gen + 2,
                              prompt_lens=jnp.asarray(lens, jnp.int32))
    toks = [jnp.argmax(lg, axis=-1).astype(jnp.int32)]
    for _ in range(gen):
        lg, cache = model.decode_step(params, cache, toks[-1])
        toks.append(jnp.argmax(lg, axis=-1).astype(jnp.int32))
    got = np.concatenate([np.asarray(t) for t in toks], axis=1)
    for i, p in enumerate(prompts):
        lg1, c1 = model.prefill(params, jnp.asarray(p)[None],
                                max_len=len(p) + gen + 2)
        t1 = [jnp.argmax(lg1, axis=-1).astype(jnp.int32)]
        for _ in range(gen):
            lg1, c1 = model.decode_step(params, c1, t1[-1])
            t1.append(jnp.argmax(lg1, axis=-1).astype(jnp.int32))
        ref = np.concatenate([np.asarray(t) for t in t1], axis=1)
        np.testing.assert_array_equal(got[i], ref[0])


def test_model_prefill_ragged_rejects_unsupported_arch():
    cfg = get_smoke_config("zamba2-1.2b")        # hybrid (mamba) arch
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(NotImplementedError, match="ragged"):
        model.prefill(params, toks, max_len=16,
                      prompt_lens=jnp.asarray([5, 8], jnp.int32))


@pytest.mark.parametrize("compress", [None, "int4"])
def test_bulk_fill_ragged_records_true_lengths(compress):
    """bulk_fill(seq_lens=...) shifts each left-padded row to host
    positions [0, len) and records TRUE per-slot lengths — not the
    padded batch length."""
    cfg = get_smoke_config("opt-6.7b")
    rng = np.random.default_rng(4)
    Lh, b, s = cfg.num_layers, 3, 8
    lens = np.array([4, 8, 6])
    ks = rng.normal(size=(Lh, b, s, cfg.num_kv_heads,
                          cfg.dh)).astype(np.float32)
    vs = rng.normal(size=ks.shape).astype(np.float32)
    acts = rng.normal(size=(Lh, b, s, cfg.d_model)).astype(np.float32)
    store = HostKVStore(cfg, b, 16, compress=compress)
    store.bulk_fill(ks, vs, acts, s, seq_lens=lens)
    np.testing.assert_array_equal(store.seq_lens, lens)
    for i, n in enumerate(lens):
        pad = s - n
        np.testing.assert_array_equal(store.act[:, i, :n],
                                      acts[:, i, pad:s])
        if compress is None:
            np.testing.assert_array_equal(store.k[:, i, :n],
                                          ks[:, i, pad:s])
            np.testing.assert_array_equal(store.v[:, i, :n],
                                          vs[:, i, pad:s])


def test_bulk_fill_uniform_unchanged():
    """Uniform seq_lens take the fast whole-batch path and record s."""
    cfg = get_smoke_config("opt-6.7b")
    Lh, b, s = cfg.num_layers, 2, 6
    ks = np.ones((Lh, b, s, cfg.num_kv_heads, cfg.dh), np.float32)
    acts = np.ones((Lh, b, s, cfg.d_model), np.float32)
    store = HostKVStore(cfg, b, 12)
    store.bulk_fill(ks, ks * 2, acts, s, seq_lens=np.array([s, s]))
    np.testing.assert_array_equal(store.seq_lens, [s, s])
    np.testing.assert_array_equal(store.k[:, :, :s], ks)
