"""Scheduler / ExecutionPlan tests: plan cache hit & invalidation
semantics, amortized re-solve, per-slot ragged splits, and end-to-end
continuous-offload serving parity (paper §3's automation loop)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4, RTX5000_PCIE4X8
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import ExecutionPlan, PlanKey, Scheduler
from repro.models.transformer import Model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ------------------------------------------------------------- plan cache

def test_plan_cache_hit_and_key_invalidation(tiny_setup):
    cfg, _, _ = tiny_setup
    sched = Scheduler(A100_PCIE4)
    p1 = sched.plan_for(cfg, batch=4, mode="kvpr")
    p2 = sched.plan_for(cfg, batch=4, mode="kvpr")
    assert p1 is p2 and sched.hits == 1 and sched.misses == 1

    # any key ingredient changing must yield a fresh plan
    assert sched.plan_for(cfg, batch=8, mode="kvpr") is not p1
    assert sched.plan_for(cfg, batch=4, mode="kvpr",
                          compress="int4") is not p1
    assert sched.plan_for(cfg, batch=4, mode="flexgen") is not p1
    hw2 = dataclasses.replace(A100_PCIE4, link_bandwidth=1e9)
    assert Scheduler(hw2).plan_for(cfg, batch=4).key != p1.key

    sched.invalidate(hw=RTX5000_PCIE4X8)
    p3 = sched.plan_for(cfg, batch=4, mode="kvpr")
    assert p3 is not p1 and p3.key.hw == RTX5000_PCIE4X8


def test_plan_amortized_resolve(tiny_setup):
    cfg, _, _ = tiny_setup
    sched = Scheduler(A100_PCIE4, resolve_every=16)
    plan = sched.plan_for(cfg, batch=4, mode="kvpr")
    for s in range(32, 80):          # 48 growing lengths, 3 buckets
        d = plan.split_for(s)
        assert 0 <= d.l <= s         # bucketing rounds down: l stays legal
    assert plan.lookups == 48
    assert plan.solves <= 4


def test_per_slot_ragged_splits(tiny_setup):
    cfg, _, _ = tiny_setup
    plan = Scheduler(A100_PCIE4).plan_for(cfg, batch=3, mode="kvpr")
    lens = [10, 50, 0]
    decs = plan.splits_for_slots(lens)
    assert len(decs) == 3
    for d, s in zip(decs, lens):
        assert 0 <= d.l <= s
    # flexgen plans never recompute, at any slot length
    fg = Scheduler(A100_PCIE4).plan_for(cfg, batch=3, mode="flexgen")
    assert all(d.l == 0 for d in fg.splits_for_slots(lens))


def test_runtime_has_no_inline_solver():
    """Acceptance: the ExecutionPlan is the only decode-path call site of
    optimal_split — the runtime must not import it."""
    import inspect
    import repro.core.runtime as rt
    src = inspect.getsource(rt)
    assert "optimal_split" not in src


# -------------------------------------------------- runtime regressions

def test_int4_padded_decode(tiny_setup):
    """Padded geometry + compress="int4" used to crash on
    `store.k.shape` (the quantized store has no `.k`); pad windows are
    now clamped to store.max_len by the plan's step_geometry."""
    cfg, model, params = tiny_setup
    b, s, gen = 2, 12, 3
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (b, s)).astype(np.int32)
    logits, ks, vs, hs = prefill_with_activations(model, params,
                                                  np.asarray(toks))
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    store = HostKVStore(cfg, b, s + gen + 2, compress="int4")
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
    with OffloadDecodeRuntime(cfg, params, A100_PCIE4, mode="kvpr",
                              compress="int4") as rt:
        out, stats = rt.decode(store, first, gen)
    assert out.shape == (b, gen)
    assert all(st.bytes_transferred > 0 for st in stats)
    # the plan's pads are bucket multiples clamped to the store capacity
    assert all(st.s_pad + min(st.split_ls or [st.split_l])
               <= store.max_len for st in stats)


def test_step_geometry_buckets_and_clamps(tiny_setup):
    """Pad geometry is plan-owned: bucket multiples of pad_every, maxima
    over ragged slots, clamped to the store capacity."""
    cfg, _, _ = tiny_setup
    sched = Scheduler(A100_PCIE4, resolve_every=16)
    plan = sched.plan_for(cfg, batch=3, mode="flexgen")
    g = plan.step_geometry([10, 50, 0], max_len=256)
    assert not g.uniform
    assert list(g.ls) == [0, 0, 0]           # flexgen never recomputes
    assert list(g.s_strs) == [10, 50, 0]
    assert g.s_pad == 64                     # 50 padded up to 16-bucket
    assert g.s_pad % plan.pad_every == 0
    # uniform case: one decision, pads still bucketed
    gu = plan.step_geometry([40, 40, 40], max_len=256)
    assert gu.uniform and gu.s_pad == 48
    # clamp: padded window must stay inside the preallocated store
    gc = plan.step_geometry([50, 50, 50], max_len=51)
    assert gc.s_pad <= 51


def test_int4_plan_prices_compressed_stream(tiny_setup):
    """The int4 plan must build its Workload from effective streamed
    bytes-per-element, not dtype_bytes=4 — otherwise the solver
    overestimates KV bytes ~8x and picks an over-large recompute l."""
    cfg, _, _ = tiny_setup
    sched = Scheduler(A100_PCIE4)
    pf = sched.plan_for(cfg, batch=4, mode="kvpr", dtype_bytes=4)
    pq = sched.plan_for(cfg, batch=4, mode="kvpr", dtype_bytes=4,
                        compress="int4")
    assert pq.key.kv_bytes_per_el == pytest.approx(0.75)  # group=32
    assert pf.key.kv_bytes_per_el is None
    # cheaper streaming => recomputation pays off at most as often
    for s in (64, 256, 1024, 4096):
        assert pq.split_for(s).l <= pf.split_for(s).l


def test_offload_respects_engine_sampler(tiny_setup):
    """ServingEngine(sampler="temperature") must sample in offload decode
    too — and, given the same seed, draw the exact key chain the
    resident path draws, so the two modes emit identical tokens."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 10).astype(np.int32), max_new_tokens=5)
        for i in range(2)]
    with ServingEngine(model, params, mode="resident",
                       sampler="temperature", seed=7) as eng:
        res = eng.serve(reqs)
    with ServingEngine(model, params, mode="offload",
                       sampler="temperature", seed=7) as eng:
        off = eng.serve(reqs)
    for r, o in zip(res, off):
        np.testing.assert_array_equal(r.tokens, o.tokens)
    with ServingEngine(model, params, mode="offload", sampler="greedy",
                       seed=7) as eng:
        grd = eng.serve(reqs)
    assert any(not np.array_equal(g.tokens, o.tokens)
               for g, o in zip(grd, off))


# ------------------------------------------- continuous offload serving

@pytest.mark.slow
@pytest.mark.parametrize("compress", [None, "int4"])
def test_continuous_offload_matches_resident_alone(tiny_setup, compress):
    """A request admitted mid-decode into the offload engine must produce
    tokens identical to serving it alone on the resident path (exact
    recompute + exact ragged masking).  int4 only checks shapes/flow —
    quantizing the stream is lossy by design."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(0)
    # 5 requests, ragged prompts, 2 slots -> admissions happen mid-decode
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        8 + 3 * i).astype(np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(5)]
    sched = Scheduler(A100_PCIE4)
    with ContinuousBatchingEngine(
            model, params, num_slots=2, max_len=64, mode="offload",
            scheduler=sched, compress=compress) as ceng:
        cont = ceng.serve(reqs)
    assert sched.misses >= 1     # the engine planned through the scheduler
    with ServingEngine(model, params, mode="resident") as eng:
        for r, c in zip(reqs, cont):
            assert len(c.tokens) == r.max_new_tokens
            if compress is None:
                ref = eng.serve([r])[0]
                np.testing.assert_array_equal(c.tokens, ref.tokens,
                                              err_msg=f"uid={r.uid}")


# ----------------------------------------------------- profiler hygiene

def test_profile_system_locked_and_memoized(monkeypatch):
    """Concurrent profile_system calls must all observe the SAME
    profile object (one measurement under the lock), so every
    scheduler's plan-cache keys agree."""
    from concurrent.futures import ThreadPoolExecutor
    from repro.core import profiler
    calls = []

    def fake_link():
        calls.append(1)
        return 1e9

    monkeypatch.setattr(profiler, "measure_link_bandwidth", fake_link)
    monkeypatch.setattr(profiler, "measure_gemm_flops", lambda: 1e12)
    saved = dict(profiler._PROFILE_CACHE)
    profiler._PROFILE_CACHE.clear()
    try:
        with ThreadPoolExecutor(8) as pool:
            profs = list(pool.map(
                lambda _: profiler.profile_system("t-lock"), range(16)))
        assert all(p is profs[0] for p in profs)
        assert len(calls) == 1
    finally:
        profiler._PROFILE_CACHE.clear()
        profiler._PROFILE_CACHE.update(saved)


def test_profile_force_notifies_live_schedulers(tiny_setup, monkeypatch):
    """profile_system(force=True) must push the fresh profile into live
    Schedulers that adopted a measured profile — dropping their stale
    plans — instead of relying on callers to invalidate by hand."""
    from repro.core import profiler
    cfg, _, _ = tiny_setup
    monkeypatch.setattr(profiler, "measure_link_bandwidth", lambda: 1e9)
    monkeypatch.setattr(profiler, "measure_gemm_flops", lambda: 1e12)
    saved = dict(profiler._PROFILE_CACHE)
    profiler._PROFILE_CACHE.clear()
    try:
        sched = Scheduler()                  # lazy: measures on first use
        hw1 = sched.hw
        plan1 = sched.plan_for(cfg, batch=2)
        monkeypatch.setattr(profiler, "measure_link_bandwidth",
                            lambda: 2e9)
        hw2 = profiler.profile_system(force=True)
        assert hw2 != hw1
        assert sched.hw == hw2               # profile pushed in
        assert sched.plan_for(cfg, batch=2) is not plan1   # plans dropped
    finally:
        profiler._PROFILE_CACHE.clear()
        profiler._PROFILE_CACHE.update(saved)
