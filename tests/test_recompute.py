"""Exactness of KV partial recomputation (the paper's central invariant:
no approximation) — property-tested over split points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep, see docs/automation.md
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_smoke_config
from repro.core import recompute as RC
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models.transformer import Model


def _prefill_state(model, params, toks):
    """Replay prefill capturing per-layer normed activations + KV."""
    cfg = model.cfg
    b, s = toks.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(toks, params["embed"], cfg, jnp.arange(s))
    hs, ks, vs = [], [], []
    for li in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        h = L.apply_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = L.qkv_proj(h, lp["attn"], cfg, positions)
        out = L.gqa_attend(q, k, v, L.causal_mask(s, s)).reshape(b, s, -1)
        x = x + jnp.einsum("bsD,Dh->bsh", out, lp["attn"]["wo"])
        h2 = L.apply_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp_block(h2, lp["mlp"], cfg.act)
        hs.append(h)
        ks.append(k)
        vs.append(v)
    return jnp.stack(hs), jnp.stack(ks), jnp.stack(vs)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    key = jax.random.PRNGKey(7)
    params = model.init_params(key)
    b, s = 2, 24
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    lg_ref, cache = model.prefill(params, toks[:, :s], max_len=s + 4)
    lg1, _ = model.decode_step(params, cache, toks[:, s:s + 1])
    hs, ks, vs = _prefill_state(model, params, toks[:, :s])
    return cfg, model, params, toks, s, lg1, hs, ks, vs


@pytest.mark.parametrize("split_l", [0, 1, 8, 12, 23, 24])
def test_kvpr_decode_exact_at_any_split(setup, split_l):
    cfg, model, params, toks, s, lg_ref, hs, ks, vs = setup
    logits, k_new, v_new, h_new = RC.kvpr_decode_step(
        params, cfg, toks[:, s:s + 1], jnp.asarray(s, jnp.int32),
        hs[:, :, :split_l], ks[:, :, split_l:], vs[:, :, split_l:],
        split_l)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_kvpr_decode_with_padded_stream(setup):
    """Streamed KV may be padded past the valid length (jit bucketing)."""
    cfg, model, params, toks, s, lg_ref, hs, ks, vs = setup
    split_l = 8
    pad = 5
    k_pad = jnp.pad(ks[:, :, split_l:], ((0, 0), (0, 0), (0, pad),
                                         (0, 0), (0, 0)))
    v_pad = jnp.pad(vs[:, :, split_l:], ((0, 0), (0, 0), (0, pad),
                                         (0, 0), (0, 0)))
    logits, *_ = RC.kvpr_decode_step(
        params, cfg, toks[:, s:s + 1], jnp.asarray(s, jnp.int32),
        hs[:, :, :split_l], k_pad, v_pad, split_l)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 16), st.integers(1, 3), st.booleans())
def test_merged_attention_matches_concat_oracle(split, nseg_extra, kernel):
    """merged_decode_attention over arbitrary segmentations == single
    softmax over the concatenation."""
    key = jax.random.PRNGKey(split * 7 + nseg_extra)
    b, KV, g, dh, S = 1, 2, 2, 16, 16 + split
    H = KV * g
    q = jax.random.normal(key, (b, 1, H, dh))
    segs = []
    sizes = [split, S - split] + [4] * nseg_extra
    for i, sz in enumerate(sizes):
        if sz == 0:
            continue
        kk = jax.random.normal(jax.random.fold_in(key, 2 * i), (b, sz, KV, dh))
        vv = jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                               (b, sz, KV, dh))
        segs.append((kk, vv, None))
    got = RC.merged_decode_attention(q, segs, jnp.asarray(S),
                                     use_kernel=kernel)
    want = kref.merged_attention_ref(q, segs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)
