"""Property tests for the Scheduler's three plan kinds — the decode
split (``optimal_split``), the admission-time restore split
(``Scheduler.restore_split``), and the chunked-prefill width
(``Scheduler.chunk_split`` / ``optimal_chunk``):

  - decisions stay in-bounds,
  - they never cost more than the pure endpoints (stream-everything /
    recompute-everything for the splits; the monolithic and
    minimum-chunk pipelines for the chunk width),
  - predicted cost is monotone in link bandwidth and compute rate
    (a strictly better machine never makes the chosen plan slower),
  - the recompute share is monotone in compute rate.
"""
import dataclasses

import pytest

pytest.importorskip("hypothesis")  # optional dep, see docs/automation.md
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.cost_model import HardwareProfile, Workload, layer_times
from repro.core.scheduler import Scheduler
from repro.core.solver import (chunk_pipeline_time, optimal_chunk,
                               optimal_split)


@dataclasses.dataclass(frozen=True)
class _Cfg:
    """The model-dims surface the Scheduler's plan entry points read."""
    d_model: int
    num_kv_heads: int
    dh: int
    num_layers: int
    d_ff: int
    gated_mlp: bool = True


cfgs = st.builds(
    _Cfg,
    d_model=st.sampled_from([256, 1024, 4096]),
    num_kv_heads=st.sampled_from([2, 8, 32]),
    dh=st.sampled_from([32, 64, 128]),
    num_layers=st.sampled_from([2, 16, 48]),
    d_ff=st.sampled_from([512, 4096, 16384]),
    gated_mlp=st.booleans(),
)
workloads = st.builds(
    Workload,
    batch=st.sampled_from([1, 2, 8, 64]),
    seq_len=st.integers(2, 4096),
    d_model=st.sampled_from([256, 1024, 4096]),
    kv_dim=st.sampled_from([64, 512, 4096]),
    dtype_bytes=st.sampled_from([1, 2, 4]),
)
profiles = st.builds(
    HardwareProfile,
    name=st.just("hyp"),
    link_bandwidth=st.floats(1e9, 1e12),
    gpu_flops=st.floats(1e11, 1e15),
    hbm_bandwidth=st.just(1e12),
    gemm_efficiency=st.floats(0.1, 1.0),
    dispatch_overhead=st.floats(1e-6, 1e-3),
)
lengths = st.integers(1, 4096)
schedules = st.sampled_from(["row", "column"])


def _faster(hw: HardwareProfile, link: float = 1.0, flops: float = 1.0):
    return dataclasses.replace(hw,
                               link_bandwidth=hw.link_bandwidth * link,
                               gpu_flops=hw.gpu_flops * flops)


# ------------------------------------------------------ decode split

@settings(max_examples=150, deadline=None)
@given(workloads, profiles, schedules)
def test_optimal_split_in_bounds_and_beats_endpoints(wl, hw, sched):
    d = optimal_split(wl, hw, sched)
    act = sched == "column"
    assert 0 <= d.l <= wl.seq_len
    pure_stream = layer_times(wl, hw, 0, act)["total"]
    pure_recomp = layer_times(wl, hw, wl.seq_len, act)["total"]
    assert d.t_total <= pure_stream * (1 + 1e-9)
    assert d.t_total <= pure_recomp * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, schedules)
def test_optimal_split_cost_monotone_in_rates(wl, hw, sched):
    """A faster link or a faster accelerator never makes the chosen
    plan slower (the solver re-optimizes, so cost is monotone even
    where the split direction flips)."""
    base = optimal_split(wl, hw, sched).t_total
    assert optimal_split(wl, _faster(hw, link=4.0), sched).t_total \
        <= base * (1 + 1e-9)
    assert optimal_split(wl, _faster(hw, flops=4.0), sched).t_total \
        <= base * (1 + 1e-9)


# ----------------------------------------------------- restore split

@settings(max_examples=100, deadline=None)
@given(cfgs, profiles, lengths)
def test_restore_split_in_bounds_and_beats_endpoints(cfg, hw, p):
    d = Scheduler(hw).restore_split(cfg, p)
    assert 0 <= d.l <= p            # bucketing rounds DOWN: l <= p holds
    wl = Workload(batch=1, seq_len=d.bound, d_model=cfg.d_model,
                  kv_dim=cfg.num_kv_heads * cfg.dh, dtype_bytes=4)
    # column schedule: the recomputed part's activations cross the link
    assert d.t_total <= layer_times(wl, hw, 0, True)["total"] * (1 + 1e-9)
    assert d.t_total <= layer_times(wl, hw, d.bound, True)["total"] \
        * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(cfgs, lengths)
def test_restore_split_recomputes_more_on_faster_compute(cfg, p):
    slow = HardwareProfile("slow", 32e9, 1e12, 1e12)
    fast = HardwareProfile("fast", 32e9, 1e15, 1e12)
    l_slow = Scheduler(slow).restore_split(cfg, p).l
    l_fast = Scheduler(fast).restore_split(cfg, p).l
    assert l_fast >= l_slow


# ------------------------------------------------------- chunk split

@settings(max_examples=150, deadline=None)
@given(cfgs, profiles, lengths)
def test_chunk_split_in_bounds_and_beats_endpoints(cfg, hw, n):
    d = Scheduler(hw).chunk_split(cfg, n)
    assert 1 <= d.chunk <= n
    assert d.n_chunks == -(-n // d.chunk)        # ceil: tail covered
    assert d.t_total <= d.t_monolithic * (1 + 1e-9)
    wl = Workload(batch=1, seq_len=n, d_model=cfg.d_model,
                  kv_dim=cfg.num_kv_heads * cfg.dh, dtype_bytes=4)
    mlp = 3 if cfg.gated_mlp else 2
    t_min = chunk_pipeline_time(n, min(16, n), wl, hw, cfg.num_layers,
                                cfg.d_ff, mlp_mults=mlp)["total"]
    assert d.t_total <= t_min * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(cfgs, profiles, lengths)
def test_chunk_split_cost_monotone_in_rates(cfg, hw, n):
    """More link bandwidth (faster write-back drain) or more compute
    never makes the chosen chunk pipeline slower."""
    base = Scheduler(hw).chunk_split(cfg, n).t_total
    assert Scheduler(_faster(hw, link=4.0)).chunk_split(cfg, n).t_total \
        <= base * (1 + 1e-9)
    assert Scheduler(_faster(hw, flops=4.0)).chunk_split(cfg, n).t_total \
        <= base * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, st.integers(1, 64), st.integers(1, 4096))
def test_chunk_pipeline_time_vs_sequential(wl, hw, n_layers, n):
    """The pipelined estimate is never worse than fully serializing
    every chunk's compute and write-back, and never better than the
    sum of one side alone (overlap can't create negative time)."""
    t = chunk_pipeline_time(n, max(n // 4, 1), wl, hw, n_layers, 1024)
    assert t["total"] <= t["t_compute"] + t["t_writeback"] + 1e-12
    assert t["total"] >= max(t["t_compute"], t["t_writeback"]) - 1e-12
