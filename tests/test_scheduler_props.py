"""Property tests for the Scheduler's plan kinds — the decode split
(``optimal_split``), the admission-time restore split
(``Scheduler.restore_split``), the chunked-prefill width
(``Scheduler.chunk_split`` / ``optimal_chunk``), and the mesh-sharded
variants of all of them (``optimal_shard_split`` / ``shards=`` on the
Scheduler entry points):

  - decisions stay in-bounds,
  - they never cost more than the pure endpoints (stream-everything /
    recompute-everything for the splits; the monolithic and
    minimum-chunk pipelines for the chunk width),
  - predicted cost is monotone in link bandwidth and compute rate
    (a strictly better machine never makes the chosen plan slower),
  - the recompute share is monotone in compute rate,
  - per-shard splits stay in one shard's bounds, beat that shard's
    pure endpoints, are monotone in the per-shard link share, and at
    mesh size 1 every plan kind equals the unsharded solver's output
    EXACTLY (same floats, not just same l — ``per_shard(1)`` must be
    the identity; tests/test_sharded_store_stress.py carries a
    deterministic mirror of that exactness sweep for environments
    without hypothesis).
"""
import dataclasses

import pytest

pytest.importorskip("hypothesis")  # optional dep, see docs/automation.md
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.cost_model import (HardwareProfile, TierLink, Workload,
                                   layer_times)
from repro.core.scheduler import Scheduler
from repro.core.solver import (chunk_pipeline_time, optimal_chunk,
                               optimal_shard_split, optimal_split)


@dataclasses.dataclass(frozen=True)
class _Cfg:
    """The model-dims surface the Scheduler's plan entry points read."""
    d_model: int
    num_kv_heads: int
    dh: int
    num_layers: int
    d_ff: int
    gated_mlp: bool = True


cfgs = st.builds(
    _Cfg,
    d_model=st.sampled_from([256, 1024, 4096]),
    num_kv_heads=st.sampled_from([2, 8, 32]),
    dh=st.sampled_from([32, 64, 128]),
    num_layers=st.sampled_from([2, 16, 48]),
    d_ff=st.sampled_from([512, 4096, 16384]),
    gated_mlp=st.booleans(),
)
workloads = st.builds(
    Workload,
    batch=st.sampled_from([1, 2, 8, 64]),
    seq_len=st.integers(2, 4096),
    d_model=st.sampled_from([256, 1024, 4096]),
    kv_dim=st.sampled_from([64, 512, 4096]),
    dtype_bytes=st.sampled_from([1, 2, 4]),
)
profiles = st.builds(
    HardwareProfile,
    name=st.just("hyp"),
    link_bandwidth=st.floats(1e9, 1e12),
    gpu_flops=st.floats(1e11, 1e15),
    hbm_bandwidth=st.just(1e12),
    gemm_efficiency=st.floats(0.1, 1.0),
    dispatch_overhead=st.floats(1e-6, 1e-3),
)
lengths = st.integers(1, 4096)
schedules = st.sampled_from(["row", "column"])


def _faster(hw: HardwareProfile, link: float = 1.0, flops: float = 1.0):
    return dataclasses.replace(hw,
                               link_bandwidth=hw.link_bandwidth * link,
                               gpu_flops=hw.gpu_flops * flops)


# ------------------------------------------------------ decode split

@settings(max_examples=150, deadline=None)
@given(workloads, profiles, schedules)
def test_optimal_split_in_bounds_and_beats_endpoints(wl, hw, sched):
    d = optimal_split(wl, hw, sched)
    act = sched == "column"
    assert 0 <= d.l <= wl.seq_len
    pure_stream = layer_times(wl, hw, 0, act)["total"]
    pure_recomp = layer_times(wl, hw, wl.seq_len, act)["total"]
    assert d.t_total <= pure_stream * (1 + 1e-9)
    assert d.t_total <= pure_recomp * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, schedules)
def test_optimal_split_cost_monotone_in_rates(wl, hw, sched):
    """A faster link or a faster accelerator never makes the chosen
    plan slower (the solver re-optimizes, so cost is monotone even
    where the split direction flips)."""
    base = optimal_split(wl, hw, sched).t_total
    assert optimal_split(wl, _faster(hw, link=4.0), sched).t_total \
        <= base * (1 + 1e-9)
    assert optimal_split(wl, _faster(hw, flops=4.0), sched).t_total \
        <= base * (1 + 1e-9)


# ----------------------------------------------------- restore split

@settings(max_examples=100, deadline=None)
@given(cfgs, profiles, lengths)
def test_restore_split_in_bounds_and_beats_endpoints(cfg, hw, p):
    d = Scheduler(hw).restore_split(cfg, p)
    assert 0 <= d.l <= p            # bucketing rounds DOWN: l <= p holds
    wl = Workload(batch=1, seq_len=d.bound, d_model=cfg.d_model,
                  kv_dim=cfg.num_kv_heads * cfg.dh, dtype_bytes=4)
    # column schedule: the recomputed part's activations cross the link
    assert d.t_total <= layer_times(wl, hw, 0, True)["total"] * (1 + 1e-9)
    assert d.t_total <= layer_times(wl, hw, d.bound, True)["total"] \
        * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(cfgs, lengths)
def test_restore_split_recomputes_more_on_faster_compute(cfg, p):
    slow = HardwareProfile("slow", 32e9, 1e12, 1e12)
    fast = HardwareProfile("fast", 32e9, 1e15, 1e12)
    l_slow = Scheduler(slow).restore_split(cfg, p).l
    l_fast = Scheduler(fast).restore_split(cfg, p).l
    assert l_fast >= l_slow


# ------------------------------------------------------- chunk split

@settings(max_examples=150, deadline=None)
@given(cfgs, profiles, lengths)
def test_chunk_split_in_bounds_and_beats_endpoints(cfg, hw, n):
    d = Scheduler(hw).chunk_split(cfg, n)
    assert 1 <= d.chunk <= n
    assert d.n_chunks == -(-n // d.chunk)        # ceil: tail covered
    assert d.t_total <= d.t_monolithic * (1 + 1e-9)
    wl = Workload(batch=1, seq_len=n, d_model=cfg.d_model,
                  kv_dim=cfg.num_kv_heads * cfg.dh, dtype_bytes=4)
    mlp = 3 if cfg.gated_mlp else 2
    t_min = chunk_pipeline_time(n, min(16, n), wl, hw, cfg.num_layers,
                                cfg.d_ff, mlp_mults=mlp)["total"]
    assert d.t_total <= t_min * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(cfgs, profiles, lengths)
def test_chunk_split_cost_monotone_in_rates(cfg, hw, n):
    """More link bandwidth (faster write-back drain) or more compute
    never makes the chosen chunk pipeline slower."""
    base = Scheduler(hw).chunk_split(cfg, n).t_total
    assert Scheduler(_faster(hw, link=4.0)).chunk_split(cfg, n).t_total \
        <= base * (1 + 1e-9)
    assert Scheduler(_faster(hw, flops=4.0)).chunk_split(cfg, n).t_total \
        <= base * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, st.integers(1, 64), st.integers(1, 4096))
def test_chunk_pipeline_time_vs_sequential(wl, hw, n_layers, n):
    """The pipelined estimate is never worse than fully serializing
    every chunk's compute and write-back, and never better than the
    sum of one side alone (overlap can't create negative time)."""
    t = chunk_pipeline_time(n, max(n // 4, 1), wl, hw, n_layers, 1024)
    assert t["total"] <= t["t_compute"] + t["t_writeback"] + 1e-12
    assert t["total"] >= max(t["t_compute"], t["t_writeback"]) - 1e-12


# ----------------------------------------------- mesh-sharded splits

# every kv_dim the workloads strategy emits (64/512/4096) divides by 8,
# so any shard count below divides the per-head slicing cleanly
shard_counts = st.sampled_from([2, 4, 8])


@settings(max_examples=150, deadline=None)
@given(workloads, profiles, schedules, shard_counts)
def test_shard_split_in_bounds_and_beats_endpoints(wl, hw, sched, k):
    """One shard's split stays inside [0, seq_len] and never costs more
    than that shard's pure endpoints (stream-everything over 1/k of the
    link; recompute-everything at 1/k of the FLOPs)."""
    d = optimal_shard_split(wl, hw, k, sched)
    act = sched == "column"
    assert 0 <= d.l <= wl.seq_len
    wl_s, hw_s = wl.per_shard(k), hw.per_shard(k)
    pure_stream = layer_times(wl_s, hw_s, 0, act)["total"]
    pure_recomp = layer_times(wl_s, hw_s, wl.seq_len, act)["total"]
    assert d.t_total <= pure_stream * (1 + 1e-9)
    assert d.t_total <= pure_recomp * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, schedules, shard_counts)
def test_shard_split_cost_monotone_in_link_share(wl, hw, sched, k):
    """Growing the total link bandwidth grows every shard's 1/k share,
    and the re-optimized per-shard plan never gets slower."""
    base = optimal_shard_split(wl, hw, k, sched).t_total
    assert optimal_shard_split(wl, _faster(hw, link=4.0), k, sched) \
        .t_total <= base * (1 + 1e-9)
    assert optimal_shard_split(wl, _faster(hw, flops=4.0), k, sched) \
        .t_total <= base * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles, schedules, shard_counts)
def test_shard_split_is_unsharded_split_of_shard_workload(wl, hw, sched, k):
    """``optimal_shard_split`` is definitionally the unsharded solve of
    one shard's workload on one shard's link share — exactly."""
    assert optimal_shard_split(wl, hw, k, sched) == \
        optimal_split(wl.per_shard(k), hw.per_shard(k), sched)


@settings(max_examples=100, deadline=None)
@given(cfgs, profiles, st.integers(1, 4096), st.sampled_from([1, 2, 8]))
def test_mesh1_plans_equal_unsharded_exactly(cfg, hw, n, batch):
    """Mesh size 1 must degenerate BIT-EXACTLY, for all four plan
    kinds, to the solver a shards-free caller gets: ``per_shard(1)``
    returns the profile/workload unchanged, so the decisions compare
    equal as dataclasses (same floats, not just the same split point).
    Fresh Scheduler per side so memoization can't mask a divergence."""
    s1, s0 = Scheduler(hw), Scheduler(hw)
    hw_t = hw.with_tiers(TierLink("disk", hw.link_bandwidth / 4,
                                  hw.link_bandwidth / 8))

    # 1) decode split (row schedule, the decode hot path)
    assert s1.plan_for(cfg, batch, shards=1).split_for(n) == \
        s0.plan_for(cfg, batch).split_for(n)
    # 2) admission-time restore split (batch-1, column schedule)
    assert s1.restore_split(cfg, n, shards=1) == s0.restore_split(cfg, n)
    # 3) chunked-prefill width
    assert s1.chunk_split(cfg, n, batch=batch, shards=1) == \
        s0.chunk_split(cfg, n, batch=batch)
    # 4) tier split over a two-rung ladder (half the prefix on disk)
    t1 = s1.plan_for(cfg, batch, hw=hw_t, disk_bytes_per_el=4.0,
                     shards=1).tier_split_for(n, n // 2)
    t0 = s0.plan_for(cfg, batch, hw=hw_t,
                     disk_bytes_per_el=4.0).tier_split_for(n, n // 2)
    assert t1 == t0
