import os

# Keep tests single-device (the dry-run sets its own 512-device flag in a
# separate process). Cap compilation parallelism for the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import signal  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Per-test deadline: use pytest-timeout when installed (CI); otherwise
# fall back to a SIGALRM shim so a wedged fence/future still fails the
# test instead of hanging the whole run.  The shim arms the alarm
# around the CALL phase only — module fixtures (model builds, XLA
# warm-up compiles) stay un-deadlined.
try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False

_CAN_ALARM = hasattr(signal, "SIGALRM")


def pytest_addoption(parser):
    if _HAVE_PYTEST_TIMEOUT:
        return                      # the real plugin owns the ini option
    parser.addini("timeout",
                  "per-test deadline in seconds (SIGALRM shim)",
                  default="0")


def _deadline_for(item) -> float:
    m = item.get_closest_marker("timeout")
    if m is not None and m.args:
        return float(m.args[0])
    try:
        return float(item.config.getini("timeout") or 0)
    except (TypeError, ValueError):
        return 0.0


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _HAVE_PYTEST_TIMEOUT or not _CAN_ALARM:
        yield
        return
    limit = _deadline_for(item)
    if limit <= 0:
        yield
        return

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {limit:g}s per-test deadline "
            f"(conftest SIGALRM shim)")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


# Executables of long-dead engines stay pinned by jax's process-global
# jit caches, and every one holds mmap'd code/data regions: a single
# process running the whole suite drifts toward vm.max_map_count
# (65530 by default), after which XLA segfaults when an mmap fails
# mid-compile.  Shed the caches whenever map pressure gets high — the
# occasional recompile is far cheaper than a segfault at test ~320.
_MAP_PRESSURE_LIMIT = 20_000


def _map_count() -> int:
    try:
        with open("/proc/self/maps") as f:
            return sum(1 for _ in f)
    except OSError:                     # non-Linux: no pressure signal
        return 0


def pytest_runtest_teardown(item, nextitem):
    if _map_count() > _MAP_PRESSURE_LIMIT:
        import gc

        jax.clear_caches()
        gc.collect()


def xla_device_count(n: int, env=None) -> dict:
    """Subprocess environment emulating ``n`` CPU devices.

    COMPOSES ``--xla_force_host_platform_device_count=n`` with whatever
    ``XLA_FLAGS`` the caller or CI already exported instead of
    clobbering them (a pre-existing device-count flag is replaced, all
    other flags survive).  Also points PYTHONPATH at src so
    ``python -c`` subprocesses import the package from the repo root.
    The flag must be set before jax initializes — this test process is
    pinned to 1 CPU device, which is why every multi-device test runs
    its mesh half in a subprocess with this env.
    """
    out = dict(os.environ if env is None else env)
    flags = [f for f in out.get("XLA_FLAGS", "").split()
             if not f.startswith(
                 "--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={int(n)}")
    out["XLA_FLAGS"] = " ".join(flags)
    out["JAX_PLATFORMS"] = "cpu"
    pp = out.get("PYTHONPATH", "")
    if "src" not in pp.split(os.pathsep):
        out["PYTHONPATH"] = "src" + (os.pathsep + pp if pp else "")
    return out


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test, excluded from the fast CI lane "
        "(pytest -m 'not slow'); the full suite still runs it")
    if not _HAVE_PYTEST_TIMEOUT:
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test deadline (SIGALRM shim when "
            "pytest-timeout is absent)")
