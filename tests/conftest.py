import os

# Keep tests single-device (the dry-run sets its own 512-device flag in a
# separate process). Cap compilation parallelism for the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test, excluded from the fast CI lane "
        "(pytest -m 'not slow'); the full suite still runs it")
