"""Per-architecture smoke tests (deliverable f): reduced config, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.transformer import Model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("opt-")]


def _extra(cfg, b, key):
    if cfg.arch_type == "audio":
        return {"frames": jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))}
    if cfg.arch_type == "vlm":
        return {"patches": jax.random.normal(
            key, (b, cfg.num_patch_tokens, cfg.d_model))}
    return None


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    b, s = 2, 64
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, aux = model.forward(params, toks, _extra(cfg, b, key))
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    opt_state = init_opt_state(params)
    step = make_train_step(model, AdamWConfig(total_steps=10))
    b, s = 2, 64
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex = _extra(cfg, b, key)
    if ex:
        batch["extra"] = ex
    params2, opt2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    diffs = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, params2)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_teacher_forcing(arch):
    """prefill + decode_step must equal the full-sequence forward."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    b, s, gen = 2, 32, 4
    toks = jax.random.randint(key, (b, s + gen), 0, cfg.vocab_size)
    ex = _extra(cfg, b, key)
    logits_tf, _ = model.forward(params, toks, ex)
    max_len = s + gen + 8
    if cfg.arch_type == "vlm":
        max_len += cfg.num_patch_tokens
    lg, cache = model.prefill(params, toks[:, :s], ex, max_len=max_len)
    outs = [lg]
    for i in range(gen - 1):
        lg, cache = model.decode_step(params, cache, toks[:, s + i:s + i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    ref = logits_tf[:, s - 1:s + gen - 1]
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)
