"""Unit tests for the tiered KV storage hierarchy (docs/storage.md):
the tier_split plan kind, the mmap disk tier's three layouts, typed
capacity errors, dual LRU+TTL eviction, and the disk-fault ladder.
"""
import time

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (A100_PCIE4, PrefixCache, PrefixCacheConfig,
                        Scheduler, TierLink, Workload,
                        brute_force_tier_split, layer_times,
                        optimal_split, optimal_tier_split,
                        tier_layer_times)
from repro.core.faults import (DiskFullError, DiskReadError, FaultPolicy,
                               TransientTransferError)
from repro.core.kvstore import (HostKVStore, KVTiersConfig, MmapDiskTier,
                                StoreCapacityError, TieredKVStore)

CFG = get_smoke_config("tinyllama-1.1b")
DISK_BW = 1e9


def _wl(batch=4, s=1024):
    return Workload(batch=batch, seq_len=s, d_model=CFG.d_model,
                    kv_dim=CFG.num_kv_heads * CFG.dh, dtype_bytes=4)


def _fill_arrays(b, s, seed=0):
    rng = np.random.default_rng(seed)
    Lh, KV, dh, h = (CFG.num_layers, CFG.num_kv_heads, CFG.dh,
                     CFG.d_model)
    return (rng.standard_normal((Lh, b, s, KV, dh), dtype=np.float32),
            rng.standard_normal((Lh, b, s, KV, dh), dtype=np.float32),
            rng.standard_normal((Lh, b, s, h), dtype=np.float32))


# ---------------------------------------------------------------- solver


def test_tier_split_degenerates_without_disk():
    """d=0 must reproduce the single-link optimum exactly."""
    wl, hw = _wl(), A100_PCIE4
    base = optimal_split(wl, hw, "row")
    tier = optimal_tier_split(wl, hw, disk_tokens=0,
                              disk_read_bandwidth=DISK_BW)
    assert tier.l == base.l
    assert tier.t_total == pytest.approx(base.t_total)
    assert tier.t_disk == 0.0
    assert tier.paged_tokens == 0


@pytest.mark.parametrize("d_frac", [0.1, 0.5, 0.9, 1.0])
@pytest.mark.parametrize("bw", [1e8, 1e9, 1e10])
def test_tier_split_matches_brute_force(d_frac, bw):
    wl, hw = _wl(), A100_PCIE4
    d = int(wl.seq_len * d_frac)
    a = optimal_tier_split(wl, hw, disk_tokens=d, disk_read_bandwidth=bw)
    b = brute_force_tier_split(wl, hw, disk_tokens=d,
                               disk_read_bandwidth=bw)
    assert a.t_total <= b.t_total * (1 + 1e-9)
    assert a.paged_tokens == max(0, d - a.l)


def test_slower_disk_recomputes_more():
    """A slower disk rung shifts the split toward recomputation (the
    demoted prefix is cheaper to recompute than to page in)."""
    wl, hw = _wl(), A100_PCIE4
    d = wl.seq_len // 2
    l_fast = optimal_tier_split(wl, hw, d, disk_read_bandwidth=1e11).l
    l_slow = optimal_tier_split(wl, hw, d, disk_read_bandwidth=1e7).l
    assert l_slow >= l_fast
    # with a pathologically slow disk the whole demoted prefix is
    # recomputed: nothing left to page
    assert optimal_tier_split(wl, hw, d,
                              disk_read_bandwidth=1e3).paged_tokens == 0


def test_tier_layer_times_charges_both_crossings():
    wl, hw = _wl(), A100_PCIE4
    d = 256
    t = tier_layer_times(wl, hw, l=0, disk_tokens=d,
                         disk_read_bandwidth=DISK_BW)
    base = layer_times(wl, hw, 0)
    # cold tokens cross disk->host on top of the host->device stream
    assert t["t_disk"] > 0
    assert t["t_kv"] == pytest.approx(base["t_kv"] + t["t_disk"])
    # recomputing past the demoted prefix removes the disk term
    assert tier_layer_times(wl, hw, l=d, disk_tokens=d,
                            disk_read_bandwidth=DISK_BW)["t_disk"] == 0


def test_plan_tier_split_memoized():
    sched = Scheduler(A100_PCIE4.with_tiers(
        TierLink("disk", DISK_BW, DISK_BW)))
    plan = sched.plan_for(CFG, batch=2, mode="kvpr")
    a = plan.tier_split_for(512, 128)
    b = plan.tier_split_for(512, 128)
    assert a == b
    assert plan.solves <= plan.lookups  # memo hit, not re-solve
    # disk_tokens is reported against the REAL d even when bucketed
    c = plan.tier_split_for(512, 130)
    assert c.disk_tokens == 130


# ------------------------------------------------------------- disk tier


@pytest.mark.parametrize("layout", ["raw", "pack"])
def test_disk_tier_roundtrip(tmp_path, layout):
    b, ml, bt = 2, 64, 8
    tier = MmapDiskTier(CFG, b, ml, bt, layout=layout,
                        directory=str(tmp_path))
    rng = np.random.default_rng(0)
    Lh, KV, dh = CFG.num_layers, CFG.num_kv_heads, CFG.dh
    k = rng.standard_normal((Lh, bt, KV, dh), dtype=np.float32)
    v = rng.standard_normal((Lh, bt, KV, dh), dtype=np.float32)
    tier.write_block(1, 3, k, v)
    ok = np.zeros((bt, KV, dh), np.float32)
    ov = np.zeros_like(ok)
    for li in range(Lh):
        tier.read_block_layer(li, 1, 3, ok, ov)
        if layout == "raw":
            np.testing.assert_array_equal(ok, k[li])
            np.testing.assert_array_equal(ov, v[li])
        else:                        # int4: lossy but close
            assert np.abs(ok - k[li]).max() < 0.5
    assert tier.reads == Lh and tier.writes == 1
    assert tier.bytes_used > 0
    # a non-resident block is a typed read error
    with pytest.raises(DiskReadError):
        tier.read_block_layer(0, 0, 0, ok, ov)
    tier.free_block(1, 3)
    assert tier.bytes_used == 0
    tier.close()
    tier.close()                     # idempotent


def test_disk_tier_capacity_and_close(tmp_path):
    bt = 8
    tier = MmapDiskTier(CFG, 2, 64, bt, capacity_tokens=2 * bt,
                        directory=str(tmp_path))
    Lh, KV, dh = CFG.num_layers, CFG.num_kv_heads, CFG.dh
    blk = np.zeros((Lh, bt, KV, dh), np.float32)
    tier.write_block(0, 0, blk, blk)
    tier.write_block(0, 1, blk, blk)
    with pytest.raises(DiskFullError):
        tier.write_block(0, 2, blk, blk)
    tier.free_slot(0)
    tier.write_block(1, 0, blk, blk)       # capacity released
    tier.close()
    with pytest.raises(DiskFullError):
        tier.write_block(1, 1, blk, blk)   # closed tier refuses


# ----------------------------------------------------- capacity satellite


def test_host_store_rejects_over_capacity_fill():
    b, ml, s = 2, 64, 16
    ks, vs, hs = _fill_arrays(b, s)
    store = HostKVStore(CFG, b, ml, capacity_tokens=24)
    with pytest.raises(StoreCapacityError):
        store.bulk_fill(ks, vs, hs, s)         # 32 > 24
    assert int(store.seq_lens.sum()) == 0      # nothing landed
    store.bulk_fill(ks[:, :, :12], vs[:, :, :12], hs[:, :, :12], 12)
    with pytest.raises(StoreCapacityError):
        store.fill_slot(1, ks[:, :1], vs[:, :1], hs[:, :1], s)
    # per-slot length past the physical allocation is also typed
    with pytest.raises(StoreCapacityError):
        store.fill_slot(0, ks[:, :1], vs[:, :1], hs[:, :1], ml + 1)
    tb = store.tier_bytes()
    assert tb["host"]["used_tokens"] == 24
    assert tb["host"]["capacity_tokens"] == 24
    assert tb["host"]["used_bytes"] == 24 * store.kv_token_bytes


# ----------------------------------------------------------- tiered store


def test_tiered_store_demotes_and_pages_in():
    b, ml, s, bt = 2, 64, 32, 8
    ks, vs, hs = _fill_arrays(b, s)
    st = TieredKVStore(CFG, b, ml, tiers=KVTiersConfig(
        host_capacity_tokens=24, block_tokens=bt))
    st.bulk_fill(ks, vs, hs, s)
    d = st.disk_tokens()
    assert (d > 0).any()
    assert st.host_tokens <= 24
    tb = st.tier_bytes()
    assert tb["disk"]["used_tokens"] == int(d.sum())
    assert (tb["host"]["used_tokens"] + tb["disk"]["used_tokens"]
            == b * s)
    # page everything back in: host bytes must be bit-identical
    ref = HostKVStore(CFG, b, ml)
    ref.bulk_fill(ks, vs, hs, s)
    ls = np.zeros(b, np.int64)
    strs = np.full(b, s, np.int64)
    for li in range(CFG.num_layers):
        st.page_in(li, ls, strs)
    assert (st.disk_tokens() == 0).all()
    np.testing.assert_array_equal(st.k[:, :, :s], ref.k[:, :, :s])
    np.testing.assert_array_equal(st.v[:, :, :s], ref.v[:, :, :s])
    assert st.stats().promotions > 0
    st.close()


def test_tiered_store_ttl_sweep():
    b, ml, s, bt = 2, 64, 32, 8
    ks, vs, hs = _fill_arrays(b, s)
    st = TieredKVStore(CFG, b, ml, tiers=KVTiersConfig(
        block_tokens=bt, ttl_s=0.05))
    st.bulk_fill(ks, vs, hs, s)
    assert st.sweep() == 0                     # fresh: nothing idle
    time.sleep(0.08)
    assert st.sweep() > 0                      # idle past TTL: demoted
    stats = st.stats()
    assert stats.ttl_demotions > 0
    # full blocks demoted; the newest-token safety margin stays in DRAM
    assert (st.disk_tokens() >= s - 2 * bt).all()
    st.close()


def test_tiered_store_disk_full_is_benign():
    b, ml, s, bt = 2, 64, 32, 8
    ks, vs, hs = _fill_arrays(b, s)
    st = TieredKVStore(CFG, b, ml, tiers=KVTiersConfig(
        host_capacity_tokens=bt, block_tokens=bt,
        disk_capacity_tokens=bt))            # room for ONE block
    st.bulk_fill(ks, vs, hs, s)              # wants to demote far more
    stats = st.stats()
    assert stats.demotions == 1
    assert stats.demote_failures > 0         # DiskFullError absorbed
    # the store still serves: every non-demoted byte is in DRAM
    ref = HostKVStore(CFG, b, ml)
    ref.bulk_fill(ks, vs, hs, s)
    np.testing.assert_array_equal(st.k[:, :, bt:s], ref.k[:, :, bt:s])
    st.close()


def test_tiered_store_injected_disk_read_fault():
    """An injected disk_read fault surfaces as DiskReadError — a
    TransientTransferError the fetch ladder retries/degrades on."""
    b, ml, s, bt = 2, 64, 32, 8
    ks, vs, hs = _fill_arrays(b, s)
    faults = FaultPolicy(disk_read_fail_rate=1.0, seed=1)
    st = TieredKVStore(CFG, b, ml, tiers=KVTiersConfig(
        host_capacity_tokens=16, block_tokens=bt), faults=faults)
    st.bulk_fill(ks, vs, hs, s)
    assert (st.disk_tokens() > 0).any()
    with pytest.raises(TransientTransferError):
        st.page_in(0, np.zeros(b, np.int64), np.full(b, s, np.int64))
    st.close()


def test_tiered_clear_slot_releases_disk():
    b, ml, s, bt = 2, 64, 32, 8
    ks, vs, hs = _fill_arrays(b, s)
    st = TieredKVStore(CFG, b, ml, tiers=KVTiersConfig(
        host_capacity_tokens=16, block_tokens=bt))
    st.bulk_fill(ks, vs, hs, s)
    assert st.tier.resident_blocks > 0
    before = st.tier.resident_blocks
    st.clear_slot(0)
    assert st.disk_tokens()[0] == 0
    assert st.tier.resident_blocks < before
    st.close()


# -------------------------------------------------------- prefix TTL sat.


def test_prefix_cache_ttl_eviction():
    pc = PrefixCache(PrefixCacheConfig(capacity_tokens=1024,
                                       min_prefix=2, ttl_s=0.05))
    Lh, KV, dh, h = (CFG.num_layers, CFG.num_kv_heads, CFG.dh,
                     CFG.d_model)
    toks = [1, 2, 3, 4]
    p = len(toks)
    ks = np.zeros((Lh, 1, p, KV, dh), np.float32)
    hs = np.zeros((Lh, 1, p, h), np.float32)
    assert pc.insert(toks, ks, ks, hs)
    m, e = pc.lookup(toks + [5])
    assert m == p and e is not None
    time.sleep(0.08)
    # peek is non-mutating but reports the expiry
    assert pc.peek(toks + [5]) == (0, None)
    m, e = pc.lookup(toks + [5])               # sweeps, then misses
    assert (m, e) == (0, None)
    assert pc.stats.ttl_evictions == 1
    assert pc.stats.tokens_stored == 0
    # a hit refreshes the deadline
    assert pc.insert(toks, ks, ks, hs)
    time.sleep(0.03)
    assert pc.lookup(toks + [5])[0] == p       # refresh at ~0.03
    time.sleep(0.03)
    assert pc.lookup(toks + [5])[0] == p       # still alive at ~0.06
    st = pc.stats
    assert st.ttl_evictions == 1


def test_prefix_cache_ttl_none_never_expires():
    pc = PrefixCache(PrefixCacheConfig(min_prefix=2))
    Lh, KV, dh, h = (CFG.num_layers, CFG.num_kv_heads, CFG.dh,
                     CFG.d_model)
    ks = np.zeros((Lh, 1, 3, KV, dh), np.float32)
    hs = np.zeros((Lh, 1, 3, h), np.float32)
    pc.insert([7, 8, 9], ks, ks, hs)
    assert pc.lookup([7, 8, 9, 1])[0] == 3
    assert pc.stats.ttl_evictions == 0


def test_kv_tiers_config_validation():
    with pytest.raises(ValueError):
        KVTiersConfig(policy="lru").validate()
    with pytest.raises(ValueError):
        KVTiersConfig(block_tokens=0).validate()
    with pytest.raises(ValueError):
        KVTiersConfig(host_capacity_tokens=4, block_tokens=8).validate()
    with pytest.raises(ValueError):
        KVTiersConfig(ttl_s=0.0).validate()
    KVTiersConfig(host_capacity_tokens=64).validate()
