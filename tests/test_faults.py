"""Fault-injection matrix over the serving engine.

The robustness contract, exercised end to end against the same golden
per-request reference test_identity_matrix.py uses (a batch-1
resident/static run with the same engine seed and uid — the
sampling-stream invariant makes it ground truth):

  matrix      every backend x batching combo x {transient fetch
              failure, transient write-back failure, slow link, one
              hard per-request failure}: transient faults recover via
              bounded retry with ZERO token divergence; the hard fault
              errors exactly its own request while the survivors stay
              token-identical.
  stall       a dead store thread surfaces as TransferStallError
              within ``fence_timeout_s`` instead of hanging; releasing
              the hang heals the engine in place.
  poisoned    a write-back failure mid-``generate_stream`` propagates
              but does NOT wedge the engine — the next ``generate()``
              on the same engine is token-identical.
  ladder      kernel-launch failure degrades to the jnp oracle; a
              dead link degrades fetches to full recomputation from
              activations (the paper's l=p endpoint); a failed
              prefix-cache restore falls back to cold prefill and
              evicts the poisoned entry.  All three are token-exact.
  lifecycle   double close, close mid-stream, and the error-path
              fence drain leave no hung worker behind.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, FaultPolicy, LLMEngine,
                           PrefixCacheConfig, Request, SamplingParams,
                           TransferError, TransferStallError)

COMBOS = [("resident", "static"), ("offload", "static"),
          ("resident", "continuous"), ("offload", "continuous")]
FAULTS = ["transient_fetch", "transient_store", "slow_link",
          "hard_request"]

LENS = [8, 11, 14]
GENS = (5, 4, 6)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def sched():
    return Scheduler(A100_PCIE4)


def _reqs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, n).astype(np.int32)) for i, n in
        enumerate(LENS)]


def _sps():
    return [SamplingParams(max_tokens=g) for g in GENS]


_REFS = {}


def _reference(setup, sched, reqs, sps):
    """Per-request ground truth: batch-1 resident/static runs (same
    engine seed, same uid => same sampling stream), memoized."""
    cfg, model, params = setup
    outs = []
    for r, sp in zip(reqs, sps):
        key = (r.uid, r.prompt.tobytes(), sp)
        if key not in _REFS:
            with LLMEngine.from_config(model, params, EngineConfig(),
                                       scheduler=sched) as eng:
                o = eng.generate([r], sp)[0]
            _REFS[key] = (list(o.tokens), o.finish_reason)
        outs.append(_REFS[key])
    return outs


def _policy(fault: str) -> FaultPolicy:
    """Fresh (stateful!) policy per test."""
    if fault == "transient_fetch":
        return FaultPolicy(fail_first={"fetch": 1})
    if fault == "transient_store":
        return FaultPolicy(fail_first={"store": 1})
    if fault == "slow_link":
        return FaultPolicy(link_bytes_per_s=50e6)
    if fault == "hard_request":
        return FaultPolicy(hard_fail_uids=frozenset({1}))
    raise AssertionError(fault)


def _engine(setup, sched, backend, batching, policy, **kw):
    cfg, model, params = setup
    return LLMEngine.from_config(
        model, params,
        EngineConfig(backend=backend, batching=batching, slots=2,
                     max_len=64, faults=policy, io_backoff_s=1e-3,
                     **kw),
        scheduler=sched)


# ------------------------------------------------------------- matrix


@pytest.mark.parametrize("backend,batching", COMBOS)
@pytest.mark.parametrize("fault", FAULTS)
def test_fault_matrix(setup, sched, backend, batching, fault):
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = _policy(fault)
    with _engine(setup, sched, backend, batching, policy) as eng:
        outs = eng.generate(reqs, sps)
    for r, o, (ref_toks, ref_fin) in zip(reqs, outs, refs):
        if fault == "hard_request" and r.uid == 1:
            assert o.finish_reason == "error"
            assert o.error and "uid=1" in o.error
            assert len(o.tokens) == 0
        else:
            # survivors (and every request under recoverable faults)
            # are token-identical to the golden run
            assert list(o.tokens) == ref_toks, (fault, backend,
                                                batching, r.uid)
            assert o.finish_reason == ref_fin
    if fault == "hard_request":
        assert policy.injected.get("admit", 0) == 1
    elif backend == "offload" and fault != "slow_link":
        # the transient fault actually fired on the transfer path
        kind = "fetch" if fault == "transient_fetch" else "store"
        assert policy.injected.get(kind, 0) >= 1


@pytest.mark.parametrize("backend,batching", COMBOS)
def test_hard_fault_stream_sentinel(setup, sched, backend, batching):
    """The stream yields exactly one sentinel error event for the
    failed request and full token streams for the survivors."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = _policy("hard_request")
    with _engine(setup, sched, backend, batching, policy) as eng:
        events = list(eng.generate_stream(reqs, sps))
    errs = [e for e in events if e.uid == 1]
    assert len(errs) == 1
    assert (errs[0].token, errs[0].index, errs[0].finish_reason) == \
        (-1, -1, "error")
    for r, (ref_toks, _) in zip(reqs, refs):
        if r.uid == 1:
            continue
        toks = [e.token for e in events if e.uid == r.uid]
        assert toks == ref_toks, (backend, batching, r.uid)


def test_retry_counter_surfaces_in_stats(setup, sched):
    """Retried transients show up in StepStats.retries."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy(fail_first={"fetch": 2})
    with _engine(setup, sched, "offload", "static", policy) as eng:
        events = list(eng.generate_stream(reqs, sps))
        retries = sum(e.stats.retries for e in events
                      if e.stats is not None)
    assert policy.injected.get("fetch", 0) == 2
    assert retries >= 2
    for r, (ref_toks, _) in zip(reqs, refs):
        assert [e.token for e in events if e.uid == r.uid] == ref_toks


# -------------------------------------------------------------- stall


@pytest.mark.parametrize("batching,dead_after", [
    ("static", 1),       # op 0 is the admission bulk_fill
    ("continuous", 2),   # ops 0-1 are the two slot fills
])
def test_dead_store_thread_raises_stall(setup, sched, batching,
                                        dead_after):
    """A store worker that never returns surfaces as
    TransferStallError within ~fence_timeout_s (never a hang); after
    release() the same engine serves token-identically."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy(dead_store_after=dead_after)
    with _engine(setup, sched, "offload", batching, policy,
                 fence_timeout_s=1.5) as eng:
        t0 = time.perf_counter()
        with pytest.raises(TransferStallError):
            eng.generate(reqs, sps)
        # bounded: the watchdog fired (drain pays <= timeout per
        # fence, nowhere near a real hang)
        assert time.perf_counter() - t0 < 60.0
        policy.release()             # heal: hung worker resumes
        outs = eng.generate(reqs, sps)
        for o, (ref_toks, ref_fin) in zip(outs, refs):
            assert list(o.tokens) == ref_toks
            assert o.finish_reason == ref_fin


# ----------------------------------------------------------- poisoned


def test_poisoned_writeback_does_not_wedge_engine(setup, sched):
    """Satellite (a): a write-back failure mid-generate_stream
    propagates as a typed TransferError, the abandoned stream drains
    its fences, and the SAME engine then serves a clean
    token-identical generate()."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy()
    with _engine(setup, sched, "offload", "static", policy,
                 io_retries=0) as eng:
        events = eng.generate_stream(reqs, sps)
        next(events)
        next(events)                 # decode is live, fills done
        policy.store_fail_rate = 1.0  # poison every write-back
        with pytest.raises(TransferError):
            list(events)
        policy.store_fail_rate = 0.0  # heal the link
        outs = eng.generate(reqs, sps)
    for o, (ref_toks, ref_fin) in zip(outs, refs):
        assert list(o.tokens) == ref_toks
        assert o.finish_reason == ref_fin


# ------------------------------------------------------------- ladder


def test_kernel_failure_degrades_to_oracle(setup, sched):
    """Rung 1: a failed Pallas launch drops the runtime to the jnp
    oracle (warned once), token-identically."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy(kernel_fail_launches=1)
    with _engine(setup, sched, "offload", "static", policy,
                 kernels=True) as eng:
        with pytest.warns(UserWarning, match="kernel"):
            outs = eng.generate(reqs, sps)
        assert eng.runtime._kernel_fallback
    assert policy.injected.get("kernel", 0) == 1
    for o, (ref_toks, ref_fin) in zip(outs, refs):
        assert list(o.tokens) == ref_toks
        assert o.finish_reason == ref_fin


def test_dead_link_degrades_to_full_recompute(setup, sched):
    """Rung 2: when every KV fetch fails, the step recomputes the
    whole prefix from activations (the paper's l=p endpoint) —
    token-identical, with the fallback counted in StepStats."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy(fetch_fail_rate=1.0)
    with _engine(setup, sched, "offload", "static", policy,
                 io_retries=0) as eng:
        with pytest.warns(UserWarning, match="recomput"):
            events = list(eng.generate_stream(reqs, sps))
        fallbacks = sum(e.stats.fetch_fallbacks for e in events
                        if e.stats is not None)
    assert fallbacks >= 1
    assert policy.injected.get("fetch", 0) >= 1
    for r, (ref_toks, _) in zip(reqs, refs):
        assert [e.token for e in events if e.uid == r.uid] == ref_toks


@pytest.mark.parametrize("backend,batching", [("offload", "static"),
                                              ("resident", "continuous")])
def test_restore_failure_falls_back_cold_and_invalidates(
        setup, sched, backend, batching):
    """Rung 3: a failed prefix-cache restore falls back to cold
    prefill and evicts the poisoned entry (lookups stop rediscovering
    it) — tokens identical to the never-cached run."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    refs = _reference(setup, sched, reqs, sps)
    policy = FaultPolicy(restore_fail_rate=1.0)
    with _engine(setup, sched, backend, batching, policy,
                 prefix_cache=PrefixCacheConfig()) as eng:
        for rnd in range(2):         # round 2 hits what round 1 stored
            if rnd == 0:             # cold round: nothing to restore
                outs = eng.generate(reqs, sps)
            else:
                with pytest.warns(UserWarning, match="restore"):
                    outs = eng.generate(reqs, sps)
            for o, (ref_toks, ref_fin) in zip(outs, refs):
                assert list(o.tokens) == ref_toks, (backend, batching,
                                                    rnd, o.uid)
                assert o.finish_reason == ref_fin
        st = eng.prefix_stats
        assert st.hits >= 1
        assert st.invalidations >= 1


# ---------------------------------------------------------- lifecycle


def test_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(fence_timeout_s=0.0).validate()
    with pytest.raises(ValueError):
        EngineConfig(io_retries=-1).validate()
    with pytest.raises(ValueError):
        EngineConfig(io_backoff_s=-0.1).validate()
    EngineConfig(fence_timeout_s=None).validate()   # wait-forever: ok


def test_double_close_and_close_during_stream(setup, sched):
    """Satellite (b): close() is idempotent at every layer, including
    with a stream abandoned mid-decode (its fences drain; no worker
    is left hung)."""
    cfg, _, _ = setup
    reqs, sps = _reqs(cfg), _sps()
    eng = _engine(setup, sched, "offload", "continuous", None)
    events = eng.generate_stream(reqs, sps)
    next(events)
    next(events)
    events.close()                   # abandon mid-decode: fences drain
    eng.close()
    eng.close()                      # idempotent
    eng.runtime.close()              # lower layers too
    eng.runtime.xfer.close()

    # resident engines own a restore pool instead of a runtime
    cfg2, model, params = setup
    eng2 = LLMEngine.from_config(
        model, params,
        EngineConfig(prefix_cache=PrefixCacheConfig()), scheduler=sched)
    eng2.generate(reqs[:1], sps[:1])
    eng2.close()
    eng2.close()
