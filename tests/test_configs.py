import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, pad_vocab


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_heads % cfg.num_kv_heads == 0
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    assert cfg.source  # every assigned config cites its pool entry


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 6
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def test_pad_vocab():
    assert pad_vocab(49155) == 49408
    assert pad_vocab(51865) == 51968
    assert pad_vocab(256) == 256


def test_assigned_pool_values():
    """Spot-check the exact assigned dims from the pool table."""
    c = get_config("mistral-nemo-12b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (40, 5120, 32, 8, 14336, 131072)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.moe.num_experts, c.moe.top_k) == (128, 8)
    c = get_config("internvl2-76b")
    assert (c.num_layers, c.d_model) == (80, 8192)
    c = get_config("zamba2-1.2b")
    assert c.ssm.state_dim == 64
    c = get_config("xlstm-350m")
    assert c.d_ff == 0 and c.arch_type == "ssm"
    c = get_config("gemma3-12b")
    assert c.global_every == 6 and c.sliding_window > 0
