"""Request-level serving API tests (serving.api): EngineConfig,
per-request SamplingParams, streaming TokenEvents, early EOS with
mid-decode slot reuse, and the cross-path sampling-stream invariant —
request uid's t-th token is fold_in(request_key, t) no matter which
backend, batching discipline, or batch composition executed it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                           LLMEngine, Request, SamplingParams)

COMBOS = [("resident", "static"), ("offload", "static"),
          ("resident", "continuous"), ("offload", "continuous")]


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def sched():
    return Scheduler(A100_PCIE4)


_ENGINES = []


@pytest.fixture(scope="module", autouse=True)
def _close_engines():
    """Close every engine the module created (thread-pool hygiene)."""
    yield
    while _ENGINES:
        _ENGINES.pop().close()


def _engine(setup, sched, backend, batching, **kw):
    cfg, model, params = setup
    eng = LLMEngine.from_config(
        model, params,
        EngineConfig(backend=backend, batching=batching, slots=2,
                     max_len=64, **kw), scheduler=sched)
    _ENGINES.append(eng)
    return eng


def _ref_greedy(model, params, prompt, gen):
    """Per-request greedy reference: plain prefill + decode_step."""
    toks = jnp.asarray(prompt)[None]
    lg, cache = model.prefill(params, toks, max_len=len(prompt) + gen + 2)
    out, tok = [], jnp.argmax(lg, axis=-1).astype(jnp.int32)
    for _ in range(gen):
        out.append(int(tok[0, 0]))
        lg, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return out


def _reqs(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, n).astype(np.int32), max_new_tokens=g)
        for i, (n, g) in enumerate(zip(lens, budgets))]


# Greedy identity against the per-request reference (all 4 combos x
# ragged prompts x chunked/inline prefill x ...) lives in the
# consolidated golden matrix: tests/test_identity_matrix.py.

# -------------------------------------- sampling-stream invariant (sat 2)

def test_sampling_stream_identical_across_all_paths(setup, sched):
    """Temperature sampling draws fold_in(request_key, t): one seed
    gives identical tokens on all four paths (the resident/offload
    parity the old engines kept via an O(gen_len) key-mirroring loop,
    now counter-derived by construction)."""
    cfg, _, _ = setup
    reqs = _reqs(cfg, [10, 10], [5, 5], seed=3)
    sp = SamplingParams(max_tokens=5, temperature=0.8)
    tokens = {}
    for backend, batching in COMBOS:
        eng = _engine(setup, sched, backend, batching, seed=7)
        tokens[(backend, batching)] = [list(o.tokens)
                                       for o in eng.generate(reqs, sp)]
    base = tokens[COMBOS[0]]
    for combo in COMBOS[1:]:
        assert tokens[combo] == base, combo
    # and the stream is genuinely non-greedy for at least one request
    greedy = [list(o.tokens) for o in _engine(
        setup, sched, "resident", "static", seed=7).generate(reqs)]
    assert any(g != t for g, t in zip(greedy, base))


# ------------------------------------- continuous sampler + seed (sat 1)

@pytest.mark.slow
@pytest.mark.parametrize("backend", ["resident", "offload"])
def test_continuous_temperature_seeded(setup, sched, backend):
    """The continuous engine must draw from the sampler path (not
    hardcoded argmax): temperature serving is non-greedy yet
    seed-deterministic, on both backends."""
    cfg, _, _ = setup
    reqs = _reqs(cfg, [8, 11, 14], [5, 4, 6], seed=1)
    sp = SamplingParams(max_tokens=5, temperature=0.9)
    a = _engine(setup, sched, backend, "continuous", seed=5
                ).generate(reqs, sp)
    b = _engine(setup, sched, backend, "continuous", seed=5
                ).generate(reqs, sp)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
    grd = _engine(setup, sched, backend, "continuous", seed=5
                  ).generate(reqs)
    assert any(not np.array_equal(g.tokens, t.tokens)
               for g, t in zip(grd, a))
    # legacy shim: engine-level sampler="temperature" rides the same
    # path (shim default maps to temperature=0.8, per-request budgets)
    cfg_, model, params = setup
    shim = ContinuousBatchingEngine(model, params, num_slots=2,
                                    max_len=64, mode=backend,
                                    scheduler=sched,
                                    sampler="temperature", seed=5)
    sps = [SamplingParams(max_tokens=r.max_new_tokens, temperature=0.8)
           for r in reqs]
    want = _engine(setup, sched, backend, "continuous", seed=5
                   ).generate(reqs, sps)
    try:
        for x, y in zip(shim.serve(reqs), want):
            np.testing.assert_array_equal(x.tokens, y.tokens)
    finally:
        shim.close()


# --------------------------------------------------- early EOS (sat 4)

def _eos_plan(model, params, prompt, budget):
    """Pick an EOS id that fires mid-request for this prompt, and the
    index (0-based) of its first greedy occurrence."""
    ref = _ref_greedy(model, params, prompt, budget)
    eos = ref[min(2, budget - 1)]
    return ref, eos, ref.index(eos)


@pytest.mark.parametrize("backend,batching", COMBOS)
def test_early_eos_finish_reason_and_token_count(setup, sched, backend,
                                                 batching):
    """EOS at step k: finish_reason == "stop", exactly k tokens (the
    stop token included), other requests unaffected."""
    cfg, model, params = setup
    lens = [10, 10] if batching == "static" else [9, 12]
    reqs = _reqs(cfg, lens, [6, 6], seed=4)
    ref0, eos, idx = _eos_plan(model, params, reqs[0].prompt, 6)
    sps = [SamplingParams(max_tokens=6, eos_id=int(eos)),
           SamplingParams(max_tokens=6)]
    eng = _engine(setup, sched, backend, batching)
    outs = eng.generate(reqs, sps)
    assert outs[0].finish_reason == "stop"
    assert list(outs[0].tokens) == ref0[:idx + 1]      # exactly k tokens
    # the non-EOS request is token-identical to a run without the
    # early-finisher
    alone = eng.generate([reqs[1]], sps[1])
    np.testing.assert_array_equal(outs[1].tokens, alone[0].tokens)
    assert outs[1].finish_reason == "length"


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["resident", "offload"])
def test_early_eos_frees_slot_for_admission(setup, sched, backend):
    """Continuous batching, 2 slots, 3 requests: the early-EOS request's
    slot is reclaimed and the queued request is admitted into it while
    the long request is still decoding (mid-decode), on both backends;
    offload events carry StepStats showing the re-admitted slot."""
    cfg, model, params = setup
    reqs = _reqs(cfg, [9, 12, 10], [10, 6, 4], seed=6)
    ref1, eos, idx = _eos_plan(model, params, reqs[1].prompt, 6)
    sps = [SamplingParams(max_tokens=10),
           SamplingParams(max_tokens=6, eos_id=int(eos)),
           SamplingParams(max_tokens=4)]
    eng = _engine(setup, sched, backend, "continuous")
    events = list(eng.generate_stream(reqs, sps))

    stop_step = next(e.step for e in events
                     if e.uid == 1 and e.finish_reason == "stop")
    admit_step = min(e.step for e in events if e.uid == 2)
    long_last = max(e.step for e in events if e.uid == 0)
    # with 2 slots and 3 requests, uid=2 only runs once a slot frees:
    # after uid=1's stop, while uid=0 is still mid-decode
    assert stop_step <= admit_step <= long_last
    assert admit_step < long_last          # genuinely mid-decode

    # exact lifecycle: uid=1 stopped after exactly idx+1 tokens, and
    # every request's tokens match its solo greedy reference
    toks = {u: [e.token for e in events if e.uid == u] for u in (0, 1, 2)}
    assert toks[1] == ref1[:idx + 1]
    for r, u in zip(reqs, (0, 1, 2)):
        if u == 1:
            continue
        assert toks[u] == _ref_greedy(model, params, r.prompt,
                                      sps[u].max_tokens)
    if backend == "offload":
        stepped = [e for e in events if e.stats is not None]
        assert stepped, "offload events must carry StepStats"
        # after re-admission the batch is ragged: per-slot splits appear
        assert any(e.stats.split_ls is not None for e in stepped)

    # non-EOS requests are token-identical to a run without the
    # early-finisher
    sps_no = [sps[0], SamplingParams(max_tokens=6), sps[2]]
    outs_no = _engine(setup, sched, backend, "continuous"
                      ).generate(reqs, sps_no)
    assert toks[0] == list(outs_no[0].tokens)
    assert toks[2] == list(outs_no[2].tokens)


# ------------------------------------------------------------ streaming

def test_stream_events_match_generate(setup, sched):
    cfg, _, _ = setup
    reqs = _reqs(cfg, [8, 11, 14], [5, 4, 6], seed=2)
    eng = _engine(setup, sched, "offload", "continuous")
    events = list(eng.generate_stream(reqs))
    outs = _engine(setup, sched, "offload", "continuous").generate(reqs)
    for r, o in zip(reqs, outs):
        evs = [e for e in events if e.uid == r.uid]
        assert [e.token for e in evs] == list(o.tokens)
        assert [e.index for e in evs] == list(range(len(evs)))
        fins = [e.finish_reason for e in evs if e.finish_reason]
        assert fins == [o.finish_reason]       # exactly one, the last
        assert evs[-1].finish_reason == o.finish_reason
    # engine steps never go backwards in the stream
    assert all(a.step <= b.step for a, b in zip(events, events[1:]))


@pytest.mark.slow
def test_mixed_batch_finish_reasons(setup, sched):
    """Acceptance: one batch mixing greedy, temperature, and early-EOS
    requests completes with the right per-request finish_reason."""
    cfg, model, params = setup
    reqs = _reqs(cfg, [10, 10, 10], [6, 6, 6], seed=8)
    ref0, eos, idx = _eos_plan(model, params, reqs[0].prompt, 6)
    sps = [SamplingParams(max_tokens=6, eos_id=int(eos)),
           SamplingParams(max_tokens=6, temperature=0.8, seed=13),
           SamplingParams(max_tokens=6)]
    eng = _engine(setup, sched, "offload", "static")
    outs = eng.generate(reqs, sps)
    assert [o.finish_reason for o in outs] == ["stop", "length",
                                               "length"]
    assert list(outs[0].tokens) == ref0[:idx + 1]
    # the greedy request is unaffected by its stochastic neighbors
    assert list(outs[2].tokens) == _ref_greedy(model, params,
                                               reqs[2].prompt, 6)
    # the seeded temperature request is reproducible
    outs2 = _engine(setup, sched, "offload", "static"
                    ).generate(reqs, sps)
    np.testing.assert_array_equal(outs[1].tokens, outs2[1].tokens)


def test_abandoned_stream_drains_fences(setup, sched):
    """Closing generate_stream mid-iteration (offload backend) must
    still drain the HostKVStore write-back fences — the engine stays
    usable and no store task is left in flight."""
    cfg, _, _ = setup
    reqs = _reqs(cfg, [10, 10], [6, 6], seed=10)
    eng = _engine(setup, sched, "offload", "static")
    stream = eng.generate_stream(reqs)
    for ev in stream:
        if ev.step >= 1:
            break
    stream.close()
    outs = eng.generate(reqs)           # fresh run on the same engine
    assert all(o.finish_reason == "length" for o in outs)


# ------------------------------------------------------- config surface

def test_engine_config_validation_and_mode_map():
    assert EngineConfig.from_mode("resident").batching == "static"
    assert EngineConfig.from_mode("continuous-offload") == EngineConfig(
        backend="offload", batching="continuous")
    for mode in ("resident", "offload", "continuous",
                 "continuous-offload"):
        assert EngineConfig.from_mode(mode).mode == mode
    with pytest.raises(ValueError, match="unknown mode"):
        EngineConfig.from_mode("continuous_offload")
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="gpu").validate()
    with pytest.raises(ValueError, match="batching"):
        EngineConfig(batching="dynamic").validate()
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0).validate()
    # chunked-prefill knobs
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk=0).validate()
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk="sometimes").validate()
    with pytest.raises(ValueError, match="batching='continuous'"):
        EngineConfig(prefill_chunk=8, max_step_tokens=16).validate()
    with pytest.raises(ValueError, match="requires prefill_chunk"):
        EngineConfig(batching="continuous",
                     max_step_tokens=16).validate()
    from repro.serving import PrefixCacheConfig
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefill_chunk=8,
                     prefix_cache=PrefixCacheConfig()).validate()
    EngineConfig(batching="continuous", prefill_chunk="auto",
                 max_step_tokens=16).validate()


# ------------------------------------------------ runtime step callback

def test_decode_on_token_hook(setup, sched):
    """OffloadDecodeRuntime.decode streams per-step tokens through
    on_token; a truthy return stops decoding early."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    toks = rng.integers(1, cfg.vocab_size, (2, 10)).astype(np.int32)
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    store = HostKVStore(cfg, 2, 10 + 8 + 2)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), 10)
    seen = []

    def hook(step, tokens, stats):
        seen.append((step, tuple(int(t) for t in tokens)))
        assert stats.t_total > 0
        return step == 2           # stop after the third token

    with OffloadDecodeRuntime(cfg, params, A100_PCIE4, mode="kvpr",
                              scheduler=sched) as rt:
        out, stats = rt.decode(store, first, 8, on_token=hook)
    assert len(seen) == 3 and [s for s, _ in seen] == [0, 1, 2]
    assert out.shape == (2, 3) and len(stats) == 3
