"""int4 KV quantization: numpy/jnp round-trip, the fused dequant
attention kernel vs its oracle, and the executable int4 offload path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvquant as KQ
from repro.kernels import decode_attention as DA
from repro.kernels import kv_dequant_attention as DQA
from repro.kernels import ref

try:  # optional dep, see docs/automation.md — only gates the
    # property-based round-trip test, not the rest of this module
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ round trip

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 6),
           st.sampled_from([32, 64, 128]), st.integers(0, 2**31 - 1))
    def test_quant_roundtrip_np(b, s, dh, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(b, s, dh)).astype(np.float32) * 3.0
        q = KQ.quantize_np(x)
        y = KQ.dequantize_np(q)
        # max error within a group is scale/2 = (range/15)/2
        rng_ = x.reshape(b, s, dh // 32, 32)
        half_scale = (rng_.max(-1) - rng_.min(-1)) / 15.0 / 2.0 + 1e-6
        err = np.abs((y - x).reshape(b, s, dh // 32, 32)).max(-1)
        assert (err <= half_scale + 1e-5).all()
        assert q.nbytes < x.nbytes / 4  # ⅛ codes + scales overhead < ¼


def test_np_jnp_agree():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 5, 2, 64)).astype(np.float32)
    qn = KQ.quantize_np(x)
    pj, sj, zj = KQ.quantize_jnp(jnp.asarray(x))
    np.testing.assert_array_equal(qn.packed, np.asarray(pj))
    np.testing.assert_allclose(qn.scale, np.asarray(sj), rtol=1e-6)
    yn = KQ.dequantize_np(qn)
    yj = KQ.dequantize_jnp(pj, sj, zj)
    np.testing.assert_allclose(yn, np.asarray(yj), rtol=1e-5, atol=1e-5)


# ------------------------------------------------- fused dequant kernel

@pytest.mark.parametrize("b,KV,g,dh,S,valid", [
    (1, 1, 4, 64, 16, 16),
    (2, 2, 2, 128, 64, 37),
    (1, 4, 8, 64, 128, 128),
])
def test_dequant_kernel_vs_oracle(b, KV, g, dh, S, valid):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, KV, g, dh), jnp.float32)
    k = jax.random.normal(kk, (b, KV, S, dh), jnp.float32)
    v = jax.random.normal(kv, (b, KV, S, dh), jnp.float32)
    kp, ks, kz = KQ.quantize_jnp(k)
    vp, vs, vz = KQ.quantize_jnp(v)

    out, m, l = DQA.flash_decode_segment_int4(
        q, kp, ks, kz, vp, vs, vz, jnp.int32(valid), interpret=True)
    # oracle: dequantize then exact flash-decode reference
    kd = KQ.dequantize_jnp(kp, ks, kz)
    vd = KQ.dequantize_jnp(vp, vs, vz)
    oref, mref, lref = ref.flash_decode_segment_ref(q, kd, vd, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lref),
                               rtol=1e-4, atol=1e-4)


def test_mixed_precision_segment_combine():
    """KVPR + int4: exact bf16 recomputed segment combines with an int4
    streamed segment; result ≈ full-precision attention over the concat."""
    key = jax.random.PRNGKey(1)
    b, KV, g, dh, S1, S2 = 1, 2, 4, 64, 32, 64
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, KV, g, dh), jnp.float32)
    k1 = jax.random.normal(ks[1], (b, KV, S1, dh), jnp.float32)
    v1 = jax.random.normal(ks[2], (b, KV, S1, dh), jnp.float32)
    k2 = jax.random.normal(ks[3], (b, KV, S2, dh), jnp.float32)
    v2 = jax.random.normal(ks[4], (b, KV, S2, dh), jnp.float32)

    p1 = DA.flash_decode_segment(q, k1, v1, jnp.int32(S1), interpret=True)
    kp, ksc, kz = KQ.quantize_jnp(k2)
    vp, vsc, vz = KQ.quantize_jnp(v2)
    p2 = DQA.flash_decode_segment_int4(q, kp, ksc, kz, vp, vsc, vz,
                                       jnp.int32(S2), interpret=True)
    out = DA.combine_segments([p1, p2])

    # full-precision oracle over the dequantized concat
    kd = jnp.concatenate([k1, KQ.dequantize_jnp(kp, ksc, kz)], axis=2)
    vd = jnp.concatenate([v1, KQ.dequantize_jnp(vp, vsc, vz)], axis=2)
    oref, _, _ = ref.flash_decode_segment_ref(q, kd, vd, S1 + S2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------- executable int4 offload

def test_int4_offload_serving_close_and_smaller():
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 16).astype(np.int32), max_new_tokens=4)
        for i in range(2)]
    exact = ServingEngine(model, params, mode="offload").serve(reqs)
    quant = ServingEngine(model, params, mode="offload",
                          compress="int4").serve(reqs)
    # int4 KV is lossy: require high token agreement, not exactness
    agree = np.mean([np.mean(e.tokens == c.tokens)
                     for e, c in zip(exact, quant)])
    assert agree >= 0.5, f"int4 decode diverged too much: {agree}"


def test_int4_never_materialized_with_kernels(monkeypatch):
    """With the kernel path on, the packed streamed KV goes straight to
    the fused dequant-attend kernel — the jnp dequantize pass must never
    run during decode.  Poisoning runtime.KQ.dequantize_jnp proves it."""
    from repro.configs import get_smoke_config
    from repro.core import runtime as RT
    from repro.models.transformer import Model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 12).astype(np.int32), max_new_tokens=4)
        for i in range(2)]

    def boom(*a, **k):
        raise AssertionError(
            "int4 KV materialized at fp precision outside the kernel")

    monkeypatch.setattr(RT.KQ, "dequantize_jnp", boom)
    with ServingEngine(model, params, mode="offload", compress="int4",
                       kernels=True) as eng:
        outs = eng.serve(reqs)
    assert all(len(o.tokens) == 4 for o in outs)
    assert eng.runtime.compute.kernel_path


def test_int4_store_bytes_reduction():
    from repro.configs import get_smoke_config
    from repro.core.runtime import HostKVStore
    cfg = get_smoke_config("opt-6.7b")
    full = HostKVStore(cfg, 2, 64)
    q4 = HostKVStore(cfg, 2, 64, compress="int4")
    full_kv = full.k.nbytes + full.v.nbytes
    q4_kv = q4.kq.nbytes + q4.vq.nbytes
    assert q4_kv < full_kv / 4
