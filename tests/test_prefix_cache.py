"""Shared-prefix KV cache: radix-index semantics (partial-edge matches,
eviction, pruning), the scheduler's restore-split decision, and
end-to-end hit/eviction/partial-match serving identity — a prefix-cache
hit must emit tokens IDENTICAL to a cold-cache run."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                     PrefixEntry, RadixPrefixIndex)
from repro.core.runtime import restore_prefix_kv
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, LLMEngine, Request,
                           SamplingParams)

COMBOS = [("resident", "static"), ("offload", "static"),
          ("resident", "continuous"), ("offload", "continuous")]


def _entry(tokens):
    p = len(tokens)
    z = np.zeros((2, 1, p, 2, 4), np.float32)
    return PrefixEntry(tuple(tokens), z, z.copy(),
                       np.zeros((2, 1, p, 8), np.float32))


# ------------------------------------------------------------ the index

def test_radix_index_exact_and_nested_matches():
    idx = RadixPrefixIndex()
    idx.insert((1, 2, 3, 4), _entry((1, 2, 3, 4)))
    idx.insert((1, 2, 3, 4, 5, 6), _entry((1, 2, 3, 4, 5, 6)))
    assert idx.size == 2
    n, e = idx.match([1, 2, 3, 4])
    assert n == 4 and e.tokens[:4] == (1, 2, 3, 4)
    n, e = idx.match([1, 2, 3, 4, 5, 6, 7])
    assert n == 6 and e.tokens == (1, 2, 3, 4, 5, 6)
    n, e = idx.match([9, 9])
    assert n == 0 and e is None


def test_radix_index_partial_edge_match():
    """A query diverging mid-edge still matches the shared span: every
    entry under the edge covers those tokens ('prefix longer than the
    match' costs nothing)."""
    idx = RadixPrefixIndex()
    idx.insert((1, 2, 3, 4, 5), _entry((1, 2, 3, 4, 5)))
    n, e = idx.match([1, 2, 3, 9, 9])
    assert n == 3 and e.tokens == (1, 2, 3, 4, 5)
    # query shorter than the stored entry: full-query cover
    n, e = idx.match([1, 2, 3])
    assert n == 3 and e.tokens == (1, 2, 3, 4, 5)


def test_radix_index_remove_prunes():
    idx = RadixPrefixIndex()
    idx.insert((1, 2, 3), _entry((1, 2, 3)))
    idx.insert((1, 2, 9), _entry((1, 2, 9)))
    assert idx.remove((1, 2, 3)) and not idx.remove((1, 2, 3))
    assert idx.size == 1
    n, e = idx.match([1, 2, 3])
    assert n == 2 and e.tokens == (1, 2, 9)      # shared span survives
    assert idx.remove((1, 2, 9)) and idx.size == 0
    assert idx.match([1, 2, 9]) == (0, None)
    assert not idx.root.children                 # fully pruned


# ------------------------------------------------------------ the cache

def test_prefix_cache_lookup_caps_and_min_prefix():
    pc = PrefixCache(PrefixCacheConfig(min_prefix=4))
    toks = np.arange(1, 9, dtype=np.int32)
    z = np.zeros((2, 1, 8, 2, 4), np.float32)
    h = np.zeros((2, 1, 8, 8), np.float32)
    assert pc.insert(toks, z, z, h)
    # whole-prompt match is capped at len-1 (one token must prefill)
    p, e = pc.lookup(toks)
    assert p == 7 and e is not None
    # below min_prefix -> miss
    p, e = pc.lookup(np.array([1, 2, 3, 99], np.int32))
    assert (p, e) == (0, None)
    # re-inserting a covered prompt is a no-op
    assert not pc.insert(toks, z, z, h)
    assert pc.stats.entries == 1


def test_prefix_cache_lru_eviction():
    pc = PrefixCache(PrefixCacheConfig(capacity_tokens=16, min_prefix=4))
    z8 = np.zeros((2, 1, 8, 2, 4), np.float32)
    h8 = np.zeros((2, 1, 8, 8), np.float32)
    a = np.arange(1, 9, dtype=np.int32)
    b = np.arange(11, 19, dtype=np.int32)
    c = np.arange(21, 29, dtype=np.int32)
    pc.insert(a, z8, z8, h8)
    pc.insert(b, z8, z8, h8)
    pc.lookup(a)                          # a is now more recent than b
    pc.insert(c, z8, z8, h8)              # 24 tokens > 16 -> evict b
    st = pc.stats
    assert st.evictions == 1 and st.tokens_stored == 16
    assert pc.lookup(np.concatenate([b, [99]]))[1] is None
    assert pc.lookup(np.concatenate([a, [99]]))[1] is not None


def test_prefix_cache_peek_does_not_touch_lru():
    """peek() predicts lookup()'s match exactly but never counts as
    use: after peeking the LRU entry it is STILL the eviction victim,
    while a real lookup saves it (the router placement probe must not
    distort eviction order)."""
    z8 = np.zeros((2, 1, 8, 2, 4), np.float32)
    h8 = np.zeros((2, 1, 8, 8), np.float32)
    a = np.arange(1, 9, dtype=np.int32)
    b = np.arange(11, 19, dtype=np.int32)
    c = np.arange(21, 29, dtype=np.int32)
    qa = np.concatenate([a, [99]]).astype(np.int32)
    qb = np.concatenate([b, [99]]).astype(np.int32)

    def fresh():
        pc = PrefixCache(PrefixCacheConfig(capacity_tokens=16,
                                           min_prefix=4))
        pc.insert(a, z8, z8, h8)             # a is the LRU entry
        pc.insert(b, z8, z8, h8)
        return pc

    # peek agrees with lookup's prediction but mutates nothing
    pc = fresh()
    p, e = pc.peek(qa)
    assert p == 8 and e is not None and e.hits == 0
    st = pc.stats
    assert st.peeks == 1 and st.lookups == 0 and st.hits == 0

    # peeking `a` five more times does NOT refresh it: inserting c
    # still evicts a
    for _ in range(5):
        pc.peek(qa)
    pc.insert(c, z8, z8, h8)
    assert pc.peek(qa) == (0, None)                  # a evicted
    assert pc.peek(qb)[1] is not None                # b survived

    # ...while ONE real lookup refreshes a: the same insert evicts b
    pc = fresh()
    assert pc.lookup(qa)[0] == 8
    pc.insert(c, z8, z8, h8)
    assert pc.peek(qb) == (0, None)                  # b evicted
    assert pc.peek(qa)[1] is not None                # a survived


# --------------------------------------------------- the restore split

def test_restore_split_modes():
    """MHA (kv_dim == d_model): recomputing from activations beats
    streaming K+V, so the split is interior; flexgen restores stream
    everything; GQA (2*kv_dim <= d_model) streams everything too, by
    the same byte arithmetic."""
    sched = Scheduler(A100_PCIE4)
    mha = get_smoke_config("opt-6.7b")
    d = sched.restore_split(mha, 64)
    assert 0 < d.l <= 64
    assert sched.restore_split(mha, 64, mode="flexgen").l == 0
    gqa = get_smoke_config("tinyllama-1.1b")
    assert sched.restore_split(gqa, 64).l == 0


def test_restore_prefix_kv_exact():
    """restore_prefix_kv(split) reproduces the entry's KV exactly:
    the streamed tail verbatim, the recomputed head from activations
    through the same GEMM+RoPE the prefill ran."""
    from repro.core.runtime import TransferEngine, \
        prefill_with_activations
    import jax.numpy as jnp
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (1, 12)).astype(np.int32)
    _, ks, vs, hs = prefill_with_activations(model, params,
                                             jnp.asarray(toks))
    ks, vs, hs = np.asarray(ks), np.asarray(vs), np.asarray(hs)
    xfer = TransferEngine(1)
    try:
        for l in (0, 5, 12):
            k_dev, v_dev, st = restore_prefix_kv(
                cfg, params, ks, vs, hs, p=12, split_l=l, xfer=xfer)
            assert (st.recomputed, st.streamed) == (l, 12 - l)
            np.testing.assert_allclose(np.asarray(k_dev), ks[:, :, :12],
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(v_dev), vs[:, :, :12],
                                       rtol=1e-5, atol=1e-5)
            assert st.bytes_streamed > 0
    finally:
        xfer.close()


# ------------------------------------------------------------ end to end

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def sched():
    return Scheduler(A100_PCIE4)


def _family(cfg, seed=0, shared=12, tails=(3, 5)):
    rng = np.random.default_rng(seed)
    base = rng.integers(1, cfg.vocab_size, shared).astype(np.int32)
    return [np.concatenate([base, rng.integers(
        1, cfg.vocab_size, t).astype(np.int32)]) for t in tails]


@pytest.mark.slow
@pytest.mark.parametrize("backend,batching", COMBOS)
def test_prefix_hit_identical_to_cold(tiny_setup, sched, backend,
                                      batching):
    """Acceptance: a second generate() sharing an N-token prefix skips
    prefill for the matched tokens while emitting tokens identical to
    the cold-cache run — on every backend x batching combo."""
    cfg, model, params = tiny_setup
    p1, p2 = _family(cfg, seed=1)
    config = EngineConfig(backend=backend, batching=batching, slots=2,
                          max_len=64)
    with LLMEngine.from_config(model, params, config,
                               scheduler=sched) as cold:
        ref = cold.generate([Request(0, p2, 5)])[0]
    warm_cfg = dataclasses.replace(config,
                                   prefix_cache=PrefixCacheConfig())
    with LLMEngine.from_config(model, params, warm_cfg,
                               scheduler=sched) as eng:
        eng.generate([Request(0, p1, 4)])
        out = eng.generate([Request(1, p2, 5)])[0]
        st = eng.prefix_stats
    np.testing.assert_array_equal(out.tokens, ref.tokens)
    assert out.cached_prefix == 12               # the shared prefix
    assert out.restore is not None
    assert out.restore.recomputed + out.restore.streamed == 12
    assert st.hits == 1 and st.tokens_matched == 12


@pytest.mark.slow
def test_prefix_partial_match_and_batch_hit(tiny_setup, sched):
    """One static batch mixing a full hit, a PARTIAL match (prompt
    diverging inside the cached prefix), and a cold prompt — all
    token-identical to cold serving."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(5)
    base = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    full = np.concatenate([base, rng.integers(
        1, cfg.vocab_size, 4).astype(np.int32)])
    diverge = np.concatenate([base[:7], rng.integers(
        1, cfg.vocab_size, 6).astype(np.int32)])
    cold_p = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
    reqs = [Request(i, p, 4) for i, p in enumerate(
        (full, diverge, cold_p))]
    config = EngineConfig(backend="offload")
    with LLMEngine.from_config(model, params, config,
                               scheduler=sched) as cold:
        refs = cold.generate(reqs)
    warm_cfg = dataclasses.replace(config,
                                   prefix_cache=PrefixCacheConfig())
    with LLMEngine.from_config(model, params, warm_cfg,
                               scheduler=sched) as eng:
        eng.generate([Request(9, base, 4)])      # seed the cache
        outs = eng.generate(reqs)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o.tokens, r.tokens)
    assert outs[0].cached_prefix == 12           # full prefix restored
    assert outs[1].cached_prefix == 7            # partial-edge match
    assert outs[2].cached_prefix == 0            # cold


def test_prefix_eviction_end_to_end(tiny_setup, sched):
    """With a capacity of one prompt, serving a second family evicts
    the first: re-serving family A misses (cached_prefix == 0) but
    stays token-identical."""
    cfg, model, params = tiny_setup
    a1, a2 = _family(cfg, seed=2)
    b1, _ = _family(cfg, seed=3)
    warm_cfg = EngineConfig(
        backend="offload",
        prefix_cache=PrefixCacheConfig(capacity_tokens=20))
    with LLMEngine.from_config(model, params,
                               EngineConfig(backend="offload"),
                               scheduler=sched) as cold:
        ref = cold.generate([Request(0, a2, 4)])[0]
    with LLMEngine.from_config(model, params, warm_cfg,
                               scheduler=sched) as eng:
        eng.generate([Request(0, a1, 4)])        # insert A (15 tokens)
        eng.generate([Request(1, b1, 4)])        # insert B -> evict A
        out = eng.generate([Request(2, a2, 4)])[0]
        st = eng.prefix_stats
    assert st.evictions >= 1
    assert out.cached_prefix == 0                # A was evicted
    np.testing.assert_array_equal(out.tokens, ref.tokens)


def test_prefix_insert_on_finish_streaming(tiny_setup, sched):
    """Insertion happens when the request FINISHES: a second stream
    over the same prompt family hits the prefix the first inserted."""
    cfg, model, params = tiny_setup
    p1, p2 = _family(cfg, seed=4)
    warm_cfg = EngineConfig(backend="offload", batching="continuous",
                            slots=2, max_len=64,
                            prefix_cache=PrefixCacheConfig())
    with LLMEngine.from_config(model, params, warm_cfg,
                               scheduler=sched) as eng:
        list(eng.generate_stream([Request(0, p1, 3)]))
        outs = eng.generate([Request(1, p2, 3)])
        assert outs[0].cached_prefix == 12
        assert eng.prefix_stats.entries == 2


def test_prefix_cache_rejects_unsupported_arch(sched):
    cfg = get_smoke_config("zamba2-1.2b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="dense"):
        LLMEngine.from_config(
            model, params,
            EngineConfig(prefix_cache=PrefixCacheConfig()),
            scheduler=sched)
