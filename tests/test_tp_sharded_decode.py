"""Multi-device halves of the mesh-sharded pipeline, on an emulated
8-CPU-device mesh (docs/scaling.md): finding-2 tensor-parallel decode
param placement (``launch.mesh.place_tp_decode_params``) and the exact
sequence-parallel chunked-prefill combine
(``models.seq_parallel.seq_sharded_prefill_chunk_attend`` /
``seq_sharded_update_kv_chunk``), each checked against a dense
single-array reference.  Like every multi-device test, the mesh half
runs in a subprocess — this test process is pinned to 1 device
(see tests/conftest.py::xla_device_count)."""
import os
import subprocess
import sys

import pytest

from conftest import xla_device_count

_SUBPROC = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import MeshConfig, place_tp_decode_params
from repro.models import seq_parallel as SPAR
from repro.models.sharding import DEFAULT_RULES, logical_rules
from repro.models.transformer import Model

mesh = MeshConfig(model=2, data=4).build()
assert mesh.axis_names == ("data", "model")
try:
    MeshConfig(model=4, data=4).build()
    raise AssertionError("16-device mesh built on 8 devices")
except ValueError:
    pass

# ---- seq-sharded chunked prefill vs the dense reference ------------
b, S, KV, g, dh, w = 2, 32, 4, 2, 16, 6      # S_loc = 32/4 = 8
H = KV * g
ks = jax.random.split(jax.random.PRNGKey(0), 5)
k_cache = jax.random.normal(ks[0], (b, S, KV, dh))
v_cache = jax.random.normal(ks[1], (b, S, KV, dh))


def ref_attend(q, kc, vc, kn, vn, p0):
    keys = jnp.concatenate([kc[:, :p0], kn], 1).astype(jnp.float32)
    vals = jnp.concatenate([vc[:, :p0], vn], 1).astype(jnp.float32)
    qg = q.reshape(b, w, KV, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bwkgd,bskd->bkgws", qg, keys) / jnp.sqrt(dh)
    pos_k = jnp.arange(p0 + w)
    allow = (pos_k[None, :] < p0) | \
        (pos_k[None, :] - p0 <= jnp.arange(w)[:, None])
    scores = jnp.where(allow[None, None, None], scores, -1e30)
    out = jnp.einsum("bkgws,bskd->bkgwd",
                     jax.nn.softmax(scores, -1), vals)
    return jnp.moveaxis(out, 3, 1).reshape(b, w, H, dh)


# p0 = 5 and 13 straddle shard boundaries (the windowed RMW path)
for p0 in (0, 5, 8, 13):
    q = jax.random.normal(ks[2], (b, w, H, dh))
    k_new = jax.random.normal(ks[3], (b, w, KV, dh))
    v_new = jax.random.normal(ks[4], (b, w, KV, dh))
    with logical_rules(dict(DEFAULT_RULES), mesh):
        with mesh:
            out = SPAR.seq_sharded_prefill_chunk_attend(
                q, k_cache, v_cache, k_new, v_new, p0)
            kc2, vc2 = SPAR.seq_sharded_update_kv_chunk(
                k_cache, v_cache, k_new, v_new, p0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_attend(
            q, k_cache, v_cache, k_new, v_new, p0)),
        rtol=2e-5, atol=2e-5, err_msg=f"attend p0={p0}")
    for got, cache, new in ((kc2, k_cache, k_new),
                            (vc2, v_cache, v_new)):
        want = np.asarray(cache).copy()
        want[:, p0:p0 + w] = np.asarray(new)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"update p0={p0}")
print("SEQ_CHUNK_OK")

# ---- finding-2 TP decode placement ---------------------------------
cfg = get_smoke_config("tinyllama-1.1b")
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
placed = place_tp_decode_params(cfg, params, mesh)
before = jax.tree_util.tree_leaves(params)
after = jax.tree_util.tree_leaves(placed)
assert len(before) == len(after)
for x, y in zip(before, after):      # placement must not change values
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
specs = [str(getattr(y.sharding, "spec", "")) for y in after]
assert any("model" in s for s in specs), specs    # TP over "model"
assert all("data" not in s for s in specs), specs  # FSDP off: no
                                                   # per-token regather
print("TP_PLACE_OK")
"""


@pytest.mark.slow
def test_seq_chunk_and_tp_placement_on_mesh():
    env = xla_device_count(8)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SEQ_CHUNK_OK" in r.stdout and "TP_PLACE_OK" in r.stdout, \
        r.stdout + r.stderr
