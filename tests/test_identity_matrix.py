"""Cross-combo golden identity matrix.

ONE parametrized suite asserting token-identity against the per-request
reference across all 4 backend x batching combos x scenario:

  ragged      left-padded static batches / per-slot ragged continuous
  chunked     chunked prefill (+ token-budgeted mixed steps under
              continuous batching) vs the inline-prefill reference
  early_eos   a request stopping mid-decode (exact token count, no
              cross-request interference)
  mixed       greedy + seeded-temperature requests in one batch
  prefix      shared-prefix KV cache warm hits (restore + suffix
              prefill) vs the cold reference
  kernels     Pallas decode hot path (kernels=True: interpret mode on
              this CPU container, native on TPU) vs the jnp-oracle
              reference on all four combos (the knob is a no-op on the
              resident backend, which pins the reference)
  tiered      tiered KV store with host capacity below the working set
              (cold blocks demoted to the mmap disk tier, decoded via
              the tier_split plan) vs the all-DRAM reference; like
              kernels, kv_tiers is a no-op on the resident backend
  sharded     mesh-sharded decode (test_identity_matrix_sharded): 1x1
              vs 2-way vs 4-way model-axis meshes against the
              per-request single-device reference on all four combos,
              plus prefix-cache-warm and tiered-store variants.  The
              mesh knob shards the offload DATA PLANE (per-shard KV
              head-slice streams + per-shard plan solves) and is a
              no-op on the resident backend, which pins the reference.
              Runs on a 4-KV-head config so every mesh divides.

The per-request reference for EVERY scenario is a fresh batch-1
resident/static engine run with the same engine seed and request uid —
the sampling-stream invariant (token t of uid is fold_in(request_key,
t)) makes that the ground truth for greedy AND stochastic requests.

This suite consolidates the ad-hoc identity checks that used to live in
test_api.py (test_generate_matches_greedy_reference) and overlapping
end-to-end assertions in test_ragged.py; those modules keep their
unit-level coverage.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, KVTiersConfig, LLMEngine,
                           MeshConfig, PrefixCacheConfig, Request,
                           SamplingParams)

COMBOS = [("resident", "static"), ("offload", "static"),
          ("resident", "continuous"), ("offload", "continuous")]
SCENARIOS = ["ragged", "chunked",
             pytest.param("chunked_auto", marks=pytest.mark.slow),
             pytest.param("early_eos", marks=pytest.mark.slow),
             pytest.param("mixed", marks=pytest.mark.slow),
             pytest.param("prefix", marks=pytest.mark.slow),
             "kernels", "tiered"]

LENS = [8, 11, 14]


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def sched():
    return Scheduler(A100_PCIE4)


def _reqs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, n).astype(np.int32)) for i, n in
        enumerate(LENS)]


_REFS = {}


def _reference(setup, sched, reqs, sps):
    """Per-request ground truth: batch-1 resident/static runs (same
    engine seed, same uid => same sampling stream), memoized."""
    cfg, model, params = setup
    outs = []
    for r, sp in zip(reqs, sps):
        key = (r.uid, r.prompt.tobytes(), sp)
        if key not in _REFS:
            with LLMEngine.from_config(model, params, EngineConfig(),
                                       scheduler=sched) as eng:
                o = eng.generate([r], sp)[0]
            _REFS[key] = (list(o.tokens), o.finish_reason)
        outs.append(_REFS[key])
    return outs


def _eos_for(setup, sched, req, budget):
    """An id the greedy stream emits mid-request (forces early EOS)."""
    toks, _ = _reference(setup, sched, [req],
                         [SamplingParams(max_tokens=budget)])[0]
    return int(toks[2])


def _scenario(name, setup, sched):
    """Returns (requests, sampling params, extra EngineConfig kwargs
    keyed by batching, n_serve_rounds)."""
    cfg, _, _ = setup
    reqs = _reqs(cfg)
    kw = {"static": {}, "continuous": {}}
    rounds = 1
    if name == "ragged":
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
    elif name == "chunked":
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
        kw = {"static": dict(prefill_chunk=5),
              "continuous": dict(prefill_chunk=5, max_step_tokens=6)}
    elif name == "chunked_auto":
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
        kw = {"static": dict(prefill_chunk="auto"),
              "continuous": dict(prefill_chunk="auto",
                                 max_step_tokens=8)}
    elif name == "early_eos":
        eos = _eos_for(setup, sched, reqs[0], 6)
        sps = [SamplingParams(max_tokens=6, eos_id=eos),
               SamplingParams(max_tokens=4),
               SamplingParams(max_tokens=5)]
    elif name == "mixed":
        sps = [SamplingParams(max_tokens=5, temperature=0.8, seed=11),
               SamplingParams(max_tokens=5),
               SamplingParams(max_tokens=4, temperature=0.6, seed=3)]
    elif name == "prefix":
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
        pc = dict(prefix_cache=PrefixCacheConfig())
        kw = {"static": pc, "continuous": dict(pc)}
        rounds = 2        # round 2 must hit the prefixes round 1 stored
    elif name == "kernels":
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
        kw = {"static": dict(kernels=True),
              "continuous": dict(kernels=True)}
    elif name == "tiered":
        # host capacity well below the working set, so disk-resident
        # sessions decode through the tier_split plan (lossless raw
        # layout); a no-op on the resident backend, which pins the
        # reference
        sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
        kt = dict(kv_tiers=KVTiersConfig(host_capacity_tokens=24,
                                         block_tokens=8))
        kw = {"static": kt, "continuous": dict(kt)}
        rounds = 2        # round 2 re-fills slots the disk tier served
    else:
        raise AssertionError(name)
    return reqs, sps, kw, rounds


@pytest.mark.parametrize("backend,batching", COMBOS)
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_identity_matrix(setup, sched, backend, batching, scenario):
    cfg, model, params = setup
    reqs, sps, kw, rounds = _scenario(scenario, setup, sched)
    refs = _reference(setup, sched, reqs, sps)
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend=backend, batching=batching, slots=2,
                         max_len=64, **kw[batching]),
            scheduler=sched) as eng:
        for rnd in range(rounds):
            outs = eng.generate(reqs, sps)
            for r, o, (ref_toks, ref_fin) in zip(reqs, outs, refs):
                assert list(o.tokens) == ref_toks, \
                    (scenario, backend, batching, rnd, r.uid)
                assert o.finish_reason == ref_fin, \
                    (scenario, backend, batching, rnd, r.uid)
        if scenario == "prefix":
            # the warm round genuinely restored instead of prefilled
            assert sum(o.cached_prefix for o in outs) > 0
            assert eng.prefix_stats.hits > 0


# ------------------------------------------------- sharded scenario

# model-axis mesh sizes the sharded scenario sweeps; 1 is the explicit
# 1x1 mesh (must degenerate bit-exactly, not just token-exactly — the
# scheduler props suite covers the plan side of that claim)
SHARD_MESHES = [1, 2, 4]
SHARD_VARIANTS = ["plain",
                  pytest.param("prefix", marks=pytest.mark.slow),
                  pytest.param("tiered", marks=pytest.mark.slow)]


@pytest.fixture(scope="module")
def setup4():
    """4-KV-head variant of the smoke config (g = 2 GQA) so the 2- and
    4-way model axes both divide the head count."""
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                              num_kv_heads=4)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


_REFS4 = {}


def _reference4(setup4, sched, reqs, sps):
    """Single-device per-request ground truth for the 4-KV-head model
    (resident/static, batch 1, no mesh), memoized like _REFS."""
    cfg, model, params = setup4
    outs = []
    for r, sp in zip(reqs, sps):
        key = (r.uid, r.prompt.tobytes(), sp)
        if key not in _REFS4:
            with LLMEngine.from_config(model, params, EngineConfig(),
                                       scheduler=sched) as eng:
                o = eng.generate([r], sp)[0]
            _REFS4[key] = (list(o.tokens), o.finish_reason)
        outs.append(_REFS4[key])
    return outs


@pytest.mark.parametrize("backend,batching", COMBOS)
@pytest.mark.parametrize("variant", SHARD_VARIANTS)
def test_identity_matrix_sharded(setup4, sched, backend, batching,
                                 variant):
    """Every model-axis mesh size is token-identical to the
    per-request single-device reference: the mesh shards only the data
    plane (per-shard head-slice copy streams merge byte-identically
    into the same staging buffers) and re-keys the plans, so tokens
    cannot move.  The prefix variant's warm round restores through the
    per-shard restore split; the tiered variant decodes disk-resident
    sessions through the per-shard tier_split plan."""
    cfg, model, params = setup4
    reqs = _reqs(cfg)
    sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
    refs = _reference4(setup4, sched, reqs, sps)
    kw, rounds = {}, 1
    if variant == "prefix":
        kw, rounds = dict(prefix_cache=PrefixCacheConfig()), 2
    elif variant == "tiered":
        kw, rounds = dict(kv_tiers=KVTiersConfig(
            host_capacity_tokens=24, block_tokens=8)), 2
    for k in SHARD_MESHES:
        with LLMEngine.from_config(
                model, params,
                EngineConfig(backend=backend, batching=batching,
                             slots=2, max_len=64,
                             mesh=MeshConfig(model=k), **kw),
                scheduler=sched) as eng:
            for rnd in range(rounds):
                outs = eng.generate(reqs, sps)
                for r, o, (ref_toks, ref_fin) in zip(reqs, outs, refs):
                    assert list(o.tokens) == ref_toks, \
                        (variant, backend, batching, k, rnd, r.uid)
                    assert o.finish_reason == ref_fin, \
                        (variant, backend, batching, k, rnd, r.uid)
            if variant == "prefix":
                assert eng.prefix_stats.hits > 0, (backend, batching, k)


# router tier: resident/static in the fast lane, the rest ride the
# slow one (each case builds two replica engines)
ROUTER_COMBOS = [COMBOS[0]] + [pytest.param(*c, marks=pytest.mark.slow)
                               for c in COMBOS[1:]]


@pytest.mark.parametrize("backend,batching", ROUTER_COMBOS)
def test_router_identity_matrix(setup, sched, backend, batching):
    """Routed outputs are token-identical to the per-request single-
    engine reference on every backend x batching combo — placement is
    an execution decision, never a semantics decision.  Replicas share
    the engine seed, so uid alone pins each request's sampling stream
    no matter which replica serves it (mixed greedy + seeded
    temperature, same params as the `mixed` scenario)."""
    from repro.serving.router import RouterConfig, RouterEngine
    cfg, model, params = setup
    reqs = _reqs(cfg)
    sps = [SamplingParams(max_tokens=5, temperature=0.8, seed=11),
           SamplingParams(max_tokens=5),
           SamplingParams(max_tokens=4, temperature=0.6, seed=3)]
    refs = _reference(setup, sched, reqs, sps)
    ec = EngineConfig(backend=backend, batching=batching, slots=2,
                      max_len=64,
                      prefix_cache=PrefixCacheConfig(min_prefix=4))
    with RouterEngine(model, params, ec,
                      RouterConfig(replicas=2, policy="prefix"),
                      scheduler=sched) as router:
        outs = router.generate(reqs, sps)
    for r, o, (ref_toks, ref_fin) in zip(reqs, outs, refs):
        assert list(o.tokens) == ref_toks, (backend, batching, r.uid)
        assert o.finish_reason == ref_fin, (backend, batching, r.uid)
        assert o.replica in (0, 1)


@pytest.mark.parametrize("backend,batching", COMBOS)
def test_stream_matches_generate_chunked(setup, sched, backend,
                                         batching):
    """generate_stream under chunked admission yields exactly the
    generate() tokens, with exactly one finish event per request."""
    cfg, model, params = setup
    reqs = _reqs(cfg, seed=5)
    sps = [SamplingParams(max_tokens=g) for g in (5, 4, 6)]
    kw = (dict(prefill_chunk=4, max_step_tokens=5)
          if batching == "continuous" else dict(prefill_chunk=4))
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend=backend, batching=batching, slots=2,
                         max_len=64, **kw), scheduler=sched) as eng:
        events = list(eng.generate_stream(reqs, sps))
        outs = eng.generate(reqs, sps)
    for r, o in zip(reqs, outs):
        evs = [e for e in events if e.uid == r.uid]
        assert [e.token for e in evs] == list(o.tokens)
        fins = [e.finish_reason for e in evs if e.finish_reason]
        assert fins == [o.finish_reason]
