"""Hot-path regression tests: the steady-state decode loop must be
retrace-free (XLA trace cache bounded by the plan's pad buckets) and
allocation-free (persistent staging reused across layers and steps),
and bucket-padded execution must stay token-identical to the resident
reference.  Guards the perf properties of the fenced/staged runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Request, ServingEngine

GEN = 33          # >= 32 generated tokens crosses several pad buckets


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _spill(cfg, model, params, toks, gen, compress=None):
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    store = HostKVStore(cfg, toks.shape[0], toks.shape[1] + gen + 2,
                        compress=compress)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs),
                    toks.shape[1])
    return store, first


def _distinct_geometries(plan, start, gen, max_len):
    """Replay the plan over the decoded range: the trace count must be
    bounded by the number of distinct (l_pad, s_pad) pairs it emits."""
    return {(g.l_pad, g.s_pad)
            for g in (plan.step_geometry([s] * 2, max_len=max_len)
                      for s in range(start, start + gen))}


@pytest.mark.parametrize("compress", [None, "int4"])
def test_uniform_decode_retrace_and_alloc_free(tiny_setup, compress):
    """Steady state = zero retraces and zero staging allocations: decode
    the same trajectory twice (fresh store, same runtime); the second
    pass must add no traces and no buffers."""
    cfg, model, params = tiny_setup
    b, s = 2, 12
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (b, s)).astype(np.int32)
    rt = OffloadDecodeRuntime(cfg, params, A100_PCIE4, mode="kvpr",
                              compress=compress)

    store, first = _spill(cfg, model, params, toks, GEN, compress)
    out1, stats1 = rt.decode(store, first, GEN)

    # trace cache bounded by the plan's distinct pad geometries
    plan = rt.plan_for(b)
    n_geoms = len(_distinct_geometries(plan, s, GEN, store.max_len))
    traces = rt.compute.traces()
    if traces >= 0:
        assert traces <= n_geoms
    assert n_geoms <= GEN // plan.pad_every + 2   # buckets, not steps
    assert sum(st.retraces for st in stats1) <= n_geoms

    # warm pass: identical tokens, zero new traces, zero new staging
    store2, first2 = _spill(cfg, model, params, toks, GEN, compress)
    allocs0, traces0 = rt.xfer.staging_allocs, rt.compute.traces()
    out2, stats2 = rt.decode(store2, first2, GEN)
    np.testing.assert_array_equal(out1, out2)
    assert rt.xfer.staging_allocs == allocs0
    if traces0 >= 0:
        assert rt.compute.traces() == traces0
    assert sum(st.retraces for st in stats2) == 0
    rt.close()


@pytest.mark.slow
def test_bucketed_padding_token_identity(tiny_setup):
    """Bucket-padded, masked execution must emit exactly the tokens the
    resident (unpadded) reference emits over a long decode."""
    cfg, model, params = tiny_setup
    b, s = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                       jnp.int32)
    lg, cache = model.prefill(params, toks, max_len=s + GEN + 2)
    ref, tok = [], jnp.argmax(lg, axis=-1).astype(jnp.int32)
    for _ in range(GEN + 1):
        ref.append(np.asarray(tok))
        lg, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    ref = np.concatenate(ref, axis=1)

    with OffloadDecodeRuntime(cfg, params, A100_PCIE4,
                              mode="kvpr") as rt:
        store, first = _spill(cfg, model, params, np.asarray(toks), GEN)
        np.testing.assert_array_equal(first, ref[:, :1])
        out, _ = rt.decode(store, first, GEN)
    np.testing.assert_array_equal(out, ref[:, 1:GEN + 1])


@pytest.mark.slow
def test_ragged_continuous_retrace_bounded(tiny_setup):
    """Continuous batching (ragged slots, mid-decode admission) shares
    the uniform path's traces; a second serve() over the same workload
    must be completely retrace- and allocation-free."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        8 + 3 * i).astype(np.int32),
                    max_new_tokens=10 + (i % 3))
            for i in range(4)]
    eng = ContinuousBatchingEngine(model, params, num_slots=2,
                                   max_len=64, mode="offload",
                                   scheduler=Scheduler(A100_PCIE4))
    gens1 = eng.serve(reqs)
    traces = eng.runtime.compute.traces()
    if traces >= 0:
        # every step shares a (l_pad, s_pad) variant; far fewer traces
        # than total decode steps (~40 here)
        assert traces <= 8
    allocs0, traces0 = (eng.runtime.xfer.staging_allocs,
                        eng.runtime.compute.traces())
    gens2 = eng.serve(reqs)
    assert eng.runtime.xfer.staging_allocs == allocs0
    if traces0 >= 0:
        assert eng.runtime.compute.traces() == traces0
    for g1, g2 in zip(gens1, gens2):
        np.testing.assert_array_equal(g1.tokens, g2.tokens)
    eng.close()


def test_serving_engine_reuses_runtime(tiny_setup):
    """The offload engine keeps one runtime across serve() calls, so jit
    traces and staging buffers persist (and StepStats report the new
    t_store / retraces fields)."""
    cfg, model, params = tiny_setup
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 12).astype(np.int32), max_new_tokens=6)
        for i in range(2)]
    eng = ServingEngine(model, params, mode="offload")
    assert eng.runtime is not None
    eng.serve(reqs)
    allocs0 = eng.runtime.xfer.staging_allocs
    assert allocs0 > 0
    eng.serve(reqs)
    assert eng.runtime.xfer.staging_allocs == allocs0
    eng.close()
