"""Training loop, optimizer, checkpoint, and data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep, see docs/automation.md
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, make_stream
from repro.models.transformer import Model
from repro.training import checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_schedule)
from repro.training.train_loop import cross_entropy, train


def test_loss_decreases():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)

    def stream():
        for b in make_stream(dc):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    hist, *_ = train(model, params, stream(), steps=25,
                     opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5,
                                         total_steps=25), log_every=100)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5]) - 0.2


def test_cross_entropy_ignores_masked():
    logits = jnp.zeros((2, 4, 8))
    labels = jnp.array([[1, 2, -100, -100], [3, -100, -100, -100]])
    ce = cross_entropy(logits, labels, 8)
    assert jnp.allclose(ce, jnp.log(8.0), rtol=1e-5)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, 0)) == 0.0
    assert abs(float(lr_schedule(cfg, 10)) - 1e-3) < 1e-9
    assert float(lr_schedule(cfg, 100)) <= 1e-3 * cfg.min_lr_ratio + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(1, 5))
def test_grad_clip_bounds_update(scale, seed):
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (8, 8))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                    (8, 8)) * scale * 100}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0)
    new_params, new_state, info = adamw_update(params, grads, state, cfg)
    # after clipping, first-step Adam update magnitude is bounded by ~lr
    delta = jnp.abs(new_params["w"] - params["w"]).max()
    assert float(delta) < cfg.lr * (2 + cfg.weight_decay * 10)
    assert int(new_state["step"]) == 1


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((3,), jnp.bfloat16), "d": 7, "e": "x"},
            "l": [jnp.zeros((2,), jnp.int32), 1.5]}
    p = str(tmp_path / "ck.msgpack")
    checkpoint.save(p, tree)
    back = checkpoint.load(p)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16
    assert back["b"]["d"] == 7 and back["b"]["e"] == "x"
    assert back["l"][1] == 1.5


def test_data_shards_disjoint_and_shaped():
    dcs = [DataConfig(vocab_size=512, seq_len=32, batch_size=4,
                      num_shards=2, shard_id=i) for i in range(2)]
    b0 = next(make_stream(dcs[0]))
    b1 = next(make_stream(dcs[1]))
    assert b0["tokens"].shape == (4, 32)
    assert b0["labels"].shape == (4, 32)
    assert (b0["tokens"] < 512).all() and (b0["tokens"] >= 0).all()
    # different shards draw different streams
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are next-token shifted
    full = next(make_stream(dcs[0]))
    assert not np.array_equal(full["tokens"], full["labels"])
