"""Property tests for the KVPR scheduler (paper Eq. 10-11)."""
import pytest

pytest.importorskip("hypothesis")  # optional dep, see docs/automation.md
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (A100_PCIE4, TPU_V5E, HardwareProfile, Workload,
                        brute_force_split, flexgen_step, kvpr_step,
                        layer_times, optimal_split)

workloads = st.builds(
    Workload,
    batch=st.sampled_from([1, 2, 8, 32, 64, 128]),
    seq_len=st.integers(2, 4096),
    d_model=st.sampled_from([384, 1024, 2048, 4096, 8192]),
    kv_dim=st.sampled_from([128, 512, 1024, 4096]),
    dtype_bytes=st.sampled_from([1, 2, 4]),
)
profiles = st.sampled_from([A100_PCIE4, TPU_V5E])
schedules = st.sampled_from(["row", "column"])


@settings(max_examples=200, deadline=None)
@given(workloads, profiles, schedules)
def test_solver_matches_brute_force(wl, hw, sched):
    a = optimal_split(wl, hw, sched)
    b = brute_force_split(wl, hw, sched)
    assert a.t_total <= b.t_total * (1 + 1e-9)


@settings(max_examples=200, deadline=None)
@given(workloads, profiles, schedules)
def test_kvpr_never_worse_than_full_transfer(wl, hw, sched):
    """l=0 IS full transfer, so the optimum can never exceed it."""
    full = layer_times(wl, hw, 0, include_act_transfer=(sched == "column"))
    opt = optimal_split(wl, hw, sched)
    assert opt.t_total <= full["total"] * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(workloads, profiles)
def test_split_within_bounds_and_aligned(wl, hw):
    d = optimal_split(wl, hw, "row", align=128)
    assert 0 <= d.l <= wl.seq_len
    assert d.l % 128 == 0 or d.l == wl.seq_len


@settings(max_examples=100, deadline=None)
@given(workloads)
def test_faster_gpu_recomputes_more(wl):
    """More compute per byte of link -> the optimal split moves up."""
    slow = HardwareProfile("slow", 32e9, 1e12, 1e12)
    fast = HardwareProfile("fast", 32e9, 1e15, 1e12)
    l_slow = optimal_split(wl, slow, "row").l
    l_fast = optimal_split(wl, fast, "row").l
    assert l_fast >= l_slow


@settings(max_examples=100, deadline=None)
@given(workloads, profiles)
def test_pipeline_step_consistency(wl, hw):
    fg = flexgen_step(wl, hw)
    kv = kvpr_step(wl, hw, schedule="row")
    # KVPR (weights resident) never slower in steady state
    assert kv.t_layer <= fg.t_layer * (1 + 1e-9)
    assert 0.0 <= kv.utilization <= 1.0
    # byte accounting: KVPR moves fewer or equal bytes over the link
    assert kv.transfer_total <= fg.transfer_total + 1e-12
