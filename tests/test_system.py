"""End-to-end system tests: the paper's pipeline from profile -> schedule
-> serve, plus benchmark harness sanity."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (A100_PCIE4, Workload, flexgen_step, kvpr_step,
                        optimal_split)
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


def test_paper_regime_reproduced():
    """Table 1's motivating gap: PCIe transfer >> attention compute."""
    wl = Workload(batch=32, seq_len=1024, d_model=4096, kv_dim=4096,
                  dtype_bytes=2)
    t_pcie = wl.total_kv_bytes / A100_PCIE4.v_com
    t_comp = wl.total_kv_bytes / A100_PCIE4.hbm_bandwidth
    assert t_pcie / t_comp > 10  # an order of magnitude


def test_kvpr_end_to_end_latency_win():
    """In the paper's regime the whole pipeline shows a latency win in
    the reported band (>10% per-layer at batch 64 / seq 1k)."""
    wl = Workload(batch=64, seq_len=1024, d_model=4096, kv_dim=4096,
                  dtype_bytes=2)
    fg = flexgen_step(wl, A100_PCIE4)
    kv = kvpr_step(wl, A100_PCIE4, schedule="row")
    assert kv.t_layer < fg.t_layer * 0.9
    assert kv.split.l > 0


def test_full_serving_path_exactness():
    """Serving with host-offloaded KV + partial recompute returns exactly
    the resident-cache generations (the paper's 'exact attention' claim)."""
    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        1, cfg.vocab_size, 16).astype(np.int32), max_new_tokens=6)
        for i in range(2)]
    with ServingEngine(model, params, mode="resident") as eng:
        res = eng.serve(reqs)
    with ServingEngine(model, params, mode="offload") as eng:
        off = eng.serve(reqs)
    for r, o in zip(res, off):
        np.testing.assert_array_equal(r.tokens, o.tokens)


def test_benchmarks_importable_and_run():
    from benchmarks import (fig7_latency, fig12_split_points,
                            table1_pcie_vs_compute, table2_hiding_ablation)
    rows = table1_pcie_vs_compute.run(print_csv=False)
    assert len(rows) == 3
    rows = fig12_split_points.run(print_csv=False)
    assert all(0 <= r[1] for r in rows)
    rows = table2_hiding_ablation.run(print_csv=False)
    # hiding ablation invariant: fine-grained never worse than flexgen
    for (_, fg, coarse, fine) in rows:
        assert fine <= fg * 1.0001
