"""autoshard.recommend encodes the §Perf findings correctly per
(arch-family x shape-kind)."""
import pytest

from repro.configs import get_config
from repro.launch.autoshard import recommend
from repro.launch.specs import INPUT_SHAPES


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_small_model_train_drops_tp():
    plan = recommend(get_config("xlstm-350m"), INPUT_SHAPES["train_4k"],
                     MESH)
    assert plan.strategy["tp"] is None
    assert plan.strategy["fsdp"] == ("data", "model")
    assert plan.rules["heads"] is None
    assert any("drop TP" in r for r in plan.rationale)


def test_large_dense_train_keeps_baseline():
    plan = recommend(get_config("mistral-nemo-12b"),
                     INPUT_SHAPES["train_4k"], MESH)
    assert plan.strategy["tp"] == "model"
    assert plan.strategy["fsdp"] == ("data",)
    assert plan.model_kwargs == {}


def test_dense_decode_stationary_params_and_kvseq():
    plan = recommend(get_config("mistral-nemo-12b"),
                     INPUT_SHAPES["decode_32k"], MESH)
    assert plan.strategy["fsdp"] == ()            # finding 2
    assert plan.model_kwargs.get("seq_shard")     # finding 3 (kv=8 < 16)
    assert plan.seq_axis == "model"
    assert plan.rules["kv_seq"] == "model"


def test_moe_gets_shard_map_dispatch():
    plan = recommend(get_config("qwen3-moe-30b-a3b"),
                     INPUT_SHAPES["train_4k"], MESH)
    assert plan.model_kwargs.get("moe_impl") == "shard_map"


def test_small_model_prefill_small_batch_keeps_tp():
    """finding-1 guard: prefill_32k's b=32 can't fill 256 data ways —
    dropping TP would force batch replication (measured 7x memory
    regression), so the baseline strategy must be kept."""
    plan = recommend(get_config("tinyllama-1.1b"),
                     INPUT_SHAPES["prefill_32k"], MESH)
    assert plan.strategy["tp"] == "model"
    assert plan.strategy["fsdp"] == ("data",)


def test_ssm_decode_no_kvseq():
    # xlstm has no KV cache; decode must not request seq sharding
    plan = recommend(get_config("xlstm-350m"), INPUT_SHAPES["decode_32k"],
                     MESH)
    assert "seq_shard" not in plan.model_kwargs
    assert plan.strategy["fsdp"] == ()
