"""Continuous batching: iteration-level admission must reproduce the
static engine's greedy generations exactly, for variable-length prompts
and more requests than slots."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import Model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import Request, ServingEngine


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "opt-6.7b"])
def test_continuous_matches_sequential(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # 5 requests, variable prompt lengths, 2 slots -> forced turnover
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        8 + 3 * i).astype(np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(5)]
    with ContinuousBatchingEngine(model, params, num_slots=2,
                                  max_len=64) as ceng:
        cont = ceng.serve(reqs)
    # reference: each request served alone (no padding interference)
    with ServingEngine(model, params, mode="resident") as eng:
        for r, c in zip(reqs, cont):
            ref = eng.serve([r])[0]
            np.testing.assert_array_equal(c.tokens, ref.tokens,
                                          err_msg=f"uid={r.uid}")
            assert len(c.tokens) == r.max_new_tokens
