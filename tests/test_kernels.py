"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import (combine_segments,
                                            flash_decode_segment)
from repro.kernels.kv_recompute import kv_recompute_pallas

SHAPES_KV = [
    (2, 16, 64, 2, 32),
    (1, 128, 256, 8, 32),
    (3, 64, 384, 6, 64),     # whisper-like: non-128 head dims
    (2, 256, 512, 4, 128),   # MXU-aligned
    (1, 7, 96, 3, 16),       # awkward primes
]


@pytest.mark.parametrize("b,l,h,KV,dh", SHAPES_KV)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_recompute_matches_oracle(b, l, h, KV, dh, dtype):
    key = jax.random.PRNGKey(l * h)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, l, h), dtype)
    wk = (jax.random.normal(ks[1], (h, KV, dh)) / np.sqrt(h)).astype(dtype)
    wv = (jax.random.normal(ks[2], (h, KV, dh)) / np.sqrt(h)).astype(dtype)
    k1, v1 = ops.kv_recompute(x, wk, wv)
    k2, v2 = ref.kv_recompute_ref(x, wk.reshape(h, -1), wv.reshape(h, -1))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(k1.reshape(b, l, -1), np.float32),
        np.asarray(k2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(v1.reshape(b, l, -1), np.float32),
        np.asarray(v2, np.float32), rtol=tol, atol=tol)


SHAPES_FD = [
    (2, 2, 4, 32, 64, 50),
    (1, 8, 4, 64, 256, 256),
    (2, 4, 1, 128, 512, 300),
    (1, 1, 8, 64, 96, 17),
]


@pytest.mark.parametrize("b,KV,g,dh,S,valid", SHAPES_FD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_oracle(b, KV, g, dh, S, valid, dtype):
    key = jax.random.PRNGKey(S + valid)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, KV, g, dh), dtype)
    k = jax.random.normal(ks[1], (b, KV, S, dh), dtype)
    v = jax.random.normal(ks[2], (b, KV, S, dh), dtype)
    o1, m1, l1 = flash_decode_segment(q, k, v, jnp.asarray(valid),
                                      interpret=True, chunk=64)
    o2, m2, l2 = ref.flash_decode_segment_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=1e-4, atol=1e-4)


def test_multi_segment_combine_exact():
    """KVPR three-segment attention == attention over concatenated cache."""
    key = jax.random.PRNGKey(0)
    b, KV, g, dh = 2, 2, 4, 32
    H = KV * g
    q = jax.random.normal(key, (b, 1, H, dh))
    segs = []
    for i, (S, valid) in enumerate([(32, None), (64, 40), (1, None)]):
        kk = jax.random.normal(jax.random.fold_in(key, i), (b, S, KV, dh))
        vv = jax.random.normal(jax.random.fold_in(key, i + 9), (b, S, KV, dh))
        segs.append((kk, vv, valid))
    o_kern = ops.two_segment_decode_attention(q, segs, jnp.asarray(96))
    o_ref = ref.merged_attention_ref(q, segs)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_combine_is_permutation_invariant():
    key = jax.random.PRNGKey(1)
    parts = []
    for i in range(3):
        o = jax.random.normal(jax.random.fold_in(key, i), (1, 2, 4, 16))
        m = jax.random.normal(jax.random.fold_in(key, i + 5), (1, 2, 4, 1))
        l = jax.random.uniform(jax.random.fold_in(key, i + 9),
                               (1, 2, 4, 1)) + 0.1
        parts.append((o, m, l))
    a = combine_segments(parts)
    b = combine_segments(parts[::-1])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
