"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvquant as KQ
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (combine_segments,
                                            flash_decode_segment,
                                            flash_decode_segment_db)
from repro.kernels.kv_recompute import (kv_recompute_pallas,
                                        recompute_attend_segment)
from repro.models import layers as L

SHAPES_KV = [
    (2, 16, 64, 2, 32),
    (1, 128, 256, 8, 32),
    (3, 64, 384, 6, 64),     # whisper-like: non-128 head dims
    (2, 256, 512, 4, 128),   # MXU-aligned
    (1, 7, 96, 3, 16),       # awkward primes
]


@pytest.mark.parametrize("b,l,h,KV,dh", SHAPES_KV)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_recompute_matches_oracle(b, l, h, KV, dh, dtype):
    key = jax.random.PRNGKey(l * h)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, l, h), dtype)
    wk = (jax.random.normal(ks[1], (h, KV, dh)) / np.sqrt(h)).astype(dtype)
    wv = (jax.random.normal(ks[2], (h, KV, dh)) / np.sqrt(h)).astype(dtype)
    k1, v1 = ops.kv_recompute(x, wk, wv)
    k2, v2 = ref.kv_recompute_ref(x, wk.reshape(h, -1), wv.reshape(h, -1))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(k1.reshape(b, l, -1), np.float32),
        np.asarray(k2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(v1.reshape(b, l, -1), np.float32),
        np.asarray(v2, np.float32), rtol=tol, atol=tol)


SHAPES_FD = [
    (2, 2, 4, 32, 64, 50),
    (1, 8, 4, 64, 256, 256),
    (2, 4, 1, 128, 512, 300),
    (1, 1, 8, 64, 96, 17),
]


@pytest.mark.parametrize("b,KV,g,dh,S,valid", SHAPES_FD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_oracle(b, KV, g, dh, S, valid, dtype):
    key = jax.random.PRNGKey(S + valid)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, KV, g, dh), dtype)
    k = jax.random.normal(ks[1], (b, KV, S, dh), dtype)
    v = jax.random.normal(ks[2], (b, KV, S, dh), dtype)
    o1, m1, l1 = flash_decode_segment(q, k, v, jnp.asarray(valid),
                                      interpret=True, chunk=64)
    o2, m2, l2 = ref.flash_decode_segment_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------- per-slot ragged valid

RAGGED_FD = [
    (3, 2, 4, 32, 64, (50, 64, 7)),
    (2, 4, 1, 128, 96, (96, 17)),
    (4, 1, 8, 64, 128, (0, 1, 100, 128)),   # incl. an empty slot
]


@pytest.mark.parametrize("b,KV,g,dh,S,valid", RAGGED_FD)
@pytest.mark.parametrize("variant", ["blockspec", "double_buffered"])
def test_flash_decode_ragged_valid(b, KV, g, dh, S, valid, variant):
    """(b,) per-slot valid vectors are masked in-kernel — what ragged
    continuous batching feeds the decode hot path."""
    key = jax.random.PRNGKey(S)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, KV, g, dh))
    k = jax.random.normal(ks[1], (b, KV, S, dh))
    v = jax.random.normal(ks[2], (b, KV, S, dh))
    vl = jnp.asarray(valid, jnp.int32)
    fn = (flash_decode_segment_db if variant == "double_buffered"
          else flash_decode_segment)
    o1, m1, l1 = fn(q, k, v, vl, interpret=True, chunk=32)
    o2, m2, l2 = ref.flash_decode_segment_ref(q, k, v, vl)
    # rows of an all-masked slot are garbage-but-finite on both paths;
    # compare only slots with at least one valid position
    live = np.asarray(valid) > 0
    np.testing.assert_allclose(np.asarray(o1)[live], np.asarray(o2)[live],
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m1)[live], np.asarray(m2)[live],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(l1)[live], np.asarray(l2)[live],
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(np.asarray(o1)).all()


def test_double_buffered_matches_blockspec_variant():
    """The DMA-pipelined variant is numerically interchangeable with
    the BlockSpec-pipelined one (same chunking, same accumulation)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    b, KV, g, dh, S = 2, 2, 4, 64, 256
    q = jax.random.normal(ks[0], (b, KV, g, dh))
    k = jax.random.normal(ks[1], (b, KV, S, dh))
    v = jax.random.normal(ks[2], (b, KV, S, dh))
    vl = jnp.asarray([200, 256], jnp.int32)
    o1, m1, l1 = flash_decode_segment(q, k, v, vl, interpret=True,
                                      chunk=64)
    o2, m2, l2 = flash_decode_segment_db(q, k, v, vl, interpret=True,
                                         chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------ fused recompute+attend

FUSED_SHAPES = [
    # b, Lp, h, KV, g, dh, valid, offsets, rope
    (2, 48, 96, 2, 4, 64, (30, 48), (0, 0), True),
    (1, 128, 256, 4, 2, 32, (100,), (16,), True),
    (3, 16, 64, 1, 8, 64, (16, 5, 0), (0, 3, 0), False),
]


@pytest.mark.parametrize("b,Lp,h,KV,g,dh,valid,off,rope", FUSED_SHAPES)
def test_fused_recompute_attend_vs_composed(b, Lp, h, KV, g, dh, valid,
                                            off, rope):
    """Fused recompute+attend == recompute_kv (einsum + RoPE) composed
    with the flash-decode oracle — the recomputed KV never needs to
    materialize."""
    theta = 10000.0
    key = jax.random.PRNGKey(Lp + h)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, KV, g, dh))
    x = jax.random.normal(ks[1], (b, Lp, h))
    wk = jax.random.normal(ks[2], (h, KV, dh)) / np.sqrt(h)
    wv = jax.random.normal(ks[3], (h, KV, dh)) / np.sqrt(h)
    vl = jnp.asarray(valid, jnp.int32)
    o1, m1, l1 = recompute_attend_segment(
        q, x, wk, wv, vl, jnp.asarray(off, jnp.int32), theta=theta,
        rope=rope, interpret=True, chunk=16)
    # composed oracle: standalone recompute + rope, then attend
    kr = jnp.einsum("blh,hnd->blnd", x, wk)
    vr = jnp.einsum("blh,hnd->blnd", x, wv)
    if rope:
        pos = jnp.arange(Lp)[None] + jnp.asarray(off)[:, None]
        kr = L.apply_rope(kr, pos, theta)
    o2, m2, l2 = ref.flash_decode_segment_ref(
        q, jnp.moveaxis(kr, 2, 1), jnp.moveaxis(vr, 2, 1), vl)
    live = np.asarray(valid) > 0
    np.testing.assert_allclose(np.asarray(o1)[live], np.asarray(o2)[live],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m1)[live], np.asarray(m2)[live],
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(np.asarray(o1)).all()


# --------------------------------------------------- segmented dispatch

def test_mixed_precision_three_segment_sweep():
    """The KVPR decode hot path's exact segment mix: fused-recomputed
    prefix + int4 streamed + fp new-token, dispatched through
    segmented_decode_attention, vs the jnp oracle over the dequantized
    concatenated cache.  GQA head grouping (g=4) included."""
    b, KV, g, dh, h = 2, 2, 4, 64, 96
    H = KV * g
    Lp, S = 32, 64
    theta = 10000.0
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, 1, H, dh))
    x = jax.random.normal(ks[1], (b, Lp, h))
    wk = jax.random.normal(ks[2], (h, KV, dh)) / np.sqrt(h)
    wv = jax.random.normal(ks[3], (h, KV, dh)) / np.sqrt(h)
    k_str = jax.random.normal(ks[4], (b, S, KV, dh))
    v_str = jax.random.normal(ks[5], (b, S, KV, dh))
    k_new = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, KV, dh))
    v_new = jax.random.normal(jax.random.fold_in(key, 2), (b, 1, KV, dh))
    l_valid = jnp.asarray([20, 32], jnp.int32)
    s_valid = jnp.asarray([64, 40], jnp.int32)

    kq3 = KQ.quantize_jnp(k_str)
    vq3 = KQ.quantize_jnp(v_str)
    out = ops.segmented_decode_attention(
        q,
        [("recompute", x, wk, wv, l_valid, 0, theta, True),
         ("int4", kq3, vq3, s_valid, 32),
         ("fp", k_new, v_new, None)],
        mode="interpret", chunk=32)

    kr = L.apply_rope(jnp.einsum("blh,hnd->blnd", x, wk),
                      jnp.broadcast_to(jnp.arange(Lp), (b, Lp)), theta)
    vr = jnp.einsum("blh,hnd->blnd", x, wv)
    kd = KQ.dequantize_jnp(*kq3)
    vd = KQ.dequantize_jnp(*vq3)
    o_ref = ref.merged_attention_ref(
        q, [(kr, vr, l_valid), (kd, vd, s_valid), (k_new, v_new, None)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)


def test_head_sharded_dispatch_bit_identical():
    """Mesh decode (docs/scaling.md): ``head_shards=k`` slices the
    KV-head axis per segment kind (recompute wk/wv, int4 triple, fp)
    and concatenates the per-slice launches — flash decode never
    crosses KV heads, so the result must be BIT-identical to the
    full-width launch, over the exact three-segment KVPR mix."""
    b, KV, g, dh, h = 2, 4, 2, 32, 64
    H = KV * g
    Lp, S = 16, 64
    theta = 10000.0
    key = jax.random.PRNGKey(23)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, 1, H, dh))
    x = jax.random.normal(ks[1], (b, Lp, h))
    wk = jax.random.normal(ks[2], (h, KV, dh)) / np.sqrt(h)
    wv = jax.random.normal(ks[3], (h, KV, dh)) / np.sqrt(h)
    k_str = jax.random.normal(ks[4], (b, S, KV, dh))
    v_str = jax.random.normal(ks[5], (b, S, KV, dh))
    k_new = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, KV, dh))
    v_new = jax.random.normal(jax.random.fold_in(key, 2), (b, 1, KV, dh))
    segs = [("recompute", x, wk, wv, jnp.asarray([10, 16], jnp.int32),
             0, theta, True),
            ("int4", KQ.quantize_jnp(k_str), KQ.quantize_jnp(v_str),
             jnp.asarray([64, 40], jnp.int32), 32),
            ("fp", k_new, v_new, None)]
    base = ops.segmented_decode_attention(q, segs, mode="interpret",
                                          chunk=32)
    for hs in (2, 4):
        out = ops.segmented_decode_attention(q, segs, mode="interpret",
                                             chunk=32, head_shards=hs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base),
                                      err_msg=f"head_shards={hs}")
    with pytest.raises(ValueError):
        ops.segmented_decode_attention(q, segs, mode="interpret",
                                       head_shards=3)


def test_zero_length_segment_dropped():
    """The l=0 pure-stream split hands the kernel dispatch an empty
    recomputed segment; it must be dropped before any launch (the jnp
    path already skips it) instead of tiling an S=0 grid."""
    key = jax.random.PRNGKey(5)
    b, KV, g, dh, S = 2, 2, 2, 32, 48
    H = KV * g
    q = jax.random.normal(key, (b, 1, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, KV, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, KV, dh))
    empty = jnp.zeros((b, 0, KV, dh))
    out = ops.two_segment_decode_attention(
        q, [(empty, empty, None), (k, v, jnp.asarray(40))],
        jnp.asarray(S))
    o_ref = ref.merged_attention_ref(q, [(k, v, jnp.asarray(40))])
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError):
        ops.segmented_decode_attention(q, [("fp", empty, empty, None)],
                                       mode="interpret")


def test_kernel_mode_resolver():
    """EngineConfig.kernels knob -> execution mode (on this CPU
    container: auto stays on the jnp oracle, opt-in means interpret)."""
    assert ops.kernel_mode(False) == "off"
    assert ops.kernel_mode("off") == "off"
    assert ops.kernel_mode(None) == "off"
    on_tpu = jax.default_backend() == "tpu"
    assert ops.kernel_mode("auto") == ("pallas" if on_tpu else "off")
    assert ops.kernel_mode(True) == ("pallas" if on_tpu else "interpret")
    assert ops.kernel_mode("interpret") == "interpret"
    assert ops.kernel_mode("pallas") == "pallas"
    with pytest.raises(ValueError):
        ops.kernel_mode("sometimes")


def test_multi_segment_combine_exact():
    """KVPR three-segment attention == attention over concatenated cache."""
    key = jax.random.PRNGKey(0)
    b, KV, g, dh = 2, 2, 4, 32
    H = KV * g
    q = jax.random.normal(key, (b, 1, H, dh))
    segs = []
    for i, (S, valid) in enumerate([(32, None), (64, 40), (1, None)]):
        kk = jax.random.normal(jax.random.fold_in(key, i), (b, S, KV, dh))
        vv = jax.random.normal(jax.random.fold_in(key, i + 9), (b, S, KV, dh))
        segs.append((kk, vv, valid))
    o_kern = ops.two_segment_decode_attention(q, segs, jnp.asarray(96))
    o_ref = ref.merged_attention_ref(q, segs)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_combine_is_permutation_invariant():
    key = jax.random.PRNGKey(1)
    parts = []
    for i in range(3):
        o = jax.random.normal(jax.random.fold_in(key, i), (1, 2, 4, 16))
        m = jax.random.normal(jax.random.fold_in(key, i + 5), (1, 2, 4, 1))
        l = jax.random.uniform(jax.random.fold_in(key, i + 9),
                               (1, 2, 4, 1)) + 0.1
        parts.append((o, m, l))
    a = combine_segments(parts)
    b = combine_segments(parts[::-1])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
