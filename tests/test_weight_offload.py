"""Executable weight offloading (paper §3.2 throughput mode + §3.3
fine-grained W_K/W_V-first pipeline, Fig. 5): streaming layer weights
from host per step must be bit-exact vs resident weights, in both
coarse and fine-grained pipelines, with KVPR split active."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.models.transformer import Model


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("opt-6.7b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 24)).astype(np.int32)
    logits, ks, vs, hs = prefill_with_activations(model, params,
                                                  np.asarray(toks))
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    return cfg, params, first, ks, vs, hs


def _decode(setup, gen=4, **rt_kwargs):
    cfg, params, first, ks, vs, hs = setup
    store = HostKVStore(cfg, first.shape[0], 24 + gen + 2)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), 24)
    with OffloadDecodeRuntime(cfg, params, profile_system(),
                              mode="kvpr", schedule="row",
                              **rt_kwargs) as rt:
        toks, stats = rt.decode(store, np.asarray(first), gen)
    return toks, stats


def test_weight_offload_exact_fine_and_coarse(setup):
    ref, _ = _decode(setup)
    fine, st_f = _decode(setup, offload_weights=True, fine_grained=True)
    coarse, st_c = _decode(setup, offload_weights=True,
                           fine_grained=False)
    np.testing.assert_array_equal(ref, fine)
    np.testing.assert_array_equal(ref, coarse)
    # weight bytes must be accounted: offloaded runs stream strictly more
    assert all(c.bytes_transferred > r.bytes_transferred
               for c, r in zip(st_f, _decode(setup)[1]))


def test_weight_offload_with_int4_stream(setup):
    """All three paper mechanisms composed: partial recompute + weight
    streaming (fine-grained) + int4 KV compression."""
    cfg, params, first, ks, vs, hs = setup
    gen = 3
    store = HostKVStore(cfg, first.shape[0], 24 + gen + 2,
                        compress="int4")
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), 24)
    with OffloadDecodeRuntime(cfg, params, profile_system(),
                              mode="kvpr", offload_weights=True,
                              compress="int4") as rt:
        toks, stats = rt.decode(store, np.asarray(first), gen)
    assert toks.shape == (first.shape[0], gen)
    assert np.isfinite(stats[-1].t_total)
