"""GShard-style shard_map MoE (models/moe.moe_block_sharded) must agree
with the GSPMD global-dispatch moe_block when capacity drops nothing,
and must fall back cleanly without a mesh. The multi-device check runs
in a subprocess (this test process is pinned to 1 device)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import xla_device_count

from repro.configs import get_smoke_config
from repro.models import moe as MOE


def test_fallback_no_mesh_identical():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    o1, a1 = MOE.moe_block(x, p, cfg)
    o2, a2 = MOE.moe_block_sharded(x, p, cfg)   # no mesh -> same path
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


_SUBPROC = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import moe as MOE
from repro.models.sharding import DEFAULT_RULES, logical_rules

mesh = jax.make_mesh((2, 4), ("data", "model"))

# two regimes: expert-parallel (E divisible by model axis) and the
# tensor-parallel fallback (E NOT divisible -> d_ff sharded per expert)
base = get_smoke_config("qwen3-moe-30b-a3b")
for n_exp in (base.moe.num_experts, 6):
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(
            base.moe, num_experts=n_exp,
            # no-drop capacity so global/local dispatch agree exactly
            capacity_factor=float(n_exp) / base.moe.top_k))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    with logical_rules(dict(DEFAULT_RULES), mesh):
        with mesh:
            o_ref, a_ref = jax.jit(
                lambda x, p: MOE.moe_block(x, p, cfg))(x, p)
            o_sm, a_sm = jax.jit(
                lambda x, p: MOE.moe_block_sharded(x, p, cfg))(x, p)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_sm),
                               rtol=2e-5, atol=2e-5)
    # aux is a per-shard estimator under local dispatch (mean over shards
    # of local E*sum(f_e*p_e)) — the standard data-parallel form (Switch).
    # It differs from the global estimator at O(1/T_loc).
    np.testing.assert_allclose(float(a_ref), float(a_sm), rtol=0.05)
    print(f"E={n_exp} ok")
print("SHARDED_MOE_OK")
"""


@pytest.mark.slow
def test_sharded_matches_gspmd_on_mesh():
    # the 8-device flag lands via the composing conftest helper — the
    # subprocess env, not a clobbering in-script os.environ write
    env = xla_device_count(8)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SHARDED_MOE_OK" in r.stdout, r.stdout + r.stderr
