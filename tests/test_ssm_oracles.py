"""Chunked-parallel SSM forward paths vs sequential (decode) oracles:
Mamba2 SSD chunking and mLSTM chunked linear attention must equal their
step-by-step recurrences, including non-chunk-multiple lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import mamba2 as M2
from repro.models import xlstm as XL


@pytest.mark.parametrize("s", [8, 32, 40, 64, 100])
def test_mamba2_chunked_equals_sequential(s):
    cfg = get_smoke_config("zamba2-1.2b")
    key = jax.random.PRNGKey(s)
    p = M2.init_mamba2(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, cfg.d_model))
    y_par = M2.mamba2_forward(x, p, cfg)
    y_seq = M2.mamba2_reference(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [8, 32, 40, 64])
def test_mamba2_state_handoff(s):
    """forward_with_state then decode == forward over s+1 tokens."""
    cfg = get_smoke_config("zamba2-1.2b")
    key = jax.random.PRNGKey(s + 1)
    p = M2.init_mamba2(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2),
                          (2, s + 1, cfg.d_model))
    y_full = M2.mamba2_forward(x, p, cfg)
    _, st = M2.mamba2_forward_with_state(x[:, :s], p, cfg)
    y_step, _ = M2.mamba2_decode(x[:, s:s + 1], st, p, cfg)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_full[:, s:s + 1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [8, 32, 40, 64, 96])
def test_mlstm_chunked_equals_sequential(s):
    cfg = get_smoke_config("xlstm-350m")
    key = jax.random.PRNGKey(s + 5)
    p = XL.init_mlstm(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, s, cfg.d_model))
    y_par = XL.mlstm_forward(x, p, cfg)

    # sequential oracle via decode steps
    state = XL.init_mlstm_state(cfg, 2)
    ys = []
    for t in range(s):
        y, state = XL.mlstm_decode(x[:, t:t + 1], state, p, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=3e-4, atol=3e-4)


def test_mlstm_state_handoff():
    cfg = get_smoke_config("xlstm-350m")
    key = jax.random.PRNGKey(11)
    p = XL.init_mlstm(key, cfg, jnp.float32)
    s = 40
    x = jax.random.normal(key, (1, s + 1, cfg.d_model))
    _, st = XL.mlstm_forward_with_state(x[:, :s], p, cfg)
    y_step, _ = XL.mlstm_decode(x[:, s:s + 1], st, p, cfg)
    y_full = XL.mlstm_forward(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_full[:, s:s + 1]),
                               rtol=3e-4, atol=3e-4)


def test_slstm_forward_equals_decode():
    cfg = get_smoke_config("xlstm-350m")
    key = jax.random.PRNGKey(13)
    p = XL.init_slstm(key, cfg, jnp.float32)
    s = 16
    x = jax.random.normal(key, (2, s, cfg.d_model))
    y_par, st_f = XL.slstm_forward_with_state(x, p, cfg)
    state = XL.init_slstm_state(cfg, 2)
    ys = []
    for t in range(s):
        y, state = XL.slstm_decode(x[:, t:t + 1], state, p, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_f["c"]), np.asarray(state["c"]),
                               rtol=1e-5, atol=1e-5)
