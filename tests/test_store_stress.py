"""Concurrency stress for the HostKVStore fence machinery and the
TransferEngine's persistent parity-keyed staging buffers.

Three flows run interleaved for N decode steps, the way a mixed
prefill/decode engine drives them:

  - decode-style per-layer FETCHES on the copy pool (each waits the
    layer's write-back fence, stages through the parity buffers),
  - per-layer token APPEND write-backs on the store pool (fenced with
    ``set_fence``, exactly like ``OffloadDecodeRuntime.step``),
  - prefill CHUNK write-backs into a different slot on the same store
    pool (fenced with ``push_chunk_fence``, exactly like
    ``ChunkedPrefill``).

Every value written is position-derived, so any torn read — a fetch
observing a half-landed store the fences should have ordered — shows up
as a wrong float.  Staging buffers must be allocated once (warmup step)
and never again: ``staging_allocs`` stays zero afterwards.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvstore import KVTiersConfig, TieredKVStore
from repro.core.runtime import HostKVStore, TransferEngine

STEPS = 24
CHUNK = 6
CHUNK_TOTAL = 48


def _kv_pattern(pos, KV, dh, base=0.0):
    """(len(pos), KV, dh) values derived from position: torn reads can't
    reproduce them."""
    p = np.asarray(pos, np.float32)[:, None, None]
    return np.broadcast_to(base + p + 0.5, (len(pos), KV, dh)).copy()


@pytest.mark.slow
def test_fences_survive_interleaved_fetch_store_chunk_writeback():
    cfg = get_smoke_config("opt-6.7b").replace(num_layers=4)
    Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                     cfg.d_model)
    max_len = 8 + STEPS + CHUNK_TOTAL
    store = HostKVStore(cfg, 2, max_len)
    xfer = TransferEngine(n_copy_threads=2)
    errors = []

    # slot 0: a decoding request with an 8-token prefix
    s0 = 8
    pos0 = np.arange(s0)
    for li in range(Lh):
        store.k[li, 0, :s0] = _kv_pattern(pos0, KV, dh)
        store.v[li, 0, :s0] = _kv_pattern(pos0, KV, dh, base=1000.0)
    store.act[:, 0, :s0] = np.arange(s0, dtype=np.float32)[:, None]
    store.seq_lens[0] = s0

    # slot 1: receives prefill chunks concurrently (never decoded here)
    def chunk_writer():
        try:
            for start in range(0, CHUNK_TOTAL, CHUNK):
                pos = np.arange(start, start + CHUNK)
                ks = np.broadcast_to(
                    _kv_pattern(pos, KV, dh, base=5e4)[None, None],
                    (Lh, 1, CHUNK, KV, dh)).copy()
                vs = np.broadcast_to(
                    _kv_pattern(pos, KV, dh, base=6e4)[None, None],
                    (Lh, 1, CHUNK, KV, dh)).copy()
                acts = np.broadcast_to(
                    pos.astype(np.float32)[None, None, :, None],
                    (Lh, 1, CHUNK, h)).copy()
                store.push_chunk_fence(xfer.submit_store(
                    store.fill_chunk_slot, 1, ks, vs, acts, start))
                time.sleep(0.001)
        except Exception as e:           # pragma: no cover
            errors.append(e)

    writer = threading.Thread(target=chunk_writer)
    writer.start()

    # decode loop over slot 0 (flexgen-style l=0 splits; FIXED pad
    # geometry so the staging shapes — and hence allocations — are
    # constant after the first step)
    ls = np.zeros(2, np.int64)
    s_pad = max_len
    allocs_after_warmup = None
    for step in range(STEPS):
        seq = store.seq_lens.copy()
        s_strs = seq - ls
        for li in range(Lh):
            fut = xfer.submit(xfer.fetch_layer, store, li, ls, s_strs,
                              0, s_pad)
            h_res, k_str, v_str, _ = fut.result()
            valid = int(seq[0])
            got_k = np.asarray(k_str)[0, :valid]
            got_v = np.asarray(v_str)[0, :valid]
            want_pos = np.arange(valid)
            np.testing.assert_array_equal(
                got_k, _kv_pattern(want_pos, KV, dh),
                err_msg=f"torn K read step={step} layer={li}")
            np.testing.assert_array_equal(
                got_v, _kv_pattern(want_pos, KV, dh, base=1000.0),
                err_msg=f"torn V read step={step} layer={li}")
            # fenced append of this step's new token (store pool), as
            # the runtime does: next step's fetch of layer li waits it
            new_pos = np.array([seq[0], -1])
            k_new = np.stack([_kv_pattern([seq[0]], KV, dh),
                              np.zeros((1, KV, dh), np.float32)])
            v_new = np.stack([_kv_pattern([seq[0]], KV, dh, 1000.0),
                              np.zeros((1, KV, dh), np.float32)])
            a_new = np.full((2, 1, h), float(seq[0]), np.float32)
            store.set_fence(li, xfer.submit_store(
                store.append, li, k_new, v_new, a_new, new_pos))
        store.seq_lens[0] += 1
        if step == 0:
            allocs_after_warmup = xfer.staging_allocs
    grew = xfer.staging_allocs - allocs_after_warmup

    writer.join()
    store.sync()                 # drains layer AND chunk fences
    assert not errors, errors
    assert grew == 0, f"staging allocated {grew} buffers after warmup"

    # slot 1's streamed chunks landed exactly, in order, untorn
    pos = np.arange(CHUNK_TOTAL)
    for li in range(Lh):
        np.testing.assert_array_equal(
            store.k[li, 1, :CHUNK_TOTAL],
            _kv_pattern(pos, KV, dh, base=5e4))
        np.testing.assert_array_equal(
            store.v[li, 1, :CHUNK_TOTAL],
            _kv_pattern(pos, KV, dh, base=6e4))
    np.testing.assert_array_equal(
        store.act[:, 1, :CHUNK_TOTAL],
        np.broadcast_to(pos.astype(np.float32)[None, :, None],
                        (Lh, CHUNK_TOTAL, h)))
    # slot 0's full decode trajectory is intact end to end
    final = int(store.seq_lens[0])
    assert final == s0 + STEPS
    for li in range(Lh):
        np.testing.assert_array_equal(
            store.k[li, 0, :final],
            _kv_pattern(np.arange(final), KV, dh))
    xfer.close()


@pytest.mark.slow
def test_tiered_store_concurrent_fetch_demote_promote():
    """The tiered extension: the same decode-style fetch/append loop —
    fetches now page demoted blocks back in through ``page_in`` inside
    ``fetch_layer`` — while a background thread aggressively demotes
    (capacity sweep) the whole time.  Every fetched value must still be
    its position-derived pattern: a torn read through ANY
    demote/page-in interleaving shows up as a wrong float.  The
    promote-then-redemote ping-pong (fetch windows start at l=0, so
    each step promotes everything the sweeper pushed out) maximizes
    boundary churn."""
    cfg = get_smoke_config("opt-6.7b").replace(num_layers=4)
    Lh, KV, dh, h = (cfg.num_layers, cfg.num_kv_heads, cfg.dh,
                     cfg.d_model)
    s0, steps, bt = 24, 16, 8
    max_len = s0 + steps + 4
    store = TieredKVStore(cfg, 2, max_len, tiers=KVTiersConfig(
        host_capacity_tokens=bt * 2, block_tokens=bt))
    xfer = TransferEngine(n_copy_threads=2)

    pos0 = np.arange(s0)
    for li in range(Lh):
        store.k[li, 0, :s0] = _kv_pattern(pos0, KV, dh)
        store.v[li, 0, :s0] = _kv_pattern(pos0, KV, dh, base=1000.0)
    store.act[:, 0, :s0] = np.arange(s0, dtype=np.float32)[:, None]
    store.seq_lens[0] = s0
    store.enforce_capacity()
    assert store.disk_tokens()[0] > 0          # seeded with demotions

    stop = threading.Event()
    errors = []

    def demoter():
        try:
            while not stop.is_set():
                store.sweep()
                time.sleep(0.0005)
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=demoter)
    t.start()
    try:
        ls = np.zeros(2, np.int64)
        for step in range(steps):
            seq = store.seq_lens.copy()
            s_strs = seq - ls
            for li in range(Lh):
                fut = xfer.submit(xfer.fetch_layer, store, li, ls,
                                  s_strs, 0, max_len)
                h_res, k_str, v_str, _ = fut.result()
                valid = int(seq[0])
                want = np.arange(valid)
                np.testing.assert_array_equal(
                    np.asarray(k_str)[0, :valid],
                    _kv_pattern(want, KV, dh),
                    err_msg=f"torn K read step={step} layer={li}")
                np.testing.assert_array_equal(
                    np.asarray(v_str)[0, :valid],
                    _kv_pattern(want, KV, dh, base=1000.0),
                    err_msg=f"torn V read step={step} layer={li}")
                new_pos = np.array([seq[0], -1])
                k_new = np.stack([_kv_pattern([seq[0]], KV, dh),
                                  np.zeros((1, KV, dh), np.float32)])
                v_new = np.stack(
                    [_kv_pattern([seq[0]], KV, dh, 1000.0),
                     np.zeros((1, KV, dh), np.float32)])
                a_new = np.full((2, 1, h), float(seq[0]), np.float32)
                store.set_fence(li, xfer.submit_store(
                    store.append, li, k_new, v_new, a_new, new_pos))
            store.seq_lens[0] += 1
        store.sync()
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    stats = store.stats()
    assert stats.demotions > 0 and stats.promotions > 0
    assert stats.demote_failures == 0
    # the full trajectory is intact end to end after all the churn
    final = int(store.seq_lens[0])
    assert final == s0 + steps
    for li in range(Lh):
        np.testing.assert_array_equal(
            store.k[li, 0, :final],
            _kv_pattern(np.arange(final), KV, dh))
        np.testing.assert_array_equal(
            store.v[li, 0, :final],
            _kv_pattern(np.arange(final), KV, dh, base=1000.0))
    store.close()
    xfer.close()
