"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline
tables (markdown to stdout)."""
from __future__ import annotations

import glob
import json
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "mistral-nemo-12b", "qwen3-moe-30b-a3b", "granite-moe-3b-a800m",
    "gemma3-12b", "tinyllama-1.1b", "whisper-tiny", "internvl2-76b",
    "zamba2-1.2b", "llama3.2-1b", "xlstm-350m",
]


def fmt_t(s):
    if s is None:
        return "-"
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.0f}us"


def fmt_b(b):
    if b is None:
        return "-"
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if b >= div:
            return f"{b/div:.2f}{unit}"
    return f"{b:.0f}B"


def main(path_glob="results/dryrun/*.json"):
    rows = {}
    for f in glob.glob(path_glob):
        for r in json.load(open(f)):
            rows[(r["arch"], r["shape"], r["mesh"])] = r

    # --- single-pod roofline table ---
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL_FLOPs/HLO_FLOPs | HBM args+temp/dev | "
          "what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, "single"))
            if r is None:
                print(f"| {a} | {s} | - | - | - | NOT RUN | - | - | |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | - | - | - | {r['status']} | - | - | |")
                continue
            mem = r.get("memory") or {}
            hbm = (mem.get("argument_bytes", 0) +
                   mem.get("temp_bytes", 0))
            hint = suggest(r)
            print(f"| {a} | {s} | {fmt_t(r['t_compute_s'])} | "
                  f"{fmt_t(r['t_memory_s'])} | "
                  f"{fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
                  f"{r['useful_flops_ratio']:.2f} | {fmt_b(hbm)} | "
                  f"{hint} |")

    # --- multi-pod lowering proof ---
    print()
    print("### Multi-pod (2x16x16 = 512 chips) lowering proof")
    print()
    print("| arch | " + " | ".join(SHAPE_ORDER) + " |")
    print("|---|" + "---|" * len(SHAPE_ORDER))
    for a in ARCH_ORDER:
        cells = []
        for s in SHAPE_ORDER:
            r = rows.get((a, s, "multi"))
            if r is None:
                cells.append("NOT RUN")
            elif r["status"] == "ok":
                cells.append(f"OK ({r['compile_s']}s)")
            elif r["status"].startswith("skip"):
                cells.append("skip")
            else:
                cells.append("FAIL")
        print(f"| {a} | " + " | ".join(cells) + " |")

    n_ok = sum(1 for r in rows.values() if r["status"] == "ok")
    n_skip = sum(1 for r in rows.values()
                 if r["status"].startswith("skip"))
    n_fail = len(rows) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} failed "
          f"of {len(rows)} recorded runs", file=sys.stderr)


def suggest(r) -> str:
    b = r["bottleneck"]
    shape = r["shape"]
    if b == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("KV cache reads dominate: donate cache buffers, "
                    "shard KV seq over model axis, 4-bit KV stream")
        return "activations dominate: fewer remat passes, bf16 end-to-end"
    if b == "collective":
        return ("param all-gathers dominate tiny compute: replicate "
                "params below FSDP threshold / overlap with compute")
    return "MXU-bound: raise per-chip batch or improve kernel fusion"




def compare(base_glob="results/dryrun/*.json",
            auto_glob="results/dryrun_auto/*.json"):
    """Optimized-vs-baseline table (run with: ... compare)."""
    def load(g):
        rows = {}
        for f in glob.glob(g):
            for r in json.load(open(f)):
                rows[(r["arch"], r["shape"], r.get("mesh", "single"))] = r
        return rows
    base = load(base_glob)
    auto = load(auto_glob)
    print("| arch | shape | baseline bound (term) | optimized bound "
          "(term) | gain | useful b->o |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b = base.get((a, s, "single"))
            o = auto.get((a, s, "single"))
            if not b or not o or b["status"] != "ok":
                continue
            if o["status"] != "ok":
                print(f"| {a} | {s} | - | {o['status'][:40]} | - | - |")
                continue
            tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
            to = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
            print(f"| {a} | {s} | {fmt_t(tb)} ({b['bottleneck']}) | "
                  f"{fmt_t(to)} ({o['bottleneck']}) | "
                  f"{tb/to:.1f}x | {b['useful_flops_ratio']:.2f} -> "
                  f"{o['useful_flops_ratio']:.2f} |")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "compare":
        compare(*sys.argv[2:])
    else:
        main(*sys.argv[1:])
