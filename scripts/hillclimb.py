import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver: lower+compile one (arch, shape) VARIANT on the
single-pod mesh and append its roofline row to results/hillclimb/.

A variant = a named bundle of {logical sharding rule overrides, model
options (remat / q_block / scan / seq_shard), lowering options}. Each
hillclimb iteration defines a hypothesis in EXPERIMENTS.md §Perf, runs

    PYTHONPATH=src python scripts/hillclimb.py --arch X --shape Y \
        --variant name [--set rule=axis ...] [--remat|--no-remat] \
        [--q-block N] [--seq-shard] [--layers N]

and compares the emitted terms against the baseline row.

    --layers N runs a reduced-depth unrolled lowering (for archs whose
    full unrolled compile is intractable here); compare variants at the
    SAME depth — deltas are what matter, and per-layer structure is
    depth-independent.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

import dataclasses
from repro.configs import get_config
from repro.launch import dryrun as DR
from repro.launch import roofline as RL
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import DEFAULT_RULES, logical_rules
from repro.models.transformer import Model


def parse_set(kvs):
    out = {}
    for kv in kvs or []:
        k, _, v = kv.partition("=")
        if v in ("none", "None", ""):
            out[k] = None
        elif "," in v:
            out[k] = tuple(v.split(","))
        else:
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=list(SP.INPUT_SHAPES))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--set", nargs="*", default=[],
                    help="logical rule overrides, e.g. kv_heads=model")
    ap.add_argument("--remat", dest="remat", action="store_true",
                    default=None)
    ap.add_argument("--no-remat", dest="remat", action="store_false")
    ap.add_argument("--q-block", type=int, default=4096)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--seq-axis", default="data",
                    help="mesh axis for KV sequence sharding")
    ap.add_argument("--scan", action="store_true")
    ap.add_argument("--moe-impl", default="gspmd",
                    choices=["gspmd", "shard_map"])
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--tp", default="model",
                    help="mesh axis for tensor parallelism ('none' to "
                         "disable)")
    ap.add_argument("--fsdp", default="data",
                    help="comma-joined mesh axes for FSDP param sharding")
    ap.add_argument("--dp", default="pod,data",
                    help="comma-joined mesh axes for data parallelism")
    ap.add_argument("--out-dir", default="results/hillclimb")
    args = ap.parse_args()

    from repro.launch.shardings import set_strategy
    set_strategy(tp=None if args.tp == "none" else args.tp,
                 fsdp=tuple(args.fsdp.split(",")) if args.fsdp else (),
                 dp=tuple(args.dp.split(",")) if args.dp else ())

    cfg = get_config(args.arch)
    if args.layers:
        cfg = dataclasses.replace(cfg, num_layers=args.layers)
    if args.ssm_chunk and cfg.ssm:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=args.ssm_chunk))
    ishape = SP.INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=False)
    model = Model(cfg, seq_shard=args.seq_shard, scan_layers=args.scan,
                  q_block=args.q_block, moe_impl=args.moe_impl)
    model.seq_axis = args.seq_axis
    if args.remat is not None:      # train lowering remat policy
        model.train_remat = args.remat

    rules = dict(DEFAULT_RULES)
    rules["batch"] = tuple(args.dp.split(",")) if args.dp else None
    if args.tp == "none":   # activation rules follow the param strategy
        for k in ("heads", "mlp", "vocab", "experts", "ssm_heads"):
            rules[k] = None
    if args.seq_shard:
        rules["kv_seq"] = args.seq_axis
        if args.seq_axis == "data":
            rules["batch"] = None
    rules.update(parse_set(args.set))

    t0 = time.perf_counter()
    with logical_rules(rules, mesh):
        with mesh:
            if ishape.kind == "train":
                lowered = DR._lower_train(model, cfg, ishape, mesh)
            elif ishape.kind == "prefill":
                lowered = DR._lower_prefill(model, cfg, ishape, mesh)
            else:
                lowered = DR._lower_decode(model, cfg, ishape, mesh)
            compiled = lowered.compile()
    t_all = time.perf_counter() - t0

    mf = RL.model_flops_per_device(cfg, ishape, mesh.devices.size)
    row = RL.from_compiled(compiled, args.arch, args.shape,
                           "single", mf).row()
    row.update({"variant": args.variant, "rule_overrides": args.set,
                "remat": args.remat, "q_block": args.q_block,
                "seq_shard": args.seq_shard, "layers": args.layers,
                "wall_s": round(t_all, 1), "status": "ok"})
    print(f"[{args.arch} x {args.shape}] variant={args.variant} "
          f"({t_all:.0f}s)")
    print(f"  compute={row['t_compute_s']*1e3:.3f}ms "
          f"memory={row['t_memory_s']*1e3:.3f}ms "
          f"collective={row['t_collective_s']*1e3:.3f}ms "
          f"-> {row['bottleneck']}")
    print(f"  flops/dev={row['flops_per_dev']:.3e} "
          f"bytes/dev={row['bytes_per_dev']:.3e} "
          f"coll/dev={row['coll_bytes_per_dev']:.3e} "
          f"useful={row['useful_flops_ratio']:.3f}")
    cd = {k: f"{v/2**20:.0f}MiB/{row['coll_counts'].get(k, 0)}"
          for k, v in row["coll_detail"].items() if v}
    print(f"  collectives: {cd}")

    os.makedirs(args.out_dir, exist_ok=True)
    path = os.path.join(args.out_dir,
                        f"{args.arch}_{args.shape}.json")
    hist = []
    if os.path.exists(path):
        hist = json.load(open(path))
    hist.append(row)
    with open(path, "w") as f:
        json.dump(hist, f, indent=1)
    print("appended ->", path)


if __name__ == "__main__":
    main()
