#!/usr/bin/env bash
# Tier-1 CI: collection sanity, the full test suite, and a smoke of the
# quickstart example.  Run from the repo root:
#
#     bash scripts/ci.sh [--no-install]
#
# `hypothesis` is an optional test dependency (the property suites skip
# without it — see docs/automation.md); CI installs it so they run.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--no-install" ]]; then
    python -m pip install --quiet "jax[cpu]" pytest hypothesis
fi

# 1. Collection must be clean: a bad import in any test file (e.g. an
#    unguarded optional dependency) fails here in seconds, not after the
#    whole suite has run.
python -m pytest -q --collect-only >/dev/null

# 2. Tier-1 suite.
python -m pytest -x -q

# 3. Smoke the quickstart end-to-end (profiler -> scheduler -> serving);
#    the timeout guards CI against pathological slowdowns.
timeout "${QUICKSTART_TIMEOUT:-300}" python examples/quickstart.py

# 4. Decode hot-path smoke: fails if the steady-state loop performs any
#    XLA retrace or staging allocation (see docs/performance.md).
timeout "${BREAKDOWN_TIMEOUT:-300}" \
    python benchmarks/bench_step_breakdown.py --smoke

# 5. Serve-API round-trip: the request-level front door (EngineConfig +
#    SamplingParams + streaming) over static+continuous x
#    resident+offload, incl. a mixed greedy/temperature/early-EOS batch
#    (see docs/api.md).
timeout "${SERVE_TIMEOUT:-300}" python -m repro.launch.serve --smoke

echo "ci.sh: all checks passed"
