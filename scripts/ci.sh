#!/usr/bin/env bash
# Tier-1 CI: collection sanity, the test suite, and end-to-end smokes.
# Run from the repo root:
#
#     bash scripts/ci.sh [--no-install] [--fast]
#
# --fast runs the fast test tier only (pytest -m "not slow") — the
# pre-push lane.  The full suite (slow tests included) stays the
# default and is what the GitHub workflow runs.
#
# `hypothesis` is an optional test dependency (the property suites skip
# without it — see docs/automation.md); CI installs it so they run.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

INSTALL=1
FAST=0
for arg in "$@"; do
    case "$arg" in
        --no-install) INSTALL=0 ;;
        --fast) FAST=1 ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

if [[ "$INSTALL" == "1" ]]; then
    # pytest-timeout enforces the per-test deadline in pyproject.toml;
    # without it tests/conftest.py falls back to a SIGALRM shim.
    python -m pip install --quiet "jax[cpu]" pytest pytest-timeout \
        hypothesis
fi

# 1. Collection must be clean: a bad import in any test file (e.g. an
#    unguarded optional dependency) fails here in seconds, not after the
#    whole suite has run.
python -m pytest -q --collect-only >/dev/null

# 2. Tier-1 suite: fast tier on --fast, everything otherwise.
#    --durations=15 keeps the slowest tests visible in the CI log, so a
#    creeping suite is caught by eye before it is caught by timeout.
if [[ "$FAST" == "1" ]]; then
    python -m pytest -x -q --durations=15 -m "not slow"
else
    python -m pytest -x -q --durations=15
fi

# 3. Smoke the quickstart end-to-end (profiler -> scheduler -> serving);
#    the timeout guards CI against pathological slowdowns.
timeout "${QUICKSTART_TIMEOUT:-300}" python examples/quickstart.py

# 4. Decode hot-path smoke: fails if the steady-state loop performs any
#    XLA retrace or staging allocation (see docs/performance.md).
timeout "${BREAKDOWN_TIMEOUT:-300}" \
    python benchmarks/bench_step_breakdown.py --smoke

# 4b. Kernel-parity smoke: the Pallas decode hot path (interpret mode
#     on CPU, native on TPU) must emit tokens IDENTICAL to the jnp
#     oracle over the same trajectory, for both fp and int4 streamed KV
#     (see docs/performance.md, "The Pallas kernel path").
timeout "${KERNEL_TIMEOUT:-300}" \
    python benchmarks/bench_step_breakdown.py --smoke --kernels on
timeout "${KERNEL_TIMEOUT:-300}" \
    python benchmarks/bench_step_breakdown.py --smoke --kernels on \
        --compress int4

# 4c. Committed benchmark trajectory: the BENCH_*.json snapshots at the
#     repo root must parse and carry passing gates.
python scripts/bench_trajectory.py

# 5. Serve-API round-trip: the request-level front door (EngineConfig +
#    SamplingParams + streaming) over static+continuous x
#    resident+offload, incl. a ragged static batch checked against the
#    per-request reference, a mixed greedy/temperature/early-EOS batch,
#    and a prefix-cache restore round-trip (see docs/api.md).
timeout "${SERVE_TIMEOUT:-300}" python -m repro.launch.serve --smoke

# 6. Shared-prefix cache smoke: a warm run must skip prefill for the
#    matched tokens AND emit tokens identical to the cold run.
timeout "${PREFIX_TIMEOUT:-300}" python benchmarks/bench_prefix.py --smoke

# 7. Chunked-prefill smoke: token-budgeted chunked admission of a
#    >=1k-token prompt under continuous batching must stall in-flight
#    decodes strictly less than inline admission, with identical
#    tokens (see docs/performance.md).
timeout "${CHUNKED_TIMEOUT:-300}" \
    python benchmarks/bench_chunked_prefill.py --smoke

# 8. Fault-layer smoke: the fault-injection/recovery layer must be
#    free when disabled (step-time gate vs the committed baseline) and
#    token-exact under injected transient faults (see
#    docs/robustness.md).
timeout "${FAULTS_TIMEOUT:-600}" \
    python benchmarks/bench_faults.py --smoke

# 9. Router-tier smoke: 2 replicas over a mixed-priority shared-prefix
#    batch — routed outputs token-identical to the single-engine
#    reference, warm-prefix hits > 0 under prefix placement, and a
#    preempted decode resumes token-exact (see docs/serving.md).
timeout "${ROUTER_TIMEOUT:-600}" python -m repro.launch.router --smoke

# 9b. Trace-replay smoke: replays one bursty shared-prefix trace under
#     prefix vs round_robin placement; gates cross-policy token
#     identity and the warm-hit advantage (the p99 tail comparison is
#     judged on the committed BENCH_router_replay.json in step 4c —
#     a 20-request CPU tail is too noisy to gate per run).
timeout "${ROUTER_REPLAY_TIMEOUT:-600}" \
    python benchmarks/bench_router_replay.py --smoke

# 10. Tiered KV store smoke: sessions whose working set exceeds the
#     DRAM budget must decode token-identically to the all-DRAM run,
#     and the tier_split plan must beat naive demand paging on both
#     wall clock and disk bytes read (see docs/storage.md).
timeout "${TIERED_TIMEOUT:-300}" \
    python benchmarks/bench_tiered.py --smoke

# 11. Mesh-sharded decode smoke: 1/2/4-way model-axis meshes must emit
#     identical tokens (sharding is data-plane only), and at fixed
#     split geometry each shard stream must carry 1/k of the unsharded
#     streamed-KV link bytes (see docs/scaling.md).
timeout "${SHARDED_TIMEOUT:-300}" \
    python benchmarks/bench_sharded.py --smoke

echo "ci.sh: all checks passed"
