#!/usr/bin/env python
"""Aggregate the committed BENCH_*.json trajectory into one table.

The repo commits machine-readable benchmark snapshots at the root
(BENCH_step_breakdown.json, BENCH_prefix.json,
BENCH_chunked_prefill.json, BENCH_faults.json,
BENCH_router_replay.json) so perf-relevant PRs carry their measured
effect.  This script renders them side by side — run it after
regenerating any snapshot to eyeball the trajectory:

    PYTHONPATH=src python scripts/bench_trajectory.py [--dir REPO_ROOT]

Exits non-zero if a committed snapshot recorded a failing gate
(smoke_ok / tokens_identical false), so CI can keep the committed
trajectory honest.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

FILES = ["BENCH_step_breakdown.json", "BENCH_prefix.json",
         "BENCH_chunked_prefill.json", "BENCH_faults.json",
         "BENCH_router_replay.json", "BENCH_tiered.json",
         "BENCH_sharded.json"]


def _load(root: pathlib.Path):
    out = {}
    for name in FILES:
        p = root / name
        if p.exists():
            with open(p) as f:
                out[name] = json.load(f)
    return out


def _fmt_step_breakdown(d) -> list:
    rows = []
    if "cells" in d:  # --matrix snapshot
        for cell, r in sorted(d["cells"].items()):
            s = r["steady"]
            rows.append((cell, f"{s['step_ms']:.2f} ms/step",
                         f"compute {s['t_compute_s']:.3f}s",
                         f"wait {s['t_wait_s']:.3f}s",
                         f"fence {s['t_fence_s']:.3f}s",
                         f"{s['bytes_transferred'] / 1e6:.1f} MB"))
    else:  # single-cell snapshot
        s = d["steady"]
        c = d["config"]
        cell = f"{c['mode']}/{c.get('kernels', 'off')}"
        rows.append((cell, f"{s['step_ms']:.2f} ms/step",
                     f"compute {s['t_compute_s']:.3f}s",
                     f"wait {s['t_wait_s']:.3f}s",
                     f"fence {s['t_fence_s']:.3f}s",
                     f"{s['bytes_transferred'] / 1e6:.1f} MB"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json snapshots")
    args = ap.parse_args(argv)
    data = _load(pathlib.Path(args.dir))
    if not data:
        print(f"no BENCH_*.json under {args.dir}", file=sys.stderr)
        return 1

    failed = []
    if "BENCH_step_breakdown.json" in data:
        d = data["BENCH_step_breakdown.json"]
        print("== decode step breakdown "
              f"({json.dumps(d.get('shape', d.get('config')))}) ==")
        for row in _fmt_step_breakdown(d):
            print("  " + "  ".join(f"{c:>18s}" if i else f"{c:<16s}"
                                   for i, c in enumerate(row)))
        for cell, r in d.get("cells", {}).items():
            if r["steady"]["retraces"] or r["steady"]["staging_allocs"]:
                failed.append(f"step_breakdown:{cell} retraced/allocated")
        if d.get("smoke_ok") is False:
            failed.append("step_breakdown smoke_ok=false")

    if "BENCH_prefix.json" in data:
        d = data["BENCH_prefix.json"]
        cold, warm = d["cold"], d["warm"]
        print("== shared-prefix cache ==")
        print(f"  cold {cold['wall_s']:.2f}s "
              f"({cold['prefilled_tokens']} tok prefilled)  ->  "
              f"warm {warm['wall_s']:.2f}s "
              f"({warm['restored_tokens']} tok restored, "
              f"hit_rate {warm['hit_rate']:.2f})")
        if not d.get("tokens_identical", True):
            failed.append("prefix tokens_identical=false")
        if d.get("smoke_ok") is False:
            failed.append("prefix smoke_ok=false")

    if "BENCH_chunked_prefill.json" in data:
        d = data["BENCH_chunked_prefill.json"]
        p, a = d["prefill"], d["admission"]
        print("== chunked prefill ==")
        print(f"  prefill {p['inline_tok_s']:.0f} -> "
              f"{p['chunked_tok_s']:.0f} tok/s "
              f"({p['n_chunks']} chunks of {p['chunk']})")
        print(f"  admission stall {a['inline']['max_stall_s']:.3f}s -> "
              f"{a['chunked']['max_stall_s']:.3f}s "
              f"(x{a['stall_ratio']:.1f} better)")
        if not p.get("logits_identical", True) \
                or not a.get("tokens_identical", True):
            failed.append("chunked_prefill identity=false")
        if d.get("smoke_ok") is False:
            failed.append("chunked_prefill smoke_ok=false")

    if "BENCH_faults.json" in data:
        d = data["BENCH_faults.json"]
        off, idle, rec = d["off"], d["idle"], d["recovery"]
        print("== fault layer ==")
        print(f"  off {off['step_ms']:.2f} ms/step "
              f"(floor {off['floor_step_ms']:.2f}, "
              f"{off['overhead_vs_baseline_pct']:+.2f}% vs "
              f"{d['baseline']['step_ms']:.2f} baseline)  "
              f"idle {idle['step_ms']:.2f}")
        print(f"  recovery {rec['per_fault_ms']:.2f} ms/fault "
              f"({rec['injected_faults']} injected, "
              f"{rec['retries']} retries)")
        if not d.get("gate", {}).get("ok", True):
            failed.append("faults gate ok=false")
        if not idle.get("tokens_identical", True) \
                or not rec.get("tokens_identical", True):
            failed.append("faults tokens_identical=false")
        if d.get("smoke_ok") is False:
            failed.append("faults smoke_ok=false")

    if "BENCH_router_replay.json" in data:
        d = data["BENCH_router_replay.json"]
        print("== router trace replay "
              f"({json.dumps(d.get('config'))}) ==")
        for name, r in d.get("policies", {}).items():
            cls = "  ".join(
                f"{k}={v['attained']:.2f}"
                for k, v in sorted(r.get("per_class", {}).items()))
            print(f"  {name:<13s} warm {r['warm_hit_rate']:.2f}  "
                  f"ttft p50 {r['ttft_p50_s']:.2f}s "
                  f"p99 {r['ttft_p99_s']:.2f}s  "
                  f"preempt {r['preemptions']}  slo[{cls}]")
        # the committed snapshot must carry the full victory: identity,
        # warm-hit AND the p99 tail (the per-run smoke only enforces
        # the deterministic subset — see benchmarks/bench_router_replay)
        for gate, ok in d.get("gates", {}).items():
            if not ok:
                failed.append(f"router_replay {gate}=false")
        if "p99_ttft" not in d.get("gates", {}):
            failed.append("router_replay p99_ttft gate missing")

    if "BENCH_tiered.json" in data:
        d = data["BENCH_tiered.json"]
        cap = d["capacity"]
        print("== tiered KV store "
              f"({json.dumps(d.get('config'))}) ==")
        print(f"  working set {cap['working_set_tokens']} tok "
              f"({cap['beyond_dram_tokens']} beyond DRAM, "
              f"{cap['sessions_beyond_dram']} sessions)")
        for name in ("dram", "tier_split", "demand"):
            c = d["cells"][name]
            disk = (f"  disk_read {c['disk_bytes_read'] / 1e6:.2f} MB  "
                    f"promotions {c['promotions']}"
                    if "disk_bytes_read" in c else "")
            print(f"  {name:<11s} {c['step_ms']:8.2f} ms/step{disk}")
        for gate, ok in d.get("gates", {}).items():
            if not ok:
                failed.append(f"tiered {gate}=false")
        if d.get("smoke_ok") is False:
            failed.append("tiered smoke_ok=false")

    if "BENCH_sharded.json" in data:
        d = data["BENCH_sharded.json"]
        print("== mesh-sharded decode "
              f"({json.dumps(d.get('config'))}) ==")
        for name in ("tp1", "tp2", "tp4"):
            c = d["cells"][name]
            sb = c.get("shard_kv_bytes")
            per = ("  shard_kv " + "/".join(f"{b / 1e6:.2f}" for b in sb)
                   + " MB" if sb else "")
            print(f"  {name:<5s} {c['step_ms']:8.2f} ms/step  "
                  f"split_l {c['split_l_max']:>3d}{per}")
        probe = d.get("link_probe", {})
        if probe:
            print(f"  link probe ({probe['mode']}): unsharded "
                  f"{probe['unsharded_kv_bytes'] / 1e6:.2f} MB -> "
                  "tp2 " + "/".join(
                      f"{b / 1e6:.2f}"
                      for b in probe["tp2_shard_kv_bytes"]) + "  tp4 "
                  + "/".join(f"{b / 1e6:.2f}"
                             for b in probe["tp4_shard_kv_bytes"]))
        for gate, ok in d.get("gates", {}).items():
            if not ok:
                failed.append(f"sharded {gate}=false")
        if d.get("smoke_ok") is False:
            failed.append("sharded smoke_ok=false")

    missing = [f for f in FILES if f not in data]
    if missing:
        print(f"(missing snapshots: {', '.join(missing)})")
    if failed:
        print("TRAJECTORY FAIL: " + "; ".join(failed), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
