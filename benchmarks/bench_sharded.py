"""Mesh-sharded decode benchmark: 1/2/4-way model-axis data planes.

Runs the same decode trajectory through three mesh sizes and emits one
JSON object (committed as BENCH_sharded.json):

  tp1   the unsharded offload runtime — the identity reference
  tp2   2-way model-axis mesh: per-shard plans (Workload/
        HardwareProfile.per_shard), head-sliced kernel launches, and
        2 concurrent per-KV-head-slice copy streams per fetch
  tp4   4-way mesh, same machinery

Sharding is data-plane only — the store keeps full arrays and each
shard streams a disjoint head-slice of the same staging buffer — so
every mesh size must emit byte-identical tokens; what changes is the
plan (per-shard FLOPs shrink, the link share narrows) and the per-shard
link traffic.  Each cell reports step time plus the per-shard
streamed-KV byte breakdown drained from ``StepStats.shard_kv_bytes``.

Gates (--smoke exits non-zero if any fails):

  tokens_identical   tp1, tp2, tp4 emit the same tokens
  shard_bytes_split  per-shard streams are even, and each shard carries
                     ~1/k of the unsharded streamed-KV bytes (the
                     across-mesh invariant total)

    PYTHONPATH=src python benchmarks/bench_sharded.py [--smoke]
        [--json out.json] [--batch B] [--prompt S] [--gen N]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model

MESHES = (1, 2, 4)


def _spill(cfg, model, params, toks, gen):
    """Prefill then land the KV in a fresh host store."""
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    b, s = toks.shape
    store = HostKVStore(cfg, b, s + gen + 2)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
    return store, first


def _run_cell(cfg, model, params, sched, toks, gen, shards,
              mode="kvpr"):
    """(tokens, wall_s, per-shard streamed-KV byte totals) for one mesh
    size, with a warmup decode so XLA compilation and staging/shard-pool
    allocation are off the clock."""
    with OffloadDecodeRuntime(cfg, params, scheduler=sched,
                              mode=mode, shards=shards) as rt:
        store, first = _spill(cfg, model, params, toks, gen)
        rt.decode(store, first, gen)
        store.close()

        store, first = _spill(cfg, model, params, toks, gen)
        t0 = time.perf_counter()
        tokens, stats = rt.decode(store, first, gen)
        dt = time.perf_counter() - t0
        store.close()
    per_shard = [0] * shards
    for st in stats:
        if st.shard_kv_bytes is not None:
            for si, b in enumerate(st.shard_kv_bytes):
                per_shard[si] += b
    return np.asarray(tokens), dt, stats, per_shard


def run(batch: int = 2, prompt: int = 48, gen: int = 16) -> dict:
    cfg = get_smoke_config("opt-6.7b").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        (batch, prompt)).astype(np.int32)
    sched = Scheduler(profile_system())

    cells = {}
    for k in MESHES:
        tokens, dt, stats, per_shard = _run_cell(
            cfg, model, params, sched, toks, gen, k)
        cell = {
            "shards": k,
            "wall_s": round(dt, 4),
            "step_ms": round(dt / gen * 1e3, 3),
            "tokens_per_s": round(batch * gen / dt, 2),
            "split_l_max": max(st.split_l for st in stats),
            "bytes_transferred": sum(st.bytes_transferred
                                     for st in stats),
        }
        if k > 1:
            cell["shard_kv_bytes"] = per_shard
            cell["kv_bytes_total"] = sum(per_shard)
        cells[f"tp{k}"] = cell
        cells[f"tp{k}"]["_tokens"] = tokens
        extra = (f"  shard_kv={[round(b / 1e6, 2) for b in per_shard]}MB"
                 if k > 1 else "")
        print(f"  tp{k}: step={cell['step_ms']:8.2f}ms{extra}",
              file=sys.stderr)

    toks_ref = cells["tp1"].pop("_tokens")
    identical = all(
        np.array_equal(toks_ref, cells[f"tp{k}"].pop("_tokens"))
        for k in MESHES if k > 1)

    # Under kvpr plans the streamed-KV total is NOT mesh-invariant (the
    # per-shard cost model shifts the split toward recompute as the
    # link share narrows — visible above as split_l growing with k), so
    # the 1/k byte claim is gated at FIXED geometry: flexgen streams
    # the whole window (l = 0) at every mesh size, making the total a
    # mesh invariant each shard must carry exactly 1/k of.  1%
    # tolerance absorbs the per-fetch // rounding.
    probe = {}
    for k in (2, 4):
        _, _, _, per_shard = _run_cell(cfg, model, params, sched, toks,
                                       gen, k, mode="flexgen")
        probe[k] = per_shard
    unsharded = sum(probe[2])
    split_ok = unsharded > 0 and \
        abs(sum(probe[4]) - unsharded) <= unsharded * 0.01
    for k, per in probe.items():
        even = max(per) - min(per) <= k          # // rounding slack
        near = all(abs(b - unsharded / k) <= unsharded / k * 0.01
                   for b in per)
        split_ok = split_ok and even and near

    return {
        "benchmark": "mesh_sharded_decode",
        "config": {"batch": batch, "prompt": prompt, "gen": gen,
                   "num_layers": cfg.num_layers, "d_model": cfg.d_model,
                   "num_kv_heads": cfg.num_kv_heads,
                   "meshes": list(MESHES)},
        "cells": cells,
        "link_probe": {"mode": "flexgen",
                       "unsharded_kv_bytes": unsharded,
                       "tp2_shard_kv_bytes": probe[2],
                       "tp4_shard_kv_bytes": probe[4]},
        "gates": {"tokens_identical": bool(identical),
                  "shard_bytes_split": bool(split_ok)},
        "smoke_ok": bool(identical and split_ok),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="small run; exit 1 unless tokens are identical "
                         "across mesh sizes AND per-shard link bytes "
                         "split evenly at 1/k of the unsharded stream")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batch, args.prompt, args.gen = 2, 24, 8
    res = run(batch=args.batch, prompt=args.prompt, gen=args.gen)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        print(f"SMOKE FAIL: gates={res['gates']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
