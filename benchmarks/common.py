"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.cost_model import HardwareProfile, Workload


def opt_workload(arch: str, batch: int, seq_len: int,
                 dtype_bytes: float = 2,
                 weights_offloaded: bool = False) -> Workload:
    cfg = get_config(arch)
    kv_dim = cfg.num_kv_heads * cfg.dh
    mha_bytes = int(4 * cfg.d_model * cfg.d_model * dtype_bytes) \
        if weights_offloaded else 0
    return Workload(batch=batch, seq_len=seq_len, d_model=cfg.d_model,
                    kv_dim=kv_dim, dtype_bytes=dtype_bytes,
                    mha_weight_bytes=mha_bytes)


def ffn_flops(arch: str, batch: int) -> float:
    """Per-layer decode FFN FLOPs (1 token per sequence)."""
    cfg = get_config(arch)
    mults = 3 if cfg.gated_mlp else 2
    return 2.0 * batch * mults * cfg.d_model * cfg.d_ff


def layers_of(arch: str) -> int:
    return get_config(arch).num_layers


def fmt_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
