"""Paper Fig. 7 / Tables 3-4: decode latency for a single batch of 64,
latency-oriented workload (weights resident in GPU memory), HF-Accelerate
baseline (full KV transfer) vs KVPR — across prompt lengths {128, 256,
512} and generation lengths {32, 128}."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, layers_of, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import decode_latency

# paper Tables 3-4 decode latency (s): (prompt, gen) -> (accel, kvpr)
PAPER = {
    "opt-6.7b": {(128, 32): (8.905, 6.651), (128, 128): (71.327, 45.766),
                 (256, 32): (26.825, 19.138), (256, 128): (88.354, 61.597),
                 (512, 32): (24.390, 20.349), (512, 128): (110.277, 93.932)},
    "opt-13b": {(128, 32): (11.409, 9.148), (128, 128): (73.896, 66.119),
                (256, 32): (19.381, 16.654), (256, 128): (104.115, 88.492),
                (512, 32): (35.066, 29.215), (512, 128): (168.155, 138.377)},
}


def _calibrate_overhead(arch: str) -> float:
    """Fit the fixed per-layer system overhead from ONE measured baseline
    row (prompt 128 / gen 32) — everything else is then predicted."""
    L = layers_of(arch)
    paper_base, _ = PAPER[arch][(128, 32)]

    def wl_fn(g):
        return opt_workload(arch, 64, 128 + g)
    ideal = decode_latency(wl_fn, A100_PCIE4, L, 32, method="flexgen",
                           d_ff_flops=ffn_flops(arch, 64))
    return max(0.0, (paper_base - ideal) / (L * 32))


def run(print_csv: bool = True):
    rows = []
    for arch in ("opt-6.7b", "opt-13b"):
        L = layers_of(arch)
        ovh = _calibrate_overhead(arch)
        for prompt in (128, 256, 512):
            for gen in (32, 128):
                def wl_fn(g, _p=prompt):
                    return opt_workload(arch, 64, _p + g)
                base = decode_latency(wl_fn, A100_PCIE4, L, gen,
                                      method="flexgen",
                                      d_ff_flops=ffn_flops(arch, 64),
                                      overhead_s=ovh)
                ours = decode_latency(wl_fn, A100_PCIE4, L, gen,
                                      method="kvpr", schedule="row",
                                      d_ff_flops=ffn_flops(arch, 64),
                                      overhead_s=ovh)
                red = (1 - ours / base) * 100
                paper = PAPER.get(arch, {}).get((prompt, gen))
                pred = (1 - paper[1] / paper[0]) * 100 if paper else None
                rows.append((arch, prompt, gen, base, ours, red, pred))
                if print_csv:
                    extra = (f" paper_reduction={pred:.1f}%"
                             if pred is not None else "")
                    print(fmt_row(
                        f"fig7/{arch}/p{prompt}g{gen}",
                        f"{ours*1e6:.0f}",
                        f"base_s={base:.2f} kvpr_s={ours:.2f} "
                        f"reduction={red:.1f}%{extra}"))
    return rows


if __name__ == "__main__":
    run()
