"""Benchmark runner — one function per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig7]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_runtime_real, fig6_throughput, fig7_latency,
                        fig8_utilization, fig9_compression, fig10_breakdown,
                        fig12_split_points, fig13_llama2, fig14_cpu_scaling,
                        table1_pcie_vs_compute, table2_hiding_ablation)

BENCHES = [
    ("table1", table1_pcie_vs_compute.run),
    ("fig7", fig7_latency.run),
    ("fig6", fig6_throughput.run),
    ("table2", table2_hiding_ablation.run),
    ("fig8", fig8_utilization.run),
    ("fig9", fig9_compression.run),
    ("fig10", fig10_breakdown.run),
    ("fig12", fig12_split_points.run),
    ("fig13", fig13_llama2.run),
    ("fig14", fig14_cpu_scaling.run),
    ("runtime_real", bench_runtime_real.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            fn(print_csv=True)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
