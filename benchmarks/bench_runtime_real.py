"""Executable-runtime benchmark (no simulation): real wall-clock decode on
the CPU validation runtime — host KV store streamed via the copy-thread
pool, FlexGen mode (full KV transfer) vs KVPR (solver split + recompute).
On this container the 'link' is memcpy; the overlap structure and the
transferred-byte reduction are the same as on the TPU target."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import fmt_row
from repro.configs import get_smoke_config
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model


def run(print_csv: bool = True, prompt: int = 192, gen: int = 8,
        batch: int = 4):
    cfg = get_smoke_config("opt-6.7b").replace(
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    hw = profile_system()
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (batch, prompt)).astype(np.int32)
    logits, ks, vs, hs = prefill_with_activations(
        model, params, np.asarray(toks))
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)

    # On this container the measured link (memcpy) is too fast relative to
    # CPU GEMM for recomputation to ever pay off — the solver correctly
    # picks l=0 (an adaptive-hardware result in itself). To exercise the
    # split path we emulate the paper's PCIe regime by slowing the modeled
    # link 50x for the *scheduling decision*; data movement stays real.
    # break-even needs v_gpu/v_com > 2h/p flops-per-byte; solve for the
    # link speed that puts the optimum mid-range given the measured GEMM
    import dataclasses
    h = cfg.d_model
    target_link = hw.gpu_flops / (4 * h / 4)  # ~2x past break-even
    hw_pcie_regime = dataclasses.replace(
        hw, link_bandwidth=min(hw.link_bandwidth, target_link))
    # one Scheduler across all modes: each (mode, compress) combination
    # is its own PlanKey, and within a run the plan's bucketed solves are
    # amortized across decode steps
    sched = Scheduler(hw_pcie_regime)

    rows = []
    results = {}
    for mode, compress in (("flexgen", None), ("kvpr", None),
                           ("kvpr_int4", "int4")):
        store = HostKVStore(cfg, batch, prompt + gen + 2,
                            compress=compress)
        store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs),
                        prompt)
        rt = OffloadDecodeRuntime(cfg, params, scheduler=sched,
                                  mode="kvpr" if compress else mode,
                                  schedule="row", align=32,
                                  compress=compress)
        with rt:
            # warmup jit caches with one token, then measure
            _t, _ = rt.decode(store, np.asarray(first), 1)
            t0 = time.perf_counter()
            toks_out, stats = rt.decode(store, np.asarray(_t), gen)
            dt = time.perf_counter() - t0
        nbytes = sum(s.bytes_transferred for s in stats)
        results[mode] = (toks_out, dt, nbytes, stats)
        tps = batch * gen / dt
        if print_csv:
            print(fmt_row(
                f"runtime_real/{mode}", f"{dt/gen*1e6:.0f}",
                f"tok_per_s={tps:.2f} bytes_streamed={nbytes} "
                f"mean_split={np.mean([s.split_l for s in stats]):.0f} "
                f"retraces={sum(s.retraces for s in stats)} "
                f"t_store_ms={sum(s.t_store for s in stats)*1e3:.0f}"))
        rows.append((mode, dt, nbytes))
    same = np.array_equal(results["flexgen"][0], results["kvpr"][0])
    byte_red = 1 - results["kvpr"][2] / max(results["flexgen"][2], 1)
    byte_red4 = 1 - results["kvpr_int4"][2] / max(results["flexgen"][2], 1)
    agree4 = np.mean(results["flexgen"][0] == results["kvpr_int4"][0])
    if print_csv:
        plan = rt.plan_for(batch)
        print(fmt_row("runtime_real/summary", "0",
                      f"outputs_identical={same} "
                      f"bytes_reduced={byte_red*100:.1f}% "
                      f"int4_bytes_reduced={byte_red4*100:.1f}% "
                      f"int4_token_agreement={agree4*100:.0f}% "
                      f"plan_solves={plan.solves} "
                      f"plan_lookups={plan.lookups}"))
    return rows


if __name__ == "__main__":
    run()
