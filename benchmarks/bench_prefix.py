"""Shared-prefix KV cache benchmark, machine-readable.

Serves families of prompts that share a common prefix (the few-shot /
system-prompt pattern) twice: COLD (prefix cache disabled — every
request prefills from scratch) and WARM (prefix cache enabled — each
request after the first restores the shared prefix via the scheduler's
KVPR split and prefills only its suffix).  Emits one JSON object with
the prefilled-token counts, the restore split (tokens recomputed from
activations vs streamed as KV), hit rate, and wall times — and asserts
the warm run's tokens are IDENTICAL to the cold run's.

    PYTHONPATH=src python benchmarks/bench_prefix.py [--smoke]
        [--json out.json] [--backend resident|offload]
        [--batching static|continuous] [--arch tinyllama-1.1b]
        [--shared 48] [--suffix 8] [--per-family 4] [--gen 8]

--smoke exits non-zero unless the warm run is token-identical to the
cold run AND actually skipped prefill for a positive number of matched
tokens (wired into scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, LLMEngine, PrefixCacheConfig,
                           Request)


def _prompts(cfg, rng, shared: int, suffix: int, per_family: int):
    """One family of prompts: a shared prefix + distinct suffixes."""
    base = rng.integers(1, cfg.vocab_size, shared).astype(np.int32)
    return [np.concatenate([base, rng.integers(
        1, cfg.vocab_size, suffix).astype(np.int32)])
        for _ in range(per_family)]


def _serve(engine, prompts, gen: int):
    """Serve each prompt as its own generate() call (so later requests
    can hit prefixes inserted when earlier ones finished)."""
    outs = []
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        outs.extend(engine.generate(
            [Request(uid=i, prompt=p, max_new_tokens=gen)]))
    return outs, time.perf_counter() - t0


def run(backend: str = "offload", batching: str = "static",
        arch: str = "tinyllama-1.1b", shared: int = 48, suffix: int = 8,
        per_family: int = 4, gen: int = 8, seed: int = 0,
        smoke: bool = False) -> dict:
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = _prompts(cfg, rng, shared, suffix, per_family)
    total_prompt_tokens = sum(len(p) for p in prompts)
    sched = Scheduler(A100_PCIE4)
    max_len = shared + suffix + gen + 8

    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend=backend, batching=batching,
                         max_len=max_len),
            scheduler=sched) as cold_eng:
        cold, t_cold = _serve(cold_eng, prompts, gen)

    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend=backend, batching=batching,
                         max_len=max_len,
                         prefix_cache=PrefixCacheConfig()),
            scheduler=sched) as warm_eng:
        warm, t_warm = _serve(warm_eng, prompts, gen)
        stats = warm_eng.prefix_stats

    identical = all(np.array_equal(c.tokens, w.tokens)
                    for c, w in zip(cold, warm))
    matched = sum(o.cached_prefix for o in warm)
    recomputed = sum(o.restore.recomputed for o in warm if o.restore)
    streamed = sum(o.restore.streamed for o in warm if o.restore)
    bytes_streamed = sum(o.restore.bytes_streamed
                         for o in warm if o.restore)
    out = {
        "config": {"backend": backend, "batching": batching,
                   "arch": arch, "shared": shared, "suffix": suffix,
                   "per_family": per_family, "gen": gen},
        "cold": {"wall_s": round(t_cold, 4),
                 "prefilled_tokens": total_prompt_tokens},
        "warm": {
            "wall_s": round(t_warm, 4),
            "prefilled_tokens": total_prompt_tokens - matched,
            "restored_tokens": matched,
            "restore_split": {"recomputed": recomputed,
                              "streamed": streamed,
                              "bytes_streamed": bytes_streamed},
            "hit_rate": round(stats.hit_rate, 3),
            "entries": stats.entries,
            "tokens_stored": stats.tokens_stored,
            "evictions": stats.evictions,
        },
        "tokens_identical": bool(identical),
    }
    if smoke:
        out["smoke_ok"] = bool(identical and matched > 0)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="offload",
                    choices=["resident", "offload"])
    ap.add_argument("--batching", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shared", type=int, default=48)
    ap.add_argument("--suffix", type=int, default=8)
    ap.add_argument("--per-family", type=int, default=4)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="small run; exit 1 unless warm == cold tokens "
                         "and a positive prefix match occurred")
    args = ap.parse_args(argv)

    if args.smoke:
        args.shared, args.suffix, args.per_family, args.gen = 16, 4, 3, 4
    res = run(backend=args.backend, batching=args.batching,
              arch=args.arch, shared=args.shared, suffix=args.suffix,
              per_family=args.per_family, gen=args.gen, seed=args.seed,
              smoke=args.smoke)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        print("SMOKE FAIL: warm run diverged or no prefix was restored "
              f"(identical={res['tokens_identical']} "
              f"restored={res['warm']['restored_tokens']})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
