"""Tiered KV store benchmark: tier_split vs demand paging vs warm DRAM.

Runs the same decode trajectory through three storage configurations
and emits one JSON object (committed as BENCH_tiered.json):

  dram        plain HostKVStore, everything resident — the warm
              baseline the tiered store must not distort
  tier_split  TieredKVStore with host capacity below the working set
              and an emulated slow disk rung; the fourth plan kind
              solves the split over BOTH links, so fetch windows mostly
              stay off the demoted prefix
  demand      same store and the same slow disk, but the plan stays
              disk-blind (naive demand paging): every demoted token
              under the fetch window is paged back in on use

The sessions genuinely exceed DRAM: ``host_capacity_tokens`` is set
well below batch x (prompt + gen), so a demoted disk prefix exists for
the whole decode (appends re-trigger capacity demotion every step).
The disk rung's emulated bandwidth makes the paging cost real
wall-clock time, so the win is measured, not modeled.

Gates (--smoke exits non-zero if any fails):

  tokens_identical     all three configurations emit the same tokens
                       (the raw disk layout is lossless)
  tiered_beats_demand  tier_split wall-clock < demand wall-clock AND
                       tier_split reads strictly fewer disk bytes
                       (the deterministic half of the same claim)

    PYTHONPATH=src python benchmarks/bench_tiered.py [--smoke]
        [--json out.json] [--batch B] [--prompt S] [--gen N]
        [--host-capacity T] [--disk-bw BYTES_PER_S]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.kvstore import KVTiersConfig, TieredKVStore
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model


def _spill(cfg, model, params, toks, gen, tiers):
    """Prefill then land the KV in the benchmarked store (bulk_fill on
    a tiered store immediately demotes down to the DRAM budget)."""
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    b, s = toks.shape
    if tiers is None:
        store = HostKVStore(cfg, b, s + gen + 2)
    else:
        store = TieredKVStore(cfg, b, s + gen + 2, tiers=tiers)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs), s)
    return store, first


def _run_cell(cfg, model, params, sched, toks, gen, tiers):
    """(tokens, wall_s, step stats, tiered store stats|None) for one
    storage configuration, with a warmup decode so XLA compilation and
    staging allocation are off the clock."""
    with OffloadDecodeRuntime(cfg, params, scheduler=sched,
                              mode="kvpr") as rt:
        store, first = _spill(cfg, model, params, toks, gen, tiers)
        rt.decode(store, first, gen)
        store.close()

        store, first = _spill(cfg, model, params, toks, gen, tiers)
        t0 = time.perf_counter()
        tokens, stats = rt.decode(store, first, gen)
        dt = time.perf_counter() - t0
        tstats = store.stats() if tiers is not None else None
        store.close()
    return np.asarray(tokens), dt, stats, tstats


def run(batch: int = 2, prompt: int = 48, gen: int = 16,
        host_capacity: int | None = None,
        disk_bw: float = 20e6) -> dict:
    cfg = get_smoke_config("opt-6.7b").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        (batch, prompt)).astype(np.int32)
    sched = Scheduler(profile_system())
    if host_capacity is None:
        # DRAM holds roughly a third of the working set
        host_capacity = max(8, batch * (prompt + gen) // 3)

    def tiers(policy):
        return KVTiersConfig(host_capacity_tokens=host_capacity,
                             block_tokens=8,
                             disk_read_bytes_per_s=disk_bw,
                             policy=policy)

    cells = {}
    for label, kt in (("dram", None), ("tier_split",
                                       tiers("tier_split")),
                      ("demand", tiers("demand"))):
        tokens, dt, stats, ts = _run_cell(cfg, model, params, sched,
                                          toks, gen, kt)
        cell = {
            "wall_s": round(dt, 4),
            "step_ms": round(dt / gen * 1e3, 3),
            "tokens_per_s": round(batch * gen / dt, 2),
        }
        if ts is not None:
            cell.update({
                "demotions": ts.demotions,
                "promotions": ts.promotions,
                "demote_failures": ts.demote_failures,
                "disk_bytes_read": ts.disk_bytes_read,
                "disk_bytes_written": ts.disk_bytes_written,
                "demoted_tokens_final": ts.demoted_tokens,
            })
        cells[label] = cell
        cells[label]["_tokens"] = tokens
        print(f"  {label:<10s}: step={cell['step_ms']:8.2f}ms"
              + (f"  disk_read={ts.disk_bytes_read / 1e6:.2f}MB "
                 f"promotions={ts.promotions}" if ts else ""),
              file=sys.stderr)

    toks_ref = cells["dram"].pop("_tokens")
    identical = all(
        np.array_equal(toks_ref, cells[k].pop("_tokens"))
        for k in ("tier_split", "demand"))
    ts_cell, dm_cell = cells["tier_split"], cells["demand"]
    beats = (ts_cell["wall_s"] < dm_cell["wall_s"]
             and ts_cell["disk_bytes_read"] < dm_cell["disk_bytes_read"])
    working_set = batch * (prompt + gen)
    return {
        "benchmark": "tiered_kv_store",
        "config": {"batch": batch, "prompt": prompt, "gen": gen,
                   "num_layers": cfg.num_layers, "d_model": cfg.d_model,
                   "host_capacity_tokens": host_capacity,
                   "block_tokens": 8,
                   "disk_read_bytes_per_s": disk_bw},
        "capacity": {
            "working_set_tokens": working_set,
            "beyond_dram_tokens": working_set - host_capacity,
            "sessions_beyond_dram": batch,
        },
        "cells": cells,
        "gates": {"tokens_identical": bool(identical),
                  "tiered_beats_demand": bool(beats)},
        "smoke_ok": bool(identical and beats),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--host-capacity", type=int, default=None,
                    help="DRAM token budget (default: ~working set / 3)")
    ap.add_argument("--disk-bw", type=float, default=20e6,
                    help="emulated disk read bandwidth, bytes/s")
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="small run; exit 1 unless tokens are identical "
                         "across all three configs AND tier_split beats "
                         "demand paging")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batch, args.prompt, args.gen = 2, 24, 8
    res = run(batch=args.batch, prompt=args.prompt, gen=args.gen,
              host_capacity=args.host_capacity, disk_bw=args.disk_bw)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        print(f"SMOKE FAIL: gates={res['gates']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
