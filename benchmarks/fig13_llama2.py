"""Paper Fig. 13 (Appendix A.6): decoding throughput on LLaMa2-7B/13B,
single batch of 64, latency-oriented workload — same machinery as Fig. 7
but on the gated-FFN RoPE llama2 architecture (the paper's point: the
recomputation technique is architecture-agnostic; KVPR beats the
full-KV-transfer baseline on LLaMa2 exactly as on OPT)."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, layers_of, opt_workload
from benchmarks.fig7_latency import _calibrate_overhead
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import decode_latency

# per-layer system overhead fitted from the paper's OPT rows on the same
# hardware (fig7); llama2-7b/13b have the same d_model as opt-6.7b/13b
_OVH_FROM = {"llama2-7b": "opt-6.7b", "llama2-13b": "opt-13b"}


def run(print_csv: bool = True):
    rows = []
    for arch in ("llama2-7b", "llama2-13b"):
        L = layers_of(arch)
        ovh = _calibrate_overhead(_OVH_FROM[arch])
        for prompt in (128, 256, 512):
            for gen in (32, 128):
                def wl_fn(g, _p=prompt):
                    return opt_workload(arch, 64, _p + g)
                base = decode_latency(wl_fn, A100_PCIE4, L, gen,
                                      method="flexgen",
                                      d_ff_flops=ffn_flops(arch, 64),
                                      overhead_s=ovh)
                ours = decode_latency(wl_fn, A100_PCIE4, L, gen,
                                      method="kvpr", schedule="row",
                                      d_ff_flops=ffn_flops(arch, 64),
                                      overhead_s=ovh)
                base_tps = 64 * gen / base
                ours_tps = 64 * gen / ours
                up = (ours_tps / base_tps - 1) * 100
                rows.append((arch, prompt, gen, base_tps, ours_tps, up))
                if print_csv:
                    print(fmt_row(
                        f"fig13/{arch}/p{prompt}g{gen}",
                        f"{ours * 1e3:.0f}",
                        f"baseline_tps={base_tps:.1f} "
                        f"kvpr_tps={ours_tps:.1f} speedup={up:.1f}%"))
        # invariant: KVPR never slower than the baseline
        assert all(r[4] >= r[3] * 0.999 for r in rows if r[0] == arch)
    return rows


if __name__ == "__main__":
    run()
