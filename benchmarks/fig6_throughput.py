"""Paper Fig. 6: decoding throughput (tokens/s), throughput-oriented
workload — weights offloaded to CPU, column-by-column schedule, effective
batch 32x8 — FlexGen baseline vs KVPR. Second row: batch sweep 1..48 at
prompt 1024 / gen 32."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, layers_of, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import flexgen_step, kvpr_step

PAPER_MAX_SPEEDUP = {"opt-6.7b": 15.1, "opt-13b": 46.2, "opt-30b": 29.0}


def _throughput(arch: str, batch: int, num_batches: int, prompt: int,
                gen: int, method: str) -> float:
    """Column schedule: per layer, each of num_batches batches streams its
    KV + activations while weights stay resident for the layer."""
    L = layers_of(arch)
    total = 0.0
    for g in range(gen):
        wl = opt_workload(arch, batch, prompt + g, weights_offloaded=True)
        if method == "flexgen":
            st = flexgen_step(wl, A100_PCIE4, weights_resident=False,
                              d_ff_flops=ffn_flops(arch, batch))
            per_batch = max(st.t_layer - wl.mha_weight_bytes /
                            A100_PCIE4.v_com, st.t_attn)
            # weights amortized over the batch group
            t_layer_group = wl.mha_weight_bytes / A100_PCIE4.v_com + \
                num_batches * per_batch
        else:
            st = kvpr_step(wl, A100_PCIE4, schedule="column",
                           weights_resident=False, fine_grained=True,
                           d_ff_flops=ffn_flops(arch, batch))
            per_batch = st.t_act + max(st.t_recomp, st.t_kv)
            per_batch = max(per_batch, st.t_attn)
            t_layer_group = wl.mha_weight_bytes / A100_PCIE4.v_com + \
                num_batches * per_batch
        total += L * t_layer_group
    return batch * num_batches * gen / total


def run(print_csv: bool = True):
    rows = []
    for arch in ("opt-6.7b", "opt-13b", "opt-30b"):
        for prompt in (256, 512, 1024):
            for gen in (32,):
                fg = _throughput(arch, 32, 8, prompt, gen, "flexgen")
                kv = _throughput(arch, 32, 8, prompt, gen, "kvpr")
                speed = (kv / fg - 1) * 100
                rows.append((arch, prompt, gen, fg, kv, speed))
                if print_csv:
                    print(fmt_row(
                        f"fig6/{arch}/p{prompt}",
                        f"{1e6/kv:.0f}",
                        f"flexgen_tps={fg:.1f} kvpr_tps={kv:.1f} "
                        f"speedup={speed:.1f}% "
                        f"(paper max {PAPER_MAX_SPEEDUP[arch]}%)"))
    # batch sweep
    for b in (1, 8, 16, 32, 48):
        fg = _throughput("opt-6.7b", b, 8, 1024, 32, "flexgen")
        kv = _throughput("opt-6.7b", b, 8, 1024, 32, "kvpr")
        rows.append(("opt-6.7b-batch", b, 32, fg, kv, (kv / fg - 1) * 100))
        if print_csv:
            print(fmt_row(f"fig6/batch_sweep/b{b}", f"{1e6/kv:.0f}",
                          f"flexgen_tps={fg:.1f} kvpr_tps={kv:.1f} "
                          f"speedup={(kv/fg-1)*100:.1f}%"))
    return rows


if __name__ == "__main__":
    run()
