"""Paper Fig. 12 (appendix A.4): optimal split point l over the
generation process (prompt 128, gen 32, OPT-6.7B — paper: l=182 early,
descending toward 128... our solver reproduces the trajectory shape)."""
from __future__ import annotations

from benchmarks.common import fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.solver import optimal_split


def run(print_csv: bool = True):
    arch = "opt-6.7b"
    rows = []
    for g in range(0, 33, 4):
        wl = opt_workload(arch, 64, 128 + g)
        d = optimal_split(wl, A100_PCIE4, schedule="row")
        rows.append((g, d.l, d.t_total))
        if print_csv:
            print(fmt_row(f"fig12/gen{g}", f"{d.t_total*1e6:.1f}",
                          f"split_l={d.l} of s'={128+g}"))
    return rows


if __name__ == "__main__":
    run()
