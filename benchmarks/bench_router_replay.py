"""Trace-replay benchmark for the multi-replica router tier.

Replays ONE synthetic request trace — Poisson or bursty arrivals,
shared-prefix request families (the RAG / system-prompt workload),
mixed SLO classes with mixed priorities — against a RouterEngine under
each placement policy (prefix-aware vs round_robin vs least_loaded,
same replicas, same per-replica prefix caches), and emits a
machine-readable comparison: per-class SLO attainment, p50/p99 TTFT
and queue wait, warm-prefix hit rates, preemption counts.

    PYTHONPATH=src python benchmarks/bench_router_replay.py [--smoke]
        [--json out.json] [--requests 36] [--replicas 2]
        [--families 4] [--shared 48] [--suffix 4] [--gen 6]
        [--arrival bursty|poisson] [--rate 8.0] [--burst 6]

Gates (recorded in the JSON):

  - tokens_identical: every policy's outputs are token-identical per
    uid (placement is an execution decision, never a semantics
    decision);
  - warm_hit / p99_ttft: prefix-aware placement beats round_robin on
    warm-prefix hit rate AND on p99 TTFT — keeping a family on its
    warm replica turns that family's prefills into KVPR-split
    restores, and under load the saved prefill work is exactly what
    shortens the queue tail.  The per-replica caches are sized to one
    replica's SHARE of the family working set (see run()): placement
    decides warmth only when no single replica can hold everything.

--smoke exits non-zero when tokens_identical or warm_hit fails; the
p99 tail of a 20-request CPU-container trace is dominated by host
scheduler noise and stray XLA compilation, so the tail comparison is
enforced on the committed full-size run (BENCH_router_replay.json,
checked by scripts/bench_trajectory.py) rather than per-CI-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cost_model import A100_PCIE4
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import (EngineConfig, PrefixCacheConfig, Request,
                           SamplingParams)
from repro.serving.router import RouterConfig, RouterEngine

SLO_CYCLE = ("interactive", "standard", "batch")


@dataclasses.dataclass
class TraceItem:
    at_s: float                  # arrival offset from replay start
    req: Request
    sp: SamplingParams


def build_trace(cfg, rng, n: int, families: int, shared: int,
                suffix: int, gen: int, arrival: str, rate: float,
                burst: int):
    """The replayed workload: ``n`` requests over ``families``
    shared-prefix families, SLO class (and its default priority)
    cycling per request, arrivals either Poisson (exponential
    inter-arrival at ``rate`` req/s) or bursty (bursts of ``burst``
    back-to-back arrivals, exponential gaps between bursts)."""
    bases = [rng.integers(1, cfg.vocab_size, shared).astype(np.int32)
             for _ in range(families)]
    items, t = [], 0.0
    for i in range(n):
        if arrival == "poisson":
            t += rng.exponential(1.0 / rate)
        elif arrival == "bursty":
            if i % burst == 0 and i > 0:
                t += rng.exponential(burst / rate)
        else:
            raise ValueError(f"unknown arrival process {arrival!r}")
        base = bases[i % families]
        prompt = np.concatenate([
            base, rng.integers(1, cfg.vocab_size,
                               suffix).astype(np.int32)])
        slo = SLO_CYCLE[i % len(SLO_CYCLE)]
        # seeded temperature on a third of the trace: identity across
        # policies must hold for stochastic requests too (the
        # sampling-stream invariant, one level up)
        sp = (SamplingParams(max_tokens=gen, temperature=0.7, seed=i)
              if i % 3 == 2 else SamplingParams(max_tokens=gen))
        items.append(TraceItem(t, Request(uid=i, prompt=prompt,
                                          slo=slo), sp))
    return items


def replay(model, params, trace, policy: str, replicas: int,
           scheduler, cache_tokens: int = 65536, speed: float = 1.0):
    """Replay the trace against a fresh router (fresh replica engines,
    COLD prefix caches) under ``policy``; returns (outputs by uid,
    router stats, per-class summary, wall seconds)."""
    ec = EngineConfig(prefix_cache=PrefixCacheConfig(
        min_prefix=8, capacity_tokens=cache_tokens))
    rc = RouterConfig(replicas=replicas, policy=policy)
    outs = {}
    with RouterEngine(model, params, ec, rc,
                      scheduler=scheduler) as router:
        t0 = time.perf_counter()
        uids = []
        for item in trace:
            delay = item.at_s / speed - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            uids.append(router.submit(item.req, item.sp))
        for uid in uids:
            outs[uid] = router.wait(uid)
        wall = time.perf_counter() - t0
        stats = router.stats()
        classes = router.per_class(outs.values())
    return outs, stats, classes, wall


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def summarize(outs, stats, classes, wall: float):
    served = [o for o in outs.values() if len(o.tokens)]
    ttfts = [o.ttft for o in served]
    waits = [o.queue_wait for o in served]
    tpots = [o.tpot for o in served if o.tpot > 0]
    n_tok = sum(len(o.tokens) for o in served)
    return {
        "requests": len(outs),
        "served": len(served),
        "tokens": int(n_tok),
        "wall_s": wall,
        "tok_s": n_tok / wall,
        "warm_hit_rate": stats.warm_hit_rate,
        "warm_tokens": int(stats.warm_tokens),
        "preemptions": stats.preemptions,
        "deadline_drops": stats.deadline_drops,
        "ttft_p50_s": _pct(ttfts, 50),
        "ttft_p99_s": _pct(ttfts, 99),
        "queue_wait_p50_s": _pct(waits, 50),
        "queue_wait_p99_s": _pct(waits, 99),
        "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
        "per_class": classes,
        "per_replica_dispatched": [r.dispatched for r in
                                   stats.replicas],
    }


def run(requests: int = 36, replicas: int = 2, families: int = 5,
        shared: int = 48, suffix: int = 4, gen: int = 6,
        arrival: str = "bursty", rate: float = 2.0, burst: int = 4,
        arch: str = "tinyllama-1.1b", seed: int = 0,
        cache_tokens: int = 0,
        policies=("prefix", "round_robin", "least_loaded")) -> dict:
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    sched = Scheduler(A100_PCIE4)
    trace = build_trace(cfg, rng, requests, families, shared, suffix,
                        gen, arrival, rate, burst)

    # Per-replica caches sized to hold one replica's SHARE of the
    # family working set (plus one slot of headroom), not all of it.
    # This is the regime where placement decides warmth: under prefix
    # placement each replica keeps its owned families resident, while
    # scatter placement cycles every family through every replica and
    # the LRU thrashes.  With the 64k default every replica holds
    # everything and the policies converge on warmth.
    if cache_tokens <= 0:
        entry = shared + suffix + gen
        cache_tokens = (-(-families // replicas) + 1) * entry

    # one throwaway request compiles the prefill/decode traces so the
    # first measured policy doesn't pay XLA compilation in its TTFTs
    warmup = [TraceItem(0.0, Request(uid=10_000, prompt=trace[0]
                                     .req.prompt.copy()),
                        SamplingParams(max_tokens=2))]
    replay(model, params, warmup, "round_robin", replicas, sched,
           cache_tokens)

    results, tokens_by_uid = {}, {}
    for policy in policies:
        outs, stats, classes, wall = replay(model, params, trace,
                                            policy, replicas, sched,
                                            cache_tokens)
        results[policy] = summarize(outs, stats, classes, wall)
        tokens_by_uid[policy] = {uid: list(map(int, o.tokens))
                                 for uid, o in outs.items()}

    base = tokens_by_uid[policies[0]]
    identical = all(tokens_by_uid[p] == base for p in policies[1:])
    pre, rr = results.get("prefix"), results.get("round_robin")
    gates = {"tokens_identical": bool(identical)}
    if pre and rr:
        gates["warm_hit"] = bool(
            pre["warm_hit_rate"] > rr["warm_hit_rate"])
        gates["p99_ttft"] = bool(
            pre["ttft_p99_s"] < rr["ttft_p99_s"])
    # the deterministic gates CI enforces per run; p99_ttft is judged
    # on the committed full-size JSON (see module docstring)
    smoke_gates = [k for k in ("tokens_identical", "warm_hit")
                   if k in gates]
    return {
        "bench": "router_replay",
        "config": {
            "arch": arch, "requests": requests, "replicas": replicas,
            "families": families, "shared": shared, "suffix": suffix,
            "gen": gen, "arrival": arrival, "rate": rate,
            "burst": burst, "seed": seed,
            "cache_tokens": cache_tokens,
        },
        "policies": results,
        "gates": gates,
        "smoke_gates": smoke_gates,
        "smoke_ok": bool(all(gates[k] for k in smoke_gates)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--replicas", type=int, default=2)
    # keep families coprime-ish with replicas: when families is a
    # multiple of the replica count, round_robin's rotation pins each
    # family to one replica BY ACCIDENT and the baseline stops being a
    # scatter baseline
    ap.add_argument("--families", type=int, default=5)
    ap.add_argument("--shared", type=int, default=48)
    ap.add_argument("--suffix", type=int, default=4)
    ap.add_argument("--gen", type=int, default=6)
    ap.add_argument("--arrival", default="bursty",
                    choices=["bursty", "poisson"])
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--burst", type=int, default=4,
                    help="bursty: arrivals per burst")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-tokens", type=int, default=0,
                    help="per-replica prefix-cache capacity; 0 sizes "
                         "it to one replica's share of the families "
                         "plus one slot of headroom")
    ap.add_argument("--json", default=None,
                    help="also write the JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace; exit non-zero unless every gate "
                         "passes (wired into scripts/ci.sh)")
    args = ap.parse_args(argv)

    kw = dict(requests=args.requests, replicas=args.replicas,
              families=args.families, shared=args.shared,
              suffix=args.suffix, gen=args.gen, arrival=args.arrival,
              rate=args.rate, burst=args.burst, arch=args.arch,
              seed=args.seed, cache_tokens=args.cache_tokens)
    if args.smoke:
        kw.update(requests=20, families=5, shared=32, gen=4,
                  burst=4, rate=2.0,
                  policies=("prefix", "round_robin"))
    res = run(**kw)

    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        print("bench_router_replay --smoke FAILED gates: "
              f"{res['gates']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
