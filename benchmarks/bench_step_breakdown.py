"""Decode hot-path step breakdown, machine-readable.

Runs a real (executable, CPU-validation) offload decode and emits one
JSON object with the per-step timing split the fenced runtime now
measures — t_wait (fetch stall), t_compute (device + dispatch),
t_store (overlapped host write-back) — plus link throughput, the XLA
retrace count, and the staging-allocation count.  CI runs the smoke
invocation so hot-path regressions (a retrace per step, a fresh staging
buffer per step) fail loudly instead of silently eating the overlap win.

    PYTHONPATH=src python benchmarks/bench_step_breakdown.py [--smoke]
        [--json out.json] [--mode kvpr|flexgen] [--compress int4]
        [--batch B] [--prompt S] [--gen N] [--kernels auto|on|off]
        [--matrix]

--smoke exits non-zero unless, after a warmup decode, a second decode of
the same trajectory performs ZERO retraces and ZERO staging allocations
— and, when the kernel path is on, unless the kernel-path tokens are
IDENTICAL to the jnp-oracle tokens for the same trajectory (the CI
kernel-parity gate).

--matrix runs the committed benchmark trajectory: {kvpr, flexgen, int4}
x {jnp, kernel} in one combined JSON, each cell with the per-step
compute / transfer / fence split.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.profiler import profile_system
from repro.core.runtime import (HostKVStore, OffloadDecodeRuntime,
                                prefill_with_activations)
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model


def _spill(cfg, model, params, toks, gen, compress):
    logits, ks, vs, hs = prefill_with_activations(model, params, toks)
    first = np.asarray(np.argmax(logits, axis=-1), np.int32)
    store = HostKVStore(cfg, toks.shape[0], toks.shape[1] + gen + 2,
                        compress=compress)
    store.bulk_fill(np.asarray(ks), np.asarray(vs), np.asarray(hs),
                    toks.shape[1])
    return store, first


def run(mode: str = "kvpr", compress=None, batch: int = 2,
        prompt: int = 48, gen: int = 16, smoke: bool = False,
        kernels="off") -> dict:
    cfg = get_smoke_config("opt-6.7b").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        (batch, prompt)).astype(np.int32)
    sched = Scheduler(profile_system())
    with OffloadDecodeRuntime(cfg, params, scheduler=sched,
                              mode=mode, compress=compress,
                              kernels=kernels) as rt:
        # warmup: compile every pad bucket of the trajectory + allocate
        # the staging buffers once
        store, first = _spill(cfg, model, params, toks, gen, compress)
        t0 = time.perf_counter()
        _, warm_stats = rt.decode(store, first, gen)
        t_warm = time.perf_counter() - t0

        # measured steady state: same trajectory, fresh store, warm
        # caches
        store, first = _spill(cfg, model, params, toks, gen, compress)
        allocs0, traces0 = rt.xfer.staging_allocs, rt.compute.traces()
        t0 = time.perf_counter()
        tokens, stats = rt.decode(store, first, gen)
        dt = time.perf_counter() - t0

    parity_ok = None
    if smoke and rt.compute.kernel_path:
        # kernel-parity gate: the jnp oracle over the same trajectory
        # must emit the IDENTICAL token sequence
        with OffloadDecodeRuntime(cfg, params, scheduler=sched,
                                  mode=mode, compress=compress,
                                  kernels="off") as rt_ref:
            store, first = _spill(cfg, model, params, toks, gen,
                                  compress)
            ref_tokens, _ = rt_ref.decode(store, first, gen)
        parity_ok = bool(np.array_equal(np.asarray(tokens),
                                        np.asarray(ref_tokens)))

    retraces = sum(st.retraces for st in stats)
    new_allocs = rt.xfer.staging_allocs - allocs0
    nbytes = sum(st.bytes_transferred for st in stats)
    out = {
        "config": {"mode": mode, "compress": compress, "batch": batch,
                   "prompt": prompt, "gen": gen,
                   "num_layers": cfg.num_layers,
                   "d_model": cfg.d_model,
                   "kernels": rt.compute.kernel_mode},
        "warmup": {"wall_s": round(t_warm, 4),
                   "retraces": sum(st.retraces for st in warm_stats)},
        "steady": {
            "wall_s": round(dt, 4),
            "step_ms": round(dt / gen * 1e3, 3),
            "tokens_per_s": round(batch * gen / dt, 2),
            "t_wait_s": round(sum(st.t_wait_transfer for st in stats), 4),
            "t_compute_s": round(sum(st.t_compute for st in stats), 4),
            "t_store_s": round(sum(st.t_store for st in stats), 4),
            "t_fence_s": round(sum(st.t_fence for st in stats), 4),
            "bytes_transferred": int(nbytes),
            "bytes_per_s": round(nbytes / dt, 1),
            "retraces": int(retraces),
            "staging_allocs": int(new_allocs),
            "traces_total": rt.compute.traces(),
            "kernel_path": bool(stats[-1].kernel_path),
            "pad_buckets": sorted({(st.l_pad, st.s_pad)
                                   for st in stats}),
        },
    }
    if smoke:
        out["smoke_ok"] = bool(retraces == 0 and new_allocs == 0
                               and parity_ok is not False)
        if parity_ok is not None:
            out["kernel_parity_ok"] = parity_ok
    return out


#: the committed benchmark trajectory: every offload mode on both the
#: jnp-oracle path and the Pallas kernel path
MATRIX = [("kvpr", None), ("flexgen", None), ("int4", "int4")]


def run_matrix(batch: int = 2, prompt: int = 48, gen: int = 16) -> dict:
    cells = {}
    for label, compress in MATRIX:
        mode = "flexgen" if label == "flexgen" else "kvpr"
        for path, kernels in (("jnp", "off"), ("kernel", "on")):
            r = run(mode=mode, compress=compress, batch=batch,
                    prompt=prompt, gen=gen, kernels=kernels)
            cells[f"{label}/{path}"] = {"config": r["config"],
                                        "steady": r["steady"]}
            s = r["steady"]
            print(f"  {label:8s} {path:6s}: step={s['step_ms']:8.2f}ms "
                  f"compute={s['t_compute_s']:.3f}s "
                  f"wait={s['t_wait_s']:.3f}s "
                  f"fence={s['t_fence_s']:.3f}s", file=sys.stderr)
    return {"benchmark": "step_breakdown_matrix",
            "shape": {"batch": batch, "prompt": prompt, "gen": gen},
            "cells": cells}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="kvpr",
                    choices=["kvpr", "flexgen"])
    ap.add_argument("--compress", default=None, choices=[None, "int4"])
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kernels", default="off",
                    choices=["auto", "on", "off", "interpret"],
                    help="Pallas decode hot path (on: native on TPU, "
                         "interpret mode on CPU)")
    ap.add_argument("--matrix", action="store_true",
                    help="run {kvpr,flexgen,int4} x {jnp,kernel} and "
                         "emit one combined JSON")
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="small run; exit 1 on any steady-state retrace "
                         "or staging allocation, or (with --kernels) on "
                         "any kernel/jnp token mismatch")
    args = ap.parse_args(argv)

    if args.smoke:
        args.batch, args.prompt, args.gen = 2, 24, 8
    if args.matrix:
        res = run_matrix(batch=args.batch, prompt=args.prompt,
                         gen=args.gen)
    else:
        res = run(mode=args.mode, compress=args.compress,
                  batch=args.batch, prompt=args.prompt, gen=args.gen,
                  smoke=args.smoke, kernels=args.kernels)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not args.matrix and not res["smoke_ok"]:
        print("SMOKE FAIL: steady-state decode retraced or allocated "
              f"(retraces={res['steady']['retraces']} "
              f"staging_allocs={res['steady']['staging_allocs']}) "
              f"or kernel parity broke "
              f"(kernel_parity_ok={res.get('kernel_parity_ok')})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
