"""Chunked-prefill benchmark, machine-readable.

Two measurements of the last unpipelined stage (see docs/performance.md):

  prefill   static offload path — one monolithic prefill followed by one
            monolithic ``bulk_fill`` versus the streamed ``ChunkedPrefill``
            pipeline (each finished chunk's host write-back overlaps the
            next chunk's compute).  Reports prefilled tokens/s for both.

  admission continuous batching with decodes in flight — a short request
            decodes while a LONG prompt is admitted into a freed slot.
            Inline admission prefills the whole prompt between two decode
            steps, stalling every in-flight request for the duration;
            chunked admission interleaves prompt chunks with decode steps
            under ``max_step_tokens``.  Reports the MAX per-step stall
            (wall gap between the in-flight request's consecutive tokens)
            for both.

    PYTHONPATH=src python benchmarks/bench_chunked_prefill.py [--smoke]
        [--json out.json] [--arch tinyllama-1.1b] [--prompt 1024]
        [--chunk auto|N] [--gen 16] [--batch 2]

--smoke exits non-zero unless chunked admission's max per-step stall is
STRICTLY below inline admission's for the long prompt (wired into
scripts/ci.sh) and the two runs' tokens are identical.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.profiler import profile_system
from repro.core.runtime import ChunkedPrefill, HostKVStore, \
    prefill_with_activations
from repro.core.scheduler import Scheduler
from repro.models.transformer import Model
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams


def _bench_prefill(cfg, model, params, sched, prompt: int, batch: int,
                   chunk) -> dict:
    """Static offload prefill: monolithic + bulk_fill vs streamed."""
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (batch, prompt)).astype(np.int32)
    if chunk == "auto":
        chunk_w = sched.chunk_split(cfg, prompt, batch=batch).chunk
    else:
        chunk_w = int(chunk)

    # jit the monolithic baseline too: both sides then run compiled
    # XLA, so the measured gap is the pipeline (write-back overlap +
    # chunked attention working set), not jit-vs-eager dispatch
    inline_fn = jax.jit(lambda p, t: prefill_with_activations(model, p,
                                                              t))

    with LLMEngine.from_config(model, params,
                               EngineConfig(backend="offload"),
                               scheduler=sched) as eng:
        xfer = eng.runtime.xfer

        def inline_once():
            store = HostKVStore(cfg, batch, prompt + 2)
            t0 = time.perf_counter()
            lg, ks, vs, hs = inline_fn(params, jnp.asarray(toks))
            store.bulk_fill(np.asarray(ks), np.asarray(vs),
                            np.asarray(hs), prompt)
            return time.perf_counter() - t0, lg

        def chunked_once():
            store = HostKVStore(cfg, batch, prompt + 2)
            t0 = time.perf_counter()
            cp = ChunkedPrefill(model, params, toks, chunk_w,
                                store=store, xfer=xfer)
            lg = cp.finish()
            store.seq_lens[:] = prompt
            return time.perf_counter() - t0, lg

        inline_once(); chunked_once()          # warmup: compile + staging
        t_inline, lg_a = inline_once()
        t_chunked, lg_b = chunked_once()
    identical = bool(np.allclose(np.asarray(lg_a), np.asarray(lg_b),
                                 atol=1e-5))
    n_tok = batch * prompt
    return {"tokens": n_tok, "chunk": int(chunk_w),
            "n_chunks": -(-prompt // chunk_w),
            "inline_wall_s": round(t_inline, 4),
            "chunked_wall_s": round(t_chunked, 4),
            "inline_tok_s": round(n_tok / t_inline, 1),
            "chunked_tok_s": round(n_tok / t_chunked, 1),
            "logits_identical": identical}


def _admission_run(cfg, model, params, sched, prompt: int, gen: int,
                   chunk, max_len: int) -> dict:
    """One continuous-batching run: uid0 decodes throughout, uid1 frees
    its slot after 2 tokens, uid2 (the LONG prompt) admits mid-decode.
    Returns per-uid tokens and the max wall gap between uid0's
    consecutive events — the admission stall every in-flight request
    pays."""
    rng = np.random.default_rng(1)
    reqs = [Request(uid=0, prompt=rng.integers(
                1, cfg.vocab_size, 12).astype(np.int32)),
            Request(uid=1, prompt=rng.integers(
                1, cfg.vocab_size, 10).astype(np.int32)),
            Request(uid=2, prompt=rng.integers(
                1, cfg.vocab_size, prompt).astype(np.int32))]
    sps = [SamplingParams(max_tokens=gen),
           SamplingParams(max_tokens=2),
           SamplingParams(max_tokens=4)]
    kw = {}
    if chunk is not None:
        chunk_w = (sched.chunk_split(cfg, prompt).chunk
                   if chunk == "auto" else int(chunk))
        kw = dict(prefill_chunk=chunk_w,
                  max_step_tokens=len(reqs) + chunk_w)
    with LLMEngine.from_config(
            model, params,
            EngineConfig(backend="offload", batching="continuous",
                         slots=2, max_len=max_len, **kw),
            scheduler=sched) as eng:
        eng.generate(reqs, sps)                 # warmup: compile traces
        gaps, last0 = [], None
        toks = {0: [], 1: [], 2: []}
        t_start = time.perf_counter()
        for ev in eng.generate_stream(reqs, sps):
            now = time.perf_counter()
            toks[ev.uid].append(ev.token)
            if ev.uid == 0:
                if last0 is not None:
                    gaps.append(now - last0)
                last0 = now
        wall = time.perf_counter() - t_start
    return {"tokens": toks, "max_stall_s": round(max(gaps), 4),
            "mean_stall_s": round(float(np.mean(gaps)), 4),
            "wall_s": round(wall, 4),
            "chunk": kw.get("prefill_chunk"),
            "max_step_tokens": kw.get("max_step_tokens")}


def run(arch: str = "tinyllama-1.1b", prompt: int = 1024,
        gen: int = 16, batch: int = 2, chunk="auto",
        smoke: bool = False) -> dict:
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # the MEASURED profile: chunk_split balances THIS machine's compute
    # rate against ITS host write-back bandwidth (on the preset A100
    # profile the smoke model's chunks would come out monolithic — the
    # predicted compute is far faster than this container's)
    sched = Scheduler(profile_system())
    max_len = prompt + gen + 8

    prefill = _bench_prefill(cfg, model, params, sched, prompt, batch,
                             chunk)
    inline = _admission_run(cfg, model, params, sched, prompt, gen,
                            None, max_len)
    chunked = _admission_run(cfg, model, params, sched, prompt, gen,
                             chunk, max_len)
    identical = chunked["tokens"] == inline["tokens"]
    out = {
        "config": {"arch": arch, "prompt": prompt, "gen": gen,
                   "batch": batch, "chunk": chunk},
        "prefill": prefill,
        "admission": {
            "inline": {k: v for k, v in inline.items() if k != "tokens"},
            "chunked": {k: v for k, v in chunked.items()
                        if k != "tokens"},
            "stall_ratio": round(inline["max_stall_s"]
                                 / max(chunked["max_stall_s"], 1e-9), 2),
            "tokens_identical": bool(identical),
        },
    }
    if smoke:
        out["smoke_ok"] = bool(
            identical and prefill["logits_identical"]
            and chunked["max_stall_s"] < inline["max_stall_s"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--prompt", type=int, default=1024,
                    help="long-prompt length (tokens)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2,
                    help="static prefill-throughput batch")
    ap.add_argument("--chunk", default="auto",
                    help="chunk width, or 'auto' (scheduler chunk_split)")
    ap.add_argument("--json", default=None,
                    help="also write the JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="exit 1 unless chunked admission stalls "
                         "strictly less than inline AND tokens match")
    args = ap.parse_args(argv)

    if args.smoke:
        args.prompt, args.gen = max(args.prompt, 1024), 12
    res = run(arch=args.arch, prompt=args.prompt, gen=args.gen,
              batch=args.batch, chunk=args.chunk, smoke=args.smoke)
    text = json.dumps(res, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    if args.smoke and not res["smoke_ok"]:
        adm = res["admission"]
        print("SMOKE FAIL: chunked admission did not beat inline "
              f"(inline={adm['inline']['max_stall_s']}s "
              f"chunked={adm['chunked']['max_stall_s']}s "
              f"identical={adm['tokens_identical']})", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
