"""Paper Fig. 9: KVPR + group-wise 4-bit KV cache compression — less data
over the link, further throughput gains (KVPR is orthogonal to
compression). Activations stay fp16; only the KV stream compresses."""
from __future__ import annotations

import dataclasses

from benchmarks.common import ffn_flops, fmt_row, layers_of, opt_workload
from repro.core.cost_model import A100_PCIE4, Workload
from repro.core.pipeline import kvpr_step, flexgen_step


def run(print_csv: bool = True):
    arch = "opt-13b"
    rows = []
    for prompt in (256, 512, 1024):
        wl16 = opt_workload(arch, 32, prompt, weights_offloaded=True)
        # 4-bit KV: kv stream bytes /4; activation & weight bytes unchanged
        wl4 = dataclasses.replace(wl16, dtype_bytes=0.5)
        wl4_act = wl16  # activations still 2 bytes -> use wl16 for act term
        ff = ffn_flops(arch, 32)
        base = kvpr_step(wl16, A100_PCIE4, "column", weights_resident=False,
                         fine_grained=True, d_ff_flops=ff)
        comp = kvpr_step(wl4, A100_PCIE4, "column", weights_resident=False,
                         fine_grained=True, d_ff_flops=ff)
        gain = (base.t_layer / comp.t_layer - 1) * 100
        rows.append((prompt, base.t_layer, comp.t_layer, gain))
        if print_csv:
            print(fmt_row(f"fig9/p{prompt}", f"{comp.t_layer*1e6:.1f}",
                          f"kvpr16_ms={base.t_layer*1e3:.3f} "
                          f"kvpr4bit_ms={comp.t_layer*1e3:.3f} "
                          f"gain={gain:.1f}%"))
    return rows


if __name__ == "__main__":
    run()
