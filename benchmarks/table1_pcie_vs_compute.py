"""Paper Table 1: KV cache size, PCIe transfer latency, and on-device
attention (KV-pair) compute latency for OPT models — the motivating gap
(transfer exceeds compute by >10x). FP16, batch 32, seq 1024, A100 +
PCIe 4.0 x16 profile."""
from __future__ import annotations

from benchmarks.common import fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4

# paper's reported values for comparison
PAPER = {"opt-6.7b": (512, 15.6, 0.3509),
         "opt-13b": (640, 19.5, 0.4388),
         "opt-30b": (896, 27.3, 0.6143)}


def run(print_csv: bool = True):
    rows = []
    for arch in ("opt-6.7b", "opt-13b", "opt-30b"):
        wl = opt_workload(arch, batch=32, seq_len=1024)
        kv_mb = wl.total_kv_bytes / 2**20
        t_pcie = wl.total_kv_bytes / A100_PCIE4.v_com * 1e3
        # Table 1's "Comp. Latency" is the attention read of the KV pair
        # from HBM (memory-bound at decode): bytes / HBM bandwidth.
        t_comp = wl.total_kv_bytes / A100_PCIE4.hbm_bandwidth * 1e3
        pkv, ppcie, pcomp = PAPER[arch]
        rows.append((arch, kv_mb, t_pcie, t_comp, pkv, ppcie, pcomp))
        if print_csv:
            print(fmt_row(f"table1/{arch}", f"{t_pcie*1e3:.1f}",
                          f"kv_mb={kv_mb:.0f}(paper {pkv}) "
                          f"pcie_ms={t_pcie:.2f}(paper {ppcie}) "
                          f"comp_ms={t_comp:.3f}(paper {pcomp})"))
    return rows


if __name__ == "__main__":
    run()
