"""Paper Fig. 14 (appendix A.7): CPU-assisted decoding (FastDecode-style,
attention on the host CPU) collapses when several GPUs share one CPU; KVPR
needs no host compute so it scales flat. We model host attention
throughput as a fixed CPU FLOP budget shared across processes."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import kvpr_step

CPU_FLOPS = 3.3e12          # 64-core EPYC, ~peak fp32 SIMD
CPU_MEM_BW = 200e9          # host DRAM bandwidth shared by processes


def run(print_csv: bool = True):
    from benchmarks.common import layers_of
    arch = "opt-6.7b"
    L = layers_of(arch)
    wl = opt_workload(arch, 32, 1024)
    ff = ffn_flops(arch, 32)
    rows = []
    for nproc in (1, 2, 4, 8):
        # FastDecode: attention runs on host; per-process share of DRAM bw
        attn_bytes = wl.total_kv_bytes
        t_cpu_attn = attn_bytes / (CPU_MEM_BW / nproc)
        t_rest = ff / A100_PCIE4.v_gpu
        fastdecode_tps = 32 / (L * (t_cpu_attn + t_rest))
        # KVPR: each GPU bound by its own PCIe link (not shared)
        st = kvpr_step(wl, A100_PCIE4, "row", d_ff_flops=ff)
        kvpr_tps = 32 / (L * st.t_layer)
        rows.append((nproc, fastdecode_tps, kvpr_tps))
        if print_csv:
            print(fmt_row(f"fig14/nproc{nproc}", f"{1e6/kvpr_tps:.0f}",
                          f"fastdecode_tps={fastdecode_tps:.1f} "
                          f"kvpr_tps={kvpr_tps:.1f}"))
    return rows


if __name__ == "__main__":
    run()
