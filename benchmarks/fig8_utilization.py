"""Paper Fig. 8: GPU utilization during decode — FlexGen vs KVPR (the
paper reports 85% -> 99% average)."""
from __future__ import annotations

from benchmarks.common import ffn_flops, fmt_row, opt_workload
from repro.core.cost_model import A100_PCIE4
from repro.core.pipeline import flexgen_step, kvpr_step


def run(print_csv: bool = True):
    arch = "opt-13b"
    rows = []
    for seq in (256, 512, 1024):
        wl = opt_workload(arch, 32, seq, weights_offloaded=True)
        ff = ffn_flops(arch, 32)
        fg = flexgen_step(wl, A100_PCIE4, weights_resident=False,
                          d_ff_flops=ff)
        kv = kvpr_step(wl, A100_PCIE4, "column", weights_resident=False,
                       fine_grained=True, d_ff_flops=ff)
        rows.append((seq, fg.utilization, kv.utilization))
        if print_csv:
            # NOTE: this is compute occupancy (GPU-busy / wall). The
            # paper's Fig. 8 uses nvidia-smi "utilization", which also
            # counts copy-engine activity — hence its higher baseline
            # (85%). The DELTA (KVPR raises busy time by overlapping
            # recompute with transfer) is the comparable quantity.
            print(fmt_row(f"fig8/s{seq}", f"{kv.utilization*100:.1f}",
                          f"flexgen_occupancy={fg.utilization*100:.1f}% "
                          f"kvpr_occupancy={kv.utilization*100:.1f}%"))
    return rows


if __name__ == "__main__":
    run()
